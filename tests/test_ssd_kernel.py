"""Pallas SSD kernel (interpret mode) vs sequential oracle: shape sweep,
state chaining, dtype, model-level parity, grads through custom_vjp."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.kernels.ref import ssd_ref
from repro.kernels.ssd import ssd_fwd
from repro.models import build_model


def _inputs(seed, B, S, H, P, N, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (B, S, N), dtype)
    c = jax.random.normal(ks[4], (B, S, N), dtype)
    return x, dt, a, b, c


SWEEP = [
    # B, S, H, P, N, chunk
    (2, 96, 3, 8, 16, 32),
    (1, 128, 2, 64, 128, 64),
    (2, 100, 4, 16, 32, 32),   # S not a chunk multiple
    (1, 64, 1, 8, 8, 64),      # single chunk
]


@pytest.mark.parametrize("case", SWEEP)
def test_kernel_matches_oracle(case):
    B, S, H, P, N, chunk = case
    x, dt, a, b, c = _inputs(sum(case), B, S, H, P, N)
    y_ref, s_ref = ssd_ref(x, dt, a, b, c)
    y, s = ssd_fwd(x, dt, a, b, c, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=3e-4, rtol=3e-4)


def test_state_chaining():
    x, dt, a, b, c = _inputs(0, 2, 128, 4, 16, 32)
    y_ref, s_ref = ssd_ref(x, dt, a, b, c)
    y1, s1 = ssd_fwd(x[:, :64], dt[:, :64], a, b[:, :64], c[:, :64], chunk=32, interpret=True)
    y2, s2 = ssd_fwd(
        x[:, 64:], dt[:, 64:], a, b[:, 64:], c[:, 64:], chunk=32, interpret=True,
        init_state=s1,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_ref), atol=3e-4, rtol=3e-4
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_ref), atol=3e-4, rtol=3e-4)


def test_bf16_inputs():
    x, dt, a, b, c = _inputs(1, 1, 64, 2, 16, 16, jnp.bfloat16)
    y_ref, _ = ssd_ref(x, dt, a, b, c)
    y, _ = ssd_fwd(x, dt, a, b, c, chunk=32, interpret=True)
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), atol=5e-2, rtol=5e-2
    )


def test_ops_dispatch_and_grads():
    x, dt, a, b, c = _inputs(2, 1, 64, 2, 8, 16)

    def loss(impl):
        def f(x, b, c):
            y, s = ops.ssd(x, dt, a, b, c, chunk=32, impl=impl)
            return (y**2).sum() + (s**2).sum()
        return f

    g_pallas = jax.grad(loss("pallas_interpret"), argnums=(0, 1, 2))(x, b, c)
    g_xla = jax.grad(loss("xla"), argnums=(0, 1, 2))(x, b, c)
    for gp, gx in zip(g_pallas, g_xla):
        np.testing.assert_allclose(np.asarray(gp), np.asarray(gx), atol=2e-3, rtol=2e-3)


def test_model_level_parity():
    cfg = get_config("mamba2-130m").reduced()
    lm_x = build_model(cfg.with_(ssd_impl="xla"))
    lm_p = build_model(cfg.with_(ssd_impl="pallas_interpret"))
    params = lm_x.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)}
    lx, _ = jax.jit(lm_x.loss)(params, batch)
    lp, _ = jax.jit(lm_p.loss)(params, batch)
    assert abs(float(lx) - float(lp)) < 1e-4
