"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step on CPU, asserting output shapes + no NaNs. Full configs are exercised
only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_configs, get_config
from repro.models import build_model
from repro.train.optimizer import make_optimizer
from repro.configs.base import TrainConfig

ARCHS = sorted(all_configs().keys())


def _batch_for(cfg, lm, seed=0):
    spec = lm.input_specs(SHAPES["train_4k"], reduced=True)
    key = jax.random.PRNGKey(seed)
    batch = {}
    for k, v in spec.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, v.shape, 0, cfg.vocab)
        else:
            batch[k] = jax.random.normal(key, v.shape, v.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_shapes_no_nan(arch):
    cfg = all_configs()[arch].reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, lm)
    loss, metrics = jax.jit(lm.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_updates_params(arch):
    cfg = all_configs()[arch].reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, lm)
    init, update = make_optimizer(TrainConfig(lr=1e-3, warmup_steps=0))
    opt = init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm.loss(p, batch), has_aux=True
        )(params)
        new_params, new_opt, stats = update(grads, opt, params)
        return new_params, new_opt, loss, stats

    new_params, _, loss, stats = step(params, opt, batch)
    assert np.isfinite(float(loss))
    assert float(stats["grad_norm"]) > 0
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, arch


@pytest.mark.parametrize(
    "arch",
    [
        "deepseek-7b",
        "olmoe-1b-7b",
        "mixtral-8x7b",
        "qwen2-72b",
        "codeqwen1_5-7b",
        "mamba2-130m",
        "zamba2-2_7b",
        "seamless-m4t-medium",
        "phi-3-vision-4_2b",
        "llama3-405b",
    ],
)
def test_prefill_decode_consistency(arch):
    cfg = all_configs()[arch].reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    if cfg.family == "encdec":
        src = jax.random.normal(jax.random.PRNGKey(2), (2, 24, cfg.d_model))
        batch = {"src_embeds": src, "tgt_tokens": toks}
        extend = lambda t: {"src_embeds": src, "tgt_tokens": t}
    elif cfg.family == "vlm":
        pe = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
        batch = {"tokens": toks, "prefix_embeds": pe}
        extend = lambda t: {"tokens": t, "prefix_embeds": pe}
    else:
        batch = {"tokens": toks}
        extend = lambda t: {"tokens": t}
    logits, caches = jax.jit(lambda p, b: lm.prefill(p, b, 48))(params, batch)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None]
    lg, caches = jax.jit(lm.decode_step)(params, nxt, caches)
    ext = jnp.concatenate([toks, nxt], 1)
    logits2, _ = jax.jit(lambda p, b: lm.prefill(p, b, 48))(params, extend(ext))
    np.testing.assert_allclose(
        np.asarray(lg[:, -1]), np.asarray(logits2[:, -1]), atol=1e-3, rtol=1e-3
    )


def test_sawtooth_vs_cyclic_configs_agree():
    """The paper's schedule is output-preserving at the model level too."""
    base = get_config("deepseek-7b").reduced()
    lm_s = build_model(base.with_(attn_order="sawtooth"))
    lm_c = build_model(base.with_(attn_order="cyclic"))
    params = lm_s.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, base.vocab)}
    l1, _ = jax.jit(lm_s.loss)(params, batch)
    l2, _ = jax.jit(lm_c.loss)(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-5
