"""LRU simulator vs the paper's empirical findings (§3.3, §3.4, §4.2)."""

import dataclasses

import pytest

from repro.core.cache_model import GB10, AttentionWorkload, cold_miss_sectors, l2_sector_accesses
from repro.core.cache_sim import LRUCache, SimResult, simulate_attention, simulate_trace


def scaled(cache_mb):
    return dataclasses.replace(GB10, cache_bytes=int(cache_mb * 2**20))


def test_lru_basics():
    r = SimResult()
    c = LRUCache(2)
    assert not c.access(("a",), 1, r)
    assert not c.access(("b",), 1, r)
    assert c.access(("a",), 1, r)          # hit
    assert not c.access(("c",), 1, r)      # evicts b (LRU)
    assert not c.access(("b",), 1, r)      # miss again
    assert r.accesses == 5 and r.misses == 4 and r.cold_misses == 3


def test_trace_access_count_matches_model():
    w = AttentionWorkload(seq_len=4096, tile=64)
    r = simulate_attention(w, GB10, "cyclic", n_workers=8)
    assert r.accesses == pytest.approx(l2_sector_accesses(w, GB10), rel=1e-6)


def test_fits_in_cache_only_cold_misses():
    w = AttentionWorkload(seq_len=8192, tile=64)  # KV 2MB << 24MB
    for order in ("cyclic", "sawtooth"):
        r = simulate_attention(w, GB10, order, n_workers=48)
        assert r.non_compulsory_misses == 0
        assert r.cold_misses == pytest.approx(cold_miss_sectors(w, GB10), rel=1e-6)


def test_hit_rate_law_1_minus_1_over_n():
    """Paper Fig 6: in the overflow regime hit rate ~ 1 - 1/N."""
    hw = scaled(2)
    w = AttentionWorkload(seq_len=16384, tile=64)  # KV 4MB vs 2MB
    for n in (1, 2, 4, 8, 16):
        r = simulate_attention(w, hw, "cyclic", n_workers=n)
        expect = 1 - 1 / n
        assert abs(r.hit_rate - expect) < 0.05, (n, r.hit_rate)


def test_divergence_when_kv_exceeds_cache():
    hw = scaled(2)
    small = AttentionWorkload(seq_len=4096, tile=64)   # KV 1MB < 2MB
    big = AttentionWorkload(seq_len=16384, tile=64)    # KV 4MB > 2MB
    assert simulate_attention(small, hw, "cyclic").non_compulsory_misses == 0
    assert simulate_attention(big, hw, "cyclic").non_compulsory_misses > 0


def test_sawtooth_halves_noncompulsory_misses():
    """Paper §4.2: ~50% reduction at the paper's overflow ratio (~1.33x)."""
    hw = scaled(3)
    w = AttentionWorkload(seq_len=16384, tile=64)  # KV 4MB vs 3MB cache
    cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
    saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
    reduction = 1 - saw.non_compulsory_misses / cyc.non_compulsory_misses
    assert reduction > 0.45, reduction


def test_sawtooth_never_worse_lru():
    """Property: under LRU, sawtooth non-compulsory misses <= cyclic for this
    wavefront workload across overflow ratios."""
    for cache_mb in (0.5, 1, 2, 3, 8):
        hw = scaled(cache_mb)
        w = AttentionWorkload(seq_len=8192, tile=64)
        cyc = simulate_attention(w, hw, "cyclic", n_workers=16)
        saw = simulate_attention(w, hw, "sawtooth", n_workers=16)
        assert saw.non_compulsory_misses <= cyc.non_compulsory_misses + 1e-9


def test_causal_sawtooth_still_helps():
    hw = scaled(2)
    w = AttentionWorkload(seq_len=16384, tile=64, causal=True)
    cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
    saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
    assert saw.non_compulsory_misses < cyc.non_compulsory_misses
