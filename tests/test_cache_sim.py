"""LRU simulator vs the paper's empirical findings (§3.3, §3.4, §4.2)."""

import dataclasses

import pytest

from repro.core.cache_model import GB10, AttentionWorkload, cold_miss_sectors, l2_sector_accesses
from repro.core.cache_sim import LRUCache, SimResult, simulate_attention, simulate_trace


def scaled(cache_mb):
    return dataclasses.replace(GB10, cache_bytes=int(cache_mb * 2**20))


def test_lru_basics():
    r = SimResult()
    c = LRUCache(2)
    assert not c.access(("a",), 1, r)
    assert not c.access(("b",), 1, r)
    assert c.access(("a",), 1, r)          # hit
    assert not c.access(("c",), 1, r)      # evicts b (LRU)
    assert not c.access(("b",), 1, r)      # miss again
    assert r.accesses == 5 and r.misses == 4 and r.cold_misses == 3


def test_trace_access_count_matches_model():
    w = AttentionWorkload(seq_len=4096, tile=64)
    r = simulate_attention(w, GB10, "cyclic", n_workers=8)
    assert r.accesses == pytest.approx(l2_sector_accesses(w, GB10), rel=1e-6)


def test_fits_in_cache_only_cold_misses():
    w = AttentionWorkload(seq_len=8192, tile=64)  # KV 2MB << 24MB
    for order in ("cyclic", "sawtooth"):
        r = simulate_attention(w, GB10, order, n_workers=48)
        assert r.non_compulsory_misses == 0
        assert r.cold_misses == pytest.approx(cold_miss_sectors(w, GB10), rel=1e-6)


def test_hit_rate_law_1_minus_1_over_n():
    """Paper Fig 6: in the overflow regime hit rate ~ 1 - 1/N."""
    hw = scaled(2)
    w = AttentionWorkload(seq_len=16384, tile=64)  # KV 4MB vs 2MB
    for n in (1, 2, 4, 8, 16):
        r = simulate_attention(w, hw, "cyclic", n_workers=n)
        expect = 1 - 1 / n
        assert abs(r.hit_rate - expect) < 0.05, (n, r.hit_rate)


def test_divergence_when_kv_exceeds_cache():
    hw = scaled(2)
    small = AttentionWorkload(seq_len=4096, tile=64)   # KV 1MB < 2MB
    big = AttentionWorkload(seq_len=16384, tile=64)    # KV 4MB > 2MB
    assert simulate_attention(small, hw, "cyclic").non_compulsory_misses == 0
    assert simulate_attention(big, hw, "cyclic").non_compulsory_misses > 0


def test_sawtooth_halves_noncompulsory_misses():
    """Paper §4.2: ~50% reduction at the paper's overflow ratio (~1.33x)."""
    hw = scaled(3)
    w = AttentionWorkload(seq_len=16384, tile=64)  # KV 4MB vs 3MB cache
    cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
    saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
    reduction = 1 - saw.non_compulsory_misses / cyc.non_compulsory_misses
    assert reduction > 0.45, reduction


def test_sawtooth_never_worse_lru():
    """Property: under LRU, sawtooth non-compulsory misses <= cyclic for this
    wavefront workload across overflow ratios."""
    for cache_mb in (0.5, 1, 2, 3, 8):
        hw = scaled(cache_mb)
        w = AttentionWorkload(seq_len=8192, tile=64)
        cyc = simulate_attention(w, hw, "cyclic", n_workers=16)
        saw = simulate_attention(w, hw, "sawtooth", n_workers=16)
        assert saw.non_compulsory_misses <= cyc.non_compulsory_misses + 1e-9


def test_causal_sawtooth_still_helps():
    hw = scaled(2)
    w = AttentionWorkload(seq_len=16384, tile=64, causal=True)
    cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
    saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
    assert saw.non_compulsory_misses < cyc.non_compulsory_misses


# ---- paged decode page traces (serving-side locality) -----------------------


def test_reuse_distances_stack_semantics():
    from repro.core.cache_sim import reuse_distances

    # a b a a c b : a@2 saw {b}=1, a@3 saw {}=0, b@5 saw {a,c}=2
    trace = [("a",), ("b",), ("a",), ("a",), ("c",), ("b",)]
    assert reuse_distances(trace) == [1, 0, 2]


def test_paged_decode_sawtooth_lowers_mean_reuse_distance():
    """Acceptance: sawtooth page traversal in decode (parity = cache length)
    beats cyclic on mean reuse distance — the serving analogue of Fig 8."""
    from repro.core.cache_sim import simulate_paged_decode

    for lens in ([64], [48, 120, 16]):
        cyc = simulate_paged_decode("cyclic", lens, n_steps=32, page=16)
        saw = simulate_paged_decode("sawtooth", lens, n_steps=32, page=16)
        assert saw["mean_reuse_distance"] < cyc["mean_reuse_distance"], (
            lens,
            cyc,
            saw,
        )
        assert saw["accesses"] == cyc["accesses"]  # same work, better order


def test_paged_decode_trace_lru_hit_rate():
    """With a cache holding fewer pages than one pass touches, sawtooth's
    tail-first re-touch converts boundary re-reads into hits."""
    from repro.core.cache_sim import simulate_paged_decode

    cap = 6  # pages; one sequence at 128 tokens / page 16 streams 8+ pages
    cyc = simulate_paged_decode("cyclic", [128], n_steps=16, page=16, capacity_pages=cap)
    saw = simulate_paged_decode("sawtooth", [128], n_steps=16, page=16, capacity_pages=cap)
    assert saw["hit_rate"] > cyc["hit_rate"]
