import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.train.optimizer import cosine_schedule, global_norm, make_optimizer


def _quad_problem(factored):
    target = jnp.array([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2)), "ln_f": {"scale": jnp.ones((4,))}}
    cfg = TrainConfig(
        lr=0.1,
        warmup_steps=0,
        total_steps=200,
        weight_decay=0.0,
        optimizer="adamw_factored" if factored else "adamw",
    )
    init, update = make_optimizer(cfg)

    def loss(p):
        return ((p["w"] - target) ** 2).sum() + (p["ln_f"]["scale"] ** 2).sum() * 0.0

    opt = init(params)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, stats = update(g, opt, params)
    return params, target


@pytest.mark.parametrize("factored", [False, True])
def test_converges_to_target(factored):
    params, target = _quad_problem(factored)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)


def test_factored_state_is_smaller():
    params = {"w": jnp.zeros((128, 256))}
    cfg_full = TrainConfig(optimizer="adamw")
    cfg_fact = TrainConfig(optimizer="adamw_factored")
    full = make_optimizer(cfg_full)[0](params)
    fact = make_optimizer(cfg_fact)[0](params)
    full_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(full))
    fact_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(fact))
    assert fact_bytes < 0.5 * full_bytes  # bf16 m + rank-1 v


def test_grad_clipping():
    params = {"w": jnp.zeros((4,))}
    cfg = TrainConfig(lr=1.0, warmup_steps=0, grad_clip=1.0, weight_decay=0.0)
    init, update = make_optimizer(cfg)
    opt = init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, stats = update(huge, opt, params)
    assert float(stats["clip"]) < 1e-5


def test_schedule_warmup_and_decay():
    cfg = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lr = cosine_schedule(cfg)
    assert float(lr(0)) < float(lr(10))
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) < float(lr(50))


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))
