import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


@pytest.mark.parametrize("arch", ["deepseek-7b", "mixtral-8x7b", "mamba2-130m"])
def test_engine_batches_requests(arch):
    cfg = get_config(arch).reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_size=4, max_len=96)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            tokens=rng.integers(2, cfg.vocab, size=4 + i).astype(np.int32),
            max_new_tokens=5,
            rid=i,
        )
        for i in range(7)  # spans two batches incl. ragged last one
    ]
    res = eng.generate(reqs)
    assert [r.rid for r in res] == list(range(7))
    assert all(1 <= r.steps <= 5 for r in res)


def test_greedy_is_deterministic():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_size=2, max_len=64)
    prompt = np.arange(2, 10, dtype=np.int32)
    a = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    b = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_temperature_sampling_runs():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_size=2, max_len=64, seed=1)
    prompt = np.arange(2, 10, dtype=np.int32)
    out = eng.generate([Request(tokens=prompt, max_new_tokens=6, temperature=1.0)])[0]
    assert out.steps >= 1
