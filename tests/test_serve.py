import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import PagedKVPool, PagePool, Request, ServeEngine


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ["deepseek-7b", "mixtral-8x7b", "mamba2-130m"])
def test_engine_batches_requests(arch):
    cfg = get_config(arch).reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_size=4, max_len=96)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            tokens=rng.integers(2, cfg.vocab, size=4 + i).astype(np.int32),
            max_new_tokens=5,
            rid=i,
        )
        for i in range(7)  # spans two batches incl. ragged last one
    ]
    res = eng.generate(reqs)
    assert [r.rid for r in res] == list(range(7))
    assert all(1 <= r.steps <= 5 for r in res)


def test_greedy_is_deterministic():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_size=2, max_len=64)
    prompt = np.arange(2, 10, dtype=np.int32)
    a = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    b = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_temperature_sampling_runs():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServeEngine(lm, params, batch_size=2, max_len=64, seed=1)
    prompt = np.arange(2, 10, dtype=np.int32)
    out = eng.generate([Request(tokens=prompt, max_new_tokens=6, temperature=1.0)])[0]
    assert out.steps >= 1


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_greedy_row_unaffected_by_sampling_neighbor(deepseek_lm, scheduler):
    """Per-row sampling: a temperature=0 request batched with a hot request
    must produce the same tokens as when served alone (the old engine took
    max(temperature) over the batch)."""
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler=scheduler, page_size=16
    )
    # Same prompt length: the static path shares one prefill bucket, and a
    # longer neighbor would change the greedy row's left-padding (a separate
    # effect from sampling).
    greedy = lambda: Request(tokens=np.arange(2, 10, dtype=np.int32), max_new_tokens=6, rid=0)
    hot = Request(
        tokens=np.arange(3, 11, dtype=np.int32), max_new_tokens=6, temperature=1.5, rid=1
    )
    solo = eng.generate([greedy()])[0]
    paired = eng.generate([greedy(), hot])[0]
    np.testing.assert_array_equal(solo.tokens, paired.tokens)


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_identical_sampling_requests_decorrelate(deepseek_lm, scheduler):
    """Default seeds fall back to the submission index: N copies of the same
    temperature>0 request must not return N identical streams."""
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=4, max_len=64, scheduler=scheduler, page_size=16
    )
    mk = lambda: Request(
        tokens=np.arange(2, 10, dtype=np.int32), max_new_tokens=8, temperature=1.5
    )
    res = eng.generate([mk() for _ in range(4)])
    streams = {tuple(r.tokens.tolist()) for r in res}
    assert len(streams) > 1, streams


# ---- continuous batching ----------------------------------------------------


def test_continuous_engine_serves_stream(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=4, max_len=96, scheduler="continuous", page_size=16
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            tokens=rng.integers(2, lm.cfg.vocab, size=4 + 3 * i).astype(np.int32),
            max_new_tokens=5,
            rid=i,
            arrival=i // 3,  # staggered arrival: slots refill mid-decode
        )
        for i in range(9)  # more requests than slots
    ]
    res = eng.generate(reqs)
    assert [r.rid for r in res] == list(range(9))  # input order preserved
    assert all(1 <= r.steps <= 5 for r in res)
    assert all(len(r.tokens) == r.steps for r in res)


def test_continuous_matches_static_solo_greedy(deepseek_lm):
    """A single greedy request sees no batch neighbors in either scheduler,
    and per-request bucketing matches when the prompt fills the bucket —
    the decode streams must then agree token-for-token."""
    lm, params = deepseek_lm
    prompt = np.arange(2, 18, dtype=np.int32)  # len 16 == its power-of-2 bucket
    a = ServeEngine(lm, params, batch_size=1, max_len=64).generate(
        [Request(tokens=prompt, max_new_tokens=6)]
    )[0]
    b = ServeEngine(
        lm, params, batch_size=1, max_len=64, scheduler="continuous", page_size=16
    ).generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_continuous_eos_override_truncates(deepseek_lm):
    """Request.eos_id: re-serving with eos_id set to the greedy stream's
    second token must stop the generation right there."""
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler="continuous", page_size=16
    )
    prompt = np.arange(2, 10, dtype=np.int32)
    base = eng.generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    assert base.steps >= 2, "need at least two tokens to test truncation"
    stop_at = int(base.tokens[1])
    expect = int(np.flatnonzero(base.tokens == stop_at)[0]) + 1  # first hit
    cut = eng.generate(
        [Request(tokens=prompt, max_new_tokens=6, eos_id=stop_at)]
    )[0]
    assert cut.steps == expect
    np.testing.assert_array_equal(cut.tokens, base.tokens[:expect])


def test_continuous_rejects_unsupported_family():
    cfg = get_config("mixtral-8x7b").reduced()  # SWA window
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="continuous"):
        ServeEngine(lm, params, batch_size=2, max_len=64, scheduler="continuous")


def test_engine_eos_follows_model_config(deepseek_lm):
    lm, params = deepseek_lm
    lm7 = build_model(lm.cfg.with_(eos_id=7))
    eng = ServeEngine(lm7, params, batch_size=2, max_len=64)
    assert eng.eos == 7


# ---- pool bookkeeping -------------------------------------------------------


def test_page_pool_alloc_free_reserve():
    pool = PagePool(8)  # pages 1..7 allocatable (0 = dummy)
    assert pool.free_count == 7
    ids = pool.alloc(3)
    assert 0 not in ids and len(set(ids)) == 3
    pool.reserved = 4
    assert pool.available == 0
    pool.free(ids)
    pool.reserved = 0
    assert pool.free_count == 7
    with pytest.raises(RuntimeError):
        pool.alloc(8)


def test_paged_kv_pool_lifecycle(deepseek_lm):
    lm, _ = deepseek_lm
    cfg = lm.cfg.with_(kv_layout="paged", page_size=16)
    pool = PagedKVPool(cfg, cfg.n_layers, n_slots=2, max_len=64)
    assert pool.alloc.free_count == 2 * 4  # 4 pages per slot, dummy excluded
    assert pool.can_admit(16, 8)

    prompt = np.arange(2, 18, dtype=np.int32)  # 16 tokens = 1 full page
    shared = pool.admit(0, prompt, max_new=8)
    assert shared == 0  # empty registry: nothing to adopt
    assert pool.lens[0] == 0 and not pool.block_tables[0].any()
    assert pool.alloc.reserved == 2  # 16+8 tokens -> 2 pages worst, all lazy
    pool.ensure_writable(0, 16)  # the prefill chunk materializes page 0
    assert pool.block_tables[0, 0] != 0 and pool.alloc.reserved == 1
    pool.advance(0, 16)
    pool.register_prompt(0, prompt)
    pool.ensure_writable(0)  # first decode write crosses into page 1
    assert pool.alloc.reserved == 0 and pool.block_tables[0, 1] != 0
    pool.check_invariants()
    pool.release(0)
    assert pool.alloc.free_count == 8 and pool.alloc.reserved == 0
    assert pool.lens[0] == 0 and not pool.block_tables[0].any()
    pool.check_invariants()


def test_paged_kv_pool_prefix_sharing_and_cow(deepseek_lm):
    """A second admission with a matching prompt adopts the donor's frozen
    pages (no allocation), and copy-on-write forks the partially covered
    tail page on its first write."""
    lm, _ = deepseek_lm
    cfg = lm.cfg.with_(kv_layout="paged", page_size=8)
    pool = PagedKVPool(cfg, cfg.n_layers, n_slots=3, max_len=64)  # 8 pages/slot
    prompt = np.arange(2, 26, dtype=np.int32)  # 24 tokens: 3 full pages

    assert pool.admit(0, prompt, max_new=4) == 0
    pool.ensure_writable(0, 24)
    pool.advance(0, 24)
    pool.register_prompt(0, prompt)
    donor_pages = list(pool._slot_pages[0])

    # Same prompt: full-page match capped at len-1=23 -> pages 0,1 full +
    # page 2 partially (7 of 8 tokens).
    shared = pool.admit(1, prompt, max_new=4)
    assert shared == 23
    assert pool.shared_hits == 3
    assert pool._slot_pages[1] == donor_pages  # adopted, not copied
    assert pool.lens[1] == 23
    pool.check_invariants()

    # The adopter's first write (prompt token 23 at position 23) lands in
    # shared page 2 -> CoW fork; donor's page is untouched.
    free_before = pool.alloc.free_count
    pool.ensure_writable(1, 1)
    assert pool.cow_forks == 1
    assert pool._slot_pages[1][2] != donor_pages[2]
    assert pool._slot_pages[1][:2] == donor_pages[:2]  # frozen pages still shared
    assert pool.alloc.free_count == free_before - 1
    assert pool._ref[donor_pages[2]] == 1 and pool._ref[donor_pages[0]] == 2
    pool.check_invariants()

    # Releasing the donor keeps the shared pages alive for the adopter.
    pool.release(0)
    assert pool._ref[donor_pages[0]] == 1
    pool.check_invariants()
    pool.release(1)
    assert pool.alloc.free_count == pool.alloc.n_pages - 1
    pool.check_invariants()


def test_paged_kv_pool_prefix_divergent_prompt_no_match(deepseek_lm):
    lm, _ = deepseek_lm
    cfg = lm.cfg.with_(kv_layout="paged", page_size=8)
    pool = PagedKVPool(cfg, cfg.n_layers, n_slots=2, max_len=64)
    prompt = np.arange(2, 26, dtype=np.int32)
    pool.admit(0, prompt, max_new=4)
    pool.ensure_writable(0, 24)
    pool.advance(0, 24)
    pool.register_prompt(0, prompt)
    other = prompt.copy()
    other[1] = 99  # diverges inside the first page
    assert pool.admit(1, other, max_new=4) == 0
    pool.check_invariants()
