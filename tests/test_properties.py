"""Hypothesis property tests on the system's invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.attention import flash_attention, mha_reference
from repro.core.cache_model import GB10, AttentionWorkload, l2_sector_accesses
from repro.core.cache_sim import simulate_attention, simulate_trace
from repro.core.schedule import KVSchedule, Order, kv_index_host
from repro.dist.compression import dequantize_int8, quantize_int8

SETTINGS = settings(max_examples=25, deadline=None)


@SETTINGS
@given(
    n_q=st.integers(1, 12),
    n_kv=st.integers(1, 12),
    order=st.sampled_from(list(Order)),
)
def test_schedule_always_a_permutation(n_q, n_kv, order):
    s = KVSchedule(order, n_q=n_q, n_kv=n_kv)
    for i in range(n_q):
        assert sorted(s.kv_order(i)) == list(range(n_kv))


@SETTINGS
@given(
    seq=st.integers(1, 64).map(lambda x: x * 256),
    tile=st.sampled_from([64, 80, 128]),
    causal=st.booleans(),
)
def test_sector_model_positive_and_monotone(seq, tile, causal):
    w1 = AttentionWorkload(seq_len=seq, tile=tile, causal=causal)
    w2 = AttentionWorkload(seq_len=seq * 2, tile=tile, causal=causal)
    a1, a2 = l2_sector_accesses(w1, GB10), l2_sector_accesses(w2, GB10)
    assert 0 < a1 < a2


@SETTINGS
@given(
    seed=st.integers(0, 2**16),
    sq=st.integers(2, 6).map(lambda x: x * 16),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2]),
    causal=st.booleans(),
)
def test_attention_order_invariance(seed, sq, hkv, g, causal):
    """Online softmax is KV-traversal-order invariant (the property that
    makes the paper's reordering a pure performance change)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (1, sq, hkv * g, 32))
    k = jax.random.normal(k2, (1, sq, hkv, 32))
    v = jax.random.normal(k3, (1, sq, hkv, 32))
    a = flash_attention(q, k, v, order="cyclic", causal=causal, q_block=16, kv_block=16)
    b = flash_attention(q, k, v, order="sawtooth", causal=causal, q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)
    ref = mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), atol=1e-4, rtol=1e-4)


@SETTINGS
@given(
    seed=st.integers(0, 2**16),
    shape=st.sampled_from([(64,), (33,), (8, 129), (3, 5, 7)]),
    scale=st.floats(1e-3, 1e3),
)
def test_int8_quantization_error_bound(seed, shape, scale):
    x = np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), shape) * scale, np.float32
    )
    q, s = quantize_int8(jnp.asarray(x))
    back = np.asarray(dequantize_int8(q, s, x.shape, jnp.float32))
    # blockwise symmetric int8: error <= scale/2 per element, scale = max/127
    bound = np.abs(x).max() / 127.0 * 0.5 + 1e-7
    assert np.abs(back - x).max() <= bound * 1.001


@SETTINGS
@given(
    cache_tiles=st.integers(2, 40),
    n_tiles=st.integers(2, 24),
    workers=st.integers(1, 8),
)
def test_lru_inclusion_bigger_cache_never_more_misses(cache_tiles, n_tiles, workers):
    """LRU stack property: growing the cache can't increase misses."""
    w = AttentionWorkload(seq_len=n_tiles * 64, tile=64)
    hw_small = dataclasses.replace(GB10, cache_bytes=cache_tiles * 64 * 64 * 2)
    hw_big = dataclasses.replace(GB10, cache_bytes=2 * cache_tiles * 64 * 64 * 2)
    for order in ("cyclic", "sawtooth"):
        small = simulate_attention(w, hw_small, order, n_workers=workers)
        big = simulate_attention(w, hw_big, order, n_workers=workers)
        assert big.misses <= small.misses + 1e-9


@SETTINGS
@given(seed=st.integers(0, 2**10), n=st.integers(1, 6))
def test_sim_trace_conservation(seed, n):
    """Accesses == hits + misses; cold misses <= distinct keys' sectors."""
    rng = np.random.default_rng(seed)
    trace = [((int(rng.integers(0, 10)),), 4.0) for _ in range(50 * n)]
    r = simulate_trace(trace, capacity_sectors=16)
    assert r.accesses == r.hits + r.misses
    distinct = len({k for k, _ in trace})
    assert r.cold_misses == distinct * 4.0
