"""Shared helpers for the order-parametrized kernel/backward sweeps."""

ALL_ORDERS = ["cyclic", "sawtooth", "block_snake"]


def order_kwargs(order):
    """block_snake with a small group so 2-4-tile test grids don't clamp to
    the sawtooth degenerate."""
    return {"snake_group": 2} if order == "block_snake" else {}
