"""Tiered KV memory: host-offload page tier under the device pool
(DESIGN.md §13).

Unit layer first — the bounded ``HostPageStore``, the reuse-distance spill
victim policy and its ``cache_sim`` ranking signal, the full-slot
spill/resume roundtrip (bitwise, through a prefix-sharing donor too) — then
a hypothesis random walk over the cross-tier lifecycle (admit / step /
spill / staged resume / release) holding ``check_invariants`` plus page
conservation across both tiers, and the engine integration: a tiered
engine under a device pool sized below the working set must spill instead
of preempt and stay bitwise identical to an unconstrained reference, with
the ``tier.spill`` / ``tier.fetch`` faults degrading it to preemption /
late resume — never to divergence.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.cache_sim import slot_reuse_stats
from repro.models import build_model
from repro.serve import (
    FaultPlan,
    HostPageStore,
    PoolExhausted,
    Request,
    ServeEngine,
    TieredPagePool,
    select_spill_victim,
)

SETTINGS = settings(max_examples=15, deadline=None)


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _pool_cfg():
    return get_config("deepseek-7b").reduced().with_(
        kv_layout="paged", page_size=4
    )


def _tiered(n_pages=13, host_pages=16, n_slots=3):
    return TieredPagePool(
        _pool_cfg(), 1, n_slots, max_len=32, admission="optimistic",
        n_pages=n_pages, host_pages=host_pages,
    )


def _fill_random(pool, seed=0):
    """Overwrite every pool leaf with recognizable random payloads so a
    spill/resume roundtrip has real bits to preserve."""
    rng = np.random.default_rng(seed)
    for name, leaf in pool.pages.items():
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            arr = rng.integers(-100, 100, size=leaf.shape)
        else:
            arr = rng.standard_normal(leaf.shape)
        pool.pages[name] = jnp.asarray(arr, dtype=leaf.dtype)


def _slot_rows(pool, slot):
    """{leaf -> (L, n_pages, ...)} snapshot of a slot's device pages, in
    logical page order."""
    pids = list(pool._slot_pages[slot])
    return {name: np.asarray(leaf)[:, pids] for name, leaf in pool.pages.items()}


def _grow(pool, slot, n):
    """Materialize ``n`` tokens of owned pages (allocation is lazy: admit
    reserves, only writes allocate — this is the prefill/decode stand-in)."""
    pool.ensure_writable(slot, n)
    pool.advance(slot, n)


def _resume(pool, slot, depth=2, order=None):
    pool.start_resume(slot, order=order)
    while not pool.resume_ready(slot):
        assert pool.issue_fetches(slot, depth) > 0
    assert pool.complete_resume(slot)


def _reqs(vocab, n, *, plen=24, max_new=8):
    rng = np.random.default_rng(5)
    return [
        Request(
            tokens=rng.integers(2, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new,
            rid=i,
        )
        for i in range(n)
    ]


# ---- host store --------------------------------------------------------------


def test_host_store_bounded_roundtrip():
    store = HostPageStore(2)
    row = {"k": np.arange(8.0).reshape(1, 8)}
    h0 = store.put(row)
    h1 = store.put({"k": np.ones((1, 8))})
    assert store.used == 2 and store.free == 0
    assert store.nbytes == 2 * row["k"].nbytes
    with pytest.raises(PoolExhausted):
        store.put({"k": np.zeros((1, 8))})
    assert (store.get(h0)["k"] == row["k"]).all()
    assert (store.pop(h1)["k"] == 1).all()
    assert store.free == 1
    assert store.put({"k": np.zeros((1, 8))}) not in (h0, h1)  # handles fresh

    with pytest.raises(ValueError):
        HostPageStore(0)


# ---- spill victim policy -----------------------------------------------------


def test_select_spill_victim_policy():
    assert select_spill_victim([]) is None
    # Priority dominates everything.
    assert select_spill_victim(
        [(0, 1, False, 99.0), (1, 0, True, 0.0)]
    ) == 1
    # Same priority: non-donors first (spilling a donor host-copies pages
    # that stay device-resident for the adopters anyway).
    assert select_spill_victim(
        [(0, 0, True, 99.0), (1, 0, False, 1.0)]
    ) == 1
    # Then the LARGEST mean reuse distance — the coldest page stream.
    assert select_spill_victim(
        [(0, 0, False, 2.0), (1, 0, False, 7.0), (2, 0, False, 4.0)]
    ) == 1
    # Full tie: lowest slot index, deterministically.
    assert select_spill_victim(
        [(2, 0, False, 3.0), (0, 0, False, 3.0), (1, 0, False, 3.0)]
    ) == 0


def test_reuse_distance_ranking_is_traversal_aware():
    """The ``cache_sim`` ranking signal on a sawtooth decode trace: the
    boundary reversal re-touches a long row's tail pages promptly, so the
    *short* rows are the ones whose pages only recur after the full
    interleaved sweep — their mean LRU stack distance is strictly larger,
    and the victim policy spills the shortest (coldest) stream first.
    Plain last-touch LRU is blind to this: lock-step decode touches every
    slot every step, so recency ties across all slots — as does a cyclic
    traversal, whose per-slot distances are identical by construction."""
    lens = [8, 16, 32]
    stats = slot_reuse_stats("sawtooth", lens, 4)
    means = [s["mean"] for s in stats]
    assert means[0] > means[1] > means[2]  # sawtooth favors long tails
    victim = select_spill_victim(
        [(i, 0, False, m) for i, m in enumerate(means)]
    )
    assert victim == 0
    # Cyclic traversal: every slot's distances tie — the ranking carries
    # no information and the policy degrades to the deterministic index
    # tiebreak, the same choice a recency-tied LRU would make.
    cyc = slot_reuse_stats("cyclic", lens, 4)
    assert len({round(s["mean"], 9) for s in cyc}) == 1
    assert select_spill_victim(
        [(i, 0, False, s["mean"]) for i, s in enumerate(cyc)]
    ) == 0


# ---- spill / resume roundtrip (unit) -----------------------------------------


def test_spill_resume_roundtrip_bitwise():
    pool = _tiered()
    rng = np.random.default_rng(3)
    prompt = rng.integers(2, 5, size=10).astype(np.int32)
    assert pool.admit(0, prompt, 8) is not None
    _grow(pool, 0, len(prompt))
    _fill_random(pool)
    before = _slot_rows(pool, 0)
    n_pages = len(pool._slot_pages[0])
    len_before = int(pool.lens[0])
    free_before = pool.alloc.free_count

    assert pool.spill_slot(0)
    pool.check_invariants()
    assert pool.is_suspended(0) and pool.suspended_slots() == [0]
    assert not pool.can_spill(0)                      # no double spill
    assert pool.host.used == n_pages
    assert int(pool.lens[0]) == len_before            # logical length kept
    assert not pool._slot_pages[0]
    assert pool.alloc.free_count == free_before + n_pages
    assert pool.spill_bytes == pool.host.nbytes

    # Resume in a (partial, noisy) visit order: out-of-range entries are
    # dropped, unnamed pages follow in logical order.
    assert pool.resume_need(0) == n_pages
    _resume(pool, 0, depth=2, order=[n_pages - 1, 99, -1])
    pool.check_invariants()
    assert not pool.is_suspended(0) and pool.host.used == 0
    after = _slot_rows(pool, 0)
    for name in before:
        assert np.array_equal(before[name], after[name]), name
    assert pool.fetches == n_pages and pool.fetch_bytes == pool.spill_bytes

    # First advance classifies the staged pages as prefetch hits.
    assert pool.shielded(0)
    pool.ensure_writable(0, 1)
    pool.advance(0, 1)
    assert not pool.shielded(0)
    assert pool.prefetch_hits == n_pages and pool.prefetch_wasted == 0

    pool.release(0)
    pool.check_invariants()
    assert pool.alloc.free_count == pool.alloc.n_pages - 1


def test_release_while_suspended_counts_wasted():
    pool = _tiered()
    assert pool.admit(0, np.arange(2, 10).astype(np.int32), 4) is not None
    _grow(pool, 0, 8)
    assert pool.spill_slot(0)
    pool.start_resume(0)
    staged = pool.issue_fetches(0, 1)
    assert staged == 1
    pool.release(0)                    # cancelled mid-resume
    pool.check_invariants()
    assert pool.host.used == 0         # host copies dropped with the slot
    assert pool.prefetch_wasted == staged
    assert pool.fetches == pool.prefetch_hits + pool.prefetch_wasted


def test_complete_resume_is_atomic_under_pressure():
    pool = _tiered(n_pages=13)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, 200, size=n).astype(np.int32)
               for n in (16, 20, 16)]       # distinct: no prefix adoption
    assert pool.admit(0, prompts[0], 4) is not None
    _grow(pool, 0, 16)
    n = len(pool._slot_pages[0])
    assert pool.spill_slot(0)
    # Fill the freed device pages so the resume cannot fit.
    assert pool.admit(1, prompts[1], 4) is not None
    _grow(pool, 1, 20)
    _grow(pool, 1, 4)                           # decode growth: 6th page
    assert pool.admit(2, prompts[2], 4) is not None
    _grow(pool, 2, 16)
    pool.start_resume(0)
    while pool.issue_fetches(0, 4):
        pass
    assert pool.resume_ready(0)
    assert pool.alloc.available < pool.resume_need(0)
    assert not pool.complete_resume(0)          # refused, nothing changed
    pool.check_invariants()
    assert pool.is_suspended(0) and pool.host.used == n
    pool.release(2)                             # pressure clears...
    assert pool.complete_resume(0)              # ...same call now lands
    pool.check_invariants()
    assert len(pool._slot_pages[0]) == n and pool.host.used == 0


def test_spill_donor_keeps_serving_adopters():
    """Spilling a prefix donor host-copies its pages and ref-decrements:
    the adopter keeps attending the same physical pages, and the donor
    resumes onto private copies with identical bits."""
    pool = _tiered()
    prompt = np.arange(2, 10).astype(np.int32)  # 2 full pages: registrable
    assert pool.admit(0, prompt, 4) is not None
    _grow(pool, 0, len(prompt))
    pool.register_prompt(0, prompt)              # publish the frozen pages
    _fill_random(pool)
    assert pool.admit(1, prompt, 4) is not None  # adopts the donor's pages
    shared = set(pool._slot_pages[0]) & set(pool._slot_pages[1])
    assert shared, "prefix adoption did not share pages"
    donor_rows = _slot_rows(pool, 0)
    adopter_before = _slot_rows(pool, 1)

    assert pool.spill_slot(0)
    pool.check_invariants()
    for pid in shared:
        assert pool._ref[pid] >= 1     # decremented, not freed
    for name in adopter_before:        # adopter bitwise untouched
        assert np.array_equal(adopter_before[name], _slot_rows(pool, 1)[name])

    _resume(pool, 0)
    pool.check_invariants()
    resumed = _slot_rows(pool, 0)
    for name in donor_rows:
        assert np.array_equal(donor_rows[name], resumed[name]), name
    # The resumed copies are private: CoW already happened via the spill.
    assert not set(pool._slot_pages[0]) & set(pool._slot_pages[1]) or all(
        pool._ref[p] == 1 for p in pool._slot_pages[0]
    )
    pool.release(0)
    pool.release(1)
    pool.check_invariants()


def test_can_admit_counts_both_tiers():
    def occupied(host_pages):
        pool = _tiered(n_pages=8, host_pages=host_pages)
        prompt = np.random.default_rng(1).integers(
            2, 200, size=16
        ).astype(np.int32)
        assert pool.admit(0, prompt, 16) is not None
        _grow(pool, 0, 16)                     # 4 of 8 device pages held
        return pool

    # Worst case 8 pages: overflows the device tier's 4 remaining pages,
    # fits when the host tier can absorb the overflow via spills...
    assert occupied(host_pages=16).can_admit(16, 16)
    # ...and stays inadmissible when it cannot.
    assert not occupied(host_pages=2).can_admit(16, 16)


# ---- cross-tier lifecycle random walk ----------------------------------------


@SETTINGS
@given(seed=st.integers(0, 2**16))
def test_cross_tier_lifecycle_random_walk(seed):
    """Random walk over admit / decode-step / spill / staged resume /
    release on an oversubscribed tiered pool. After every op the pool
    invariants hold, and the walk's own ledger must agree with both
    tiers: a live slot's logical length is conserved across suspend /
    resume, suspended slots hold exactly their page count in host rows,
    and a fully drained pool returns to all-free on both tiers with the
    prefetch accounting balanced."""
    rng = np.random.default_rng(seed)
    n_slots = 3
    pool = _tiered(n_pages=13, host_pages=10, n_slots=n_slots)
    live: dict[int, dict] = {}    # slot -> {len, total}

    def suspended(slot):
        return pool.is_suspended(slot)

    for _ in range(80):
        op = rng.integers(0, 6)
        free = [s for s in range(n_slots) if s not in live]
        active = [s for s in live if not suspended(s)]
        if op == 0 and free:
            slot = int(rng.choice(free))
            prompt_len = int(rng.integers(1, 20))
            prompt = rng.integers(2, 5, size=prompt_len).astype(np.int32)
            max_new = int(rng.integers(1, 12))
            if pool.admit(slot, prompt, max_new) is not None:
                live[slot] = {
                    "len": int(pool.lens[slot]),
                    "total": min(prompt_len + max_new, pool.capacity),
                }
        elif op == 1 and active:     # decode growth, spill on pressure
            slot = int(rng.choice(active))
            n = min(int(rng.integers(1, 5)),
                    live[slot]["total"] - live[slot]["len"])
            if n <= 0:
                continue
            try:
                pool.ensure_writable(slot, n)
            except PoolExhausted:
                victim = next(
                    (v for v in active if pool.can_spill(v)), None
                )
                if victim is not None:
                    assert pool.spill_slot(victim)
                else:                # tier can't absorb it: preempt
                    victim = active[0]
                    del live[victim]
                    pool.release(victim)
                pool.check_invariants()
                continue
            pool.advance(slot, n)
            live[slot]["len"] += n
        elif op == 2 and active:     # proactive spill (watermark path)
            slot = int(rng.choice(active))
            if pool.can_spill(slot):
                assert pool.spill_slot(slot)
        elif op == 3:                # fetch/resume progress
            sus = pool.suspended_slots()
            if not sus:
                continue
            slot = int(rng.choice(sus))
            if not pool._suspended[slot].started:
                n_pg = len(pool._suspended[slot].handles)
                pool.start_resume(
                    slot, order=list(rng.permutation(n_pg))[: n_pg // 2]
                )
            pool.issue_fetches(slot, int(rng.integers(1, 4)))
            if pool.resume_ready(slot):
                pool.complete_resume(slot)   # may refuse under pressure
        elif op == 4 and live:       # cancel/finish: release either state
            slot = int(rng.choice(list(live)))
            del live[slot]
            pool.release(slot)
        pool.check_invariants()
        for slot, ent in live.items():
            assert int(pool.lens[slot]) == ent["len"]  # conserved cross-tier
            if suspended(slot):
                assert len(pool._suspended[slot].handles) == \
                    pool._offslot_pages(slot)
            else:
                assert pool._offslot_pages(slot) == 0

    for slot in list(live):
        pool.release(slot)
    pool.check_invariants()
    assert pool.alloc.free_count == pool.alloc.n_pages - 1
    assert pool.alloc.reserved == 0
    assert pool.host.used == 0
    assert pool.fetches == pool.prefetch_hits + pool.prefetch_wasted


# ---- engine integration ------------------------------------------------------


def _engine(lm, params, **kw):
    return ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler="continuous",
        page_size=8, prefill_chunk=8, **kw,
    )


TIER_KW = dict(
    admission="optimistic", pool_pages=8, host_pages=24,
    prefetch_depth=4, max_preemptions=50,
)


def test_tiered_engine_bitwise_parity(deepseek_lm):
    """Device pool below the working set: the tiered engine must spill
    (not preempt), resume every slot, and stay bitwise identical to an
    unconstrained reference — through the same two compiled widths."""
    lm, params = deepseek_lm
    vocab = lm.cfg.vocab
    ref = _engine(lm, params).generate(_reqs(vocab, 4, plen=20, max_new=24))

    eng = _engine(lm, params, **TIER_KW)
    out = eng.generate(_reqs(vocab, 4, plen=20, max_new=24))
    st_ = eng.last_stats
    assert st_.spills >= 1
    assert st_.preemptions == 0
    pool = eng.last_pool
    pool.check_invariants()
    assert pool.fetches == pool.prefetch_hits + pool.prefetch_wasted
    assert pool.prefetch_hits >= 1
    for a, b in zip(ref, out):
        assert a.status == b.status == "ok"
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"
    assert eng.compiled_step_count() == 2
    # tier.* telemetry mirrors the pool's plain counters.
    assert eng.obs.value("tier.spills") == pool.spills
    assert eng.obs.value("tier.fetches") == pool.fetches


def test_spill_stall_falls_back_to_preemption(deepseek_lm):
    """A stalled host writer (``tier.spill`` fault) must degrade the
    pressure resolution to plain preemption — never wedge — and keep the
    stream bitwise intact."""
    lm, params = deepseek_lm
    vocab = lm.cfg.vocab
    ref = _engine(lm, params).generate(_reqs(vocab, 4, plen=20, max_new=24))
    eng = _engine(lm, params, faults=FaultPlan().spill_stall(0, times=100),
                  **TIER_KW)
    out = eng.generate(_reqs(vocab, 4, plen=20, max_new=24))
    st_ = eng.last_stats
    assert st_.spills == 0
    assert st_.preemptions >= 1
    for a, b in zip(ref, out):
        assert a.status == b.status == "ok"
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"


def test_fetch_fail_resumes_late_but_bitwise_intact(deepseek_lm):
    """Dropped host→device transfers (``tier.fetch`` fault) requeue the
    page — the resume lands late, the tokens land identical."""
    lm, params = deepseek_lm
    vocab = lm.cfg.vocab
    ref = _engine(lm, params).generate(_reqs(vocab, 4, plen=20, max_new=24))
    eng = _engine(lm, params, faults=FaultPlan().fetch_fail(0, times=3),
                  **TIER_KW)
    out = eng.generate(_reqs(vocab, 4, plen=20, max_new=24))
    pool = eng.last_pool
    assert eng.last_stats.spills >= 1
    assert pool.fetch_failures >= 1
    assert pool.fetches == pool.prefetch_hits + pool.prefetch_wasted
    for a, b in zip(ref, out):
        assert a.status == b.status == "ok"
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"
