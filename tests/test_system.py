"""End-to-end behaviour tests: train -> crash -> resume -> serve, watchdog,
straggler handling. These exercise the same code paths the launchers use."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, TrainConfig, get_config
from repro.data.pipeline import DataConfig, SyntheticPacked
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.serve import Request, ServeEngine
from repro.train.fault_tolerance import FailureInjector, StepTimeout, Watchdog
from repro.train.loop import run_training


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    mesh = make_local_mesh(1, 1)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=0)
    src = SyntheticPacked(dcfg)
    batches = {s: {"tokens": jnp.asarray(src.batch(s)["tokens"])} for s in range(40)}
    return cfg, lm, mesh, batches


def _tcfg(d, steps, **kw):
    base = dict(
        lr=2e-3, total_steps=steps, warmup_steps=2, checkpoint_every=5,
        checkpoint_dir=str(d), keep_checkpoints=2,
    )
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases_over_training(setup, tmp_path):
    cfg, lm, mesh, batches = setup
    fixed = batches[0]
    res = run_training(
        lm, _tcfg(tmp_path, 25), ParallelConfig(), mesh,
        make_batch=lambda s: fixed, log_every=0,
    )
    assert res.losses[-1] < res.losses[0] - 1.0


def test_crash_checkpoint_resume(setup, tmp_path):
    cfg, lm, mesh, batches = setup
    inj = FailureInjector(crash_at=(12,))
    res1 = run_training(
        lm, _tcfg(tmp_path, 20), ParallelConfig(), mesh,
        make_batch=lambda s: batches[s], injector=inj, log_every=0,
    )
    assert res1.interrupted and res1.final_step < 20
    # resume picks up from the last checkpoint and finishes
    res2 = run_training(
        lm, _tcfg(tmp_path, 20), ParallelConfig(), mesh,
        make_batch=lambda s: batches[s], log_every=0,
    )
    assert res2.resumed_from is not None and res2.resumed_from >= 9
    assert res2.final_step == 19 and not res2.interrupted


def test_straggler_watchdog_retries(setup, tmp_path):
    cfg, lm, mesh, batches = setup

    class SlowOnce:
        fired = False

        def maybe_fail(self, step):
            import time
            if step == 3 and not self.fired:
                self.fired = True
                time.sleep(1.2)

    res = run_training(
        lm, _tcfg(tmp_path, 6), ParallelConfig(), mesh,
        make_batch=lambda s: batches[s], injector=SlowOnce(),
        step_timeout_s=1.0, log_every=0,
    )
    assert res.final_step == 5  # retried step completed the run


def test_watchdog_unit():
    import time
    with pytest.raises(StepTimeout):
        with Watchdog(0.05):
            time.sleep(0.2)
    with Watchdog(5.0):
        pass  # no timeout


def test_microbatching_matches_full_batch(setup, tmp_path):
    cfg, lm, mesh, batches = setup
    from repro.train.step import make_train_state, make_train_step

    tcfg = TrainConfig(lr=1e-3, warmup_steps=0)
    batch = batches[0]
    losses = {}
    for micro in (1, 4):
        pcfg = ParallelConfig(microbatches=micro)
        with jax.set_mesh(mesh):
            state = make_train_state(lm, tcfg, jax.random.PRNGKey(0))
            _, compile_step = make_train_step(lm, tcfg, pcfg, mesh)
            compiled = compile_step(state, batch)
            state, m = compiled(state, batch)
            state, m = compiled(state, batch)
            losses[micro] = float(m["loss"])
    assert abs(losses[1] - losses[4]) < 5e-3, losses


def test_train_then_serve(setup, tmp_path):
    cfg, lm, mesh, batches = setup
    fixed = batches[0]
    res = run_training(
        lm, _tcfg(tmp_path, 15), ParallelConfig(), mesh,
        make_batch=lambda s: fixed, log_every=0,
    )
    from repro.train.checkpoint import restore_pytree
    params0 = lm.init(jax.random.PRNGKey(0))
    state, _ = restore_pytree({"params": params0}, str(tmp_path))
    eng = ServeEngine(lm, state["params"], batch_size=2, max_len=128)
    prompt = np.asarray(fixed["tokens"][0, :8], np.int32)
    out = eng.generate([Request(tokens=prompt, max_new_tokens=8)])
    assert out[0].steps >= 1
    assert np.isfinite(out[0].tokens).all()
