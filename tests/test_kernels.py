"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode_fwd
from repro.kernels.ref import decode_attention_ref, flash_attention_ref
from repro.kernels.traffic import FlashGridSpec, pipeline_traffic


def _mk(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


from helpers import ALL_ORDERS as ORDERS, order_kwargs as _okw

SWEEP = [
    # b, sq, skv, hq, hkv, d, causal, window, qb, kb
    (1, 128, 128, 2, 2, 64, False, None, 128, 128),
    (2, 256, 256, 4, 4, 64, True, None, 128, 128),
    (1, 256, 256, 8, 2, 64, True, None, 128, 128),        # GQA
    (1, 512, 512, 4, 1, 128, True, 192, 128, 128),        # MQA + SWA
    (2, 128, 384, 4, 4, 80, False, None, 128, 128),       # cross, odd head dim
    (1, 384, 384, 2, 2, 64, True, None, 256, 128),        # rectangular blocks
    (1, 200, 200, 2, 2, 64, True, None, 128, 128),        # non-multiple seq
]


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("order", ORDERS)
def test_flash_kernel_sweep(case, order):
    b, sq, skv, hq, hkv, d, causal, window, qb, kb = case
    q, k, v = _mk((b, sq, hq, d), 1), _mk((b, skv, hkv, d), 2), _mk((b, skv, hkv, d), 3)
    out = flash_attention_fwd(
        q, k, v, order=order, causal=causal, window=window,
        q_block=qb, kv_block=kb, interpret=True, **_okw(order),
    )
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5), (jnp.bfloat16, 3e-2)])
def test_flash_kernel_dtypes(dtype, tol):
    q = _mk((1, 256, 4, 64), 1, dtype)
    k = _mk((1, 256, 2, 64), 2, dtype)
    v = _mk((1, 256, 2, 64), 3, dtype)
    out = flash_attention_fwd(q, k, v, order="sawtooth", causal=True,
                              q_block=128, kv_block=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("order", ORDERS)
def test_decode_kernel(order):
    q = _mk((3, 1, 8, 64), 1)
    kc, vc = _mk((3, 640, 2, 64), 2), _mk((3, 640, 2, 64), 3)
    lens = jnp.array([640, 500, 129])
    out = flash_decode_fwd(q, kc, vc, lens, order=order, chunk=128, interpret=True,
                           **_okw(order))
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_decode_kernel_window_and_bf16():
    q = _mk((2, 1, 4, 64), 1, jnp.bfloat16)
    kc, vc = _mk((2, 512, 4, 64), 2, jnp.bfloat16), _mk((2, 512, 4, 64), 3, jnp.bfloat16)
    out = flash_decode_fwd(q, kc, vc, 512, window=128, chunk=128, interpret=True)
    ref = decode_attention_ref(q, kc, vc, 512, window=128)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_ops_custom_vjp_grad_matches_reference():
    q, k, v = _mk((1, 128, 4, 32), 1), _mk((1, 128, 2, 32), 2), _mk((1, 128, 2, 32), 3)

    def lp(q, k, v):
        return (ops.attention(q, k, v, causal=True, impl="pallas_interpret",
                              q_block=64, kv_block=64) ** 2).sum()

    def lr(q, k, v):
        return (ops.attention(q, k, v, causal=True, impl="reference") ** 2).sum()

    gp = jax.grad(lp, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_traffic_sawtooth_elides_boundary_fetches():
    spec = FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=256, kv_block=256)
    cyc = pipeline_traffic(spec, "cyclic")
    saw = pipeline_traffic(spec, "sawtooth")
    # one elided KV fetch per Q-tile boundary
    assert saw.elided_kv_fetches == spec.nq - 1
    assert cyc.elided_kv_fetches == 0
    assert saw.kv_bytes < cyc.kv_bytes
    # causal: clamped out-of-range steps are elided in both orders
    spec_c = FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=256, kv_block=256, causal=True)
    cyc_c = pipeline_traffic(spec_c, "cyclic")
    saw_c = pipeline_traffic(spec_c, "sawtooth")
    assert saw_c.kv_bytes <= cyc_c.kv_bytes


def test_traffic_window_clamps_range():
    spec = FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=256, kv_block=256,
                         causal=True, window=1024)
    full = FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=256, kv_block=256, causal=True)
    assert pipeline_traffic(spec, "cyclic").kv_bytes < pipeline_traffic(full, "cyclic").kv_bytes
