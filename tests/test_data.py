import numpy as np

from repro.data.pipeline import EOS, DataConfig, SyntheticPacked, make_batch_iterator


def test_deterministic_by_step():
    cfg = DataConfig(vocab=1000, seq_len=128, global_batch=4, seed=3)
    src = SyntheticPacked(cfg)
    a = src.batch(5)["tokens"]
    b = src.batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = src.batch(6)["tokens"]
    assert not np.array_equal(a, c)


def test_host_shards_disjoint():
    base = dict(vocab=1000, seq_len=64, global_batch=8, seed=1, host_count=2)
    h0 = SyntheticPacked(DataConfig(host_index=0, **base)).batch(0)["tokens"]
    h1 = SyntheticPacked(DataConfig(host_index=1, **base)).batch(0)["tokens"]
    assert h0.shape == (4, 64) and h1.shape == (4, 64)
    assert not np.array_equal(h0, h1)


def test_tokens_in_range_and_packed():
    cfg = DataConfig(vocab=50, seq_len=256, global_batch=2, seed=0, mean_doc_len=16)
    t = SyntheticPacked(cfg).batch(0)["tokens"]
    assert t.min() >= 1 and t.max() < 50
    assert (t == EOS).any()  # packing separators present


def test_prefetch_iterator_resumes():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=2, seed=0)
    it = make_batch_iterator(cfg, start_step=3, prefetch=2)
    first = next(it)
    it.close()
    direct = SyntheticPacked(cfg).batch(3)
    np.testing.assert_array_equal(first["tokens"], direct["tokens"])
