"""Deterministic fault injection (repro.serve.faults) through the engine.

FaultPlan unit semantics first — step-addressed arming, ``times``
consumption, cancel targeting, seeded-random reproducibility — then the
engine integration the hooks exist for: an injected ``PoolExhausted`` must
take the same preemption path a genuinely starved pool does, a transient
device-step failure must be retried once and leave the token stream
bitwise-untouched, a persistent one must fail the step's rows *typed* and
keep serving, and a seeded chaos plan must resolve every request with a
typed status while the pool invariants hold (the engine asserts them after
every step in which a fault fired).
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    FAULT_SITES,
    Fault,
    FaultPlan,
    PagePool,
    PoolExhausted,
    Request,
    ServeEngine,
    StepFault,
)


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _reqs(vocab, n, *, plen=24, max_new=8):
    rng = np.random.default_rng(5)
    return [
        Request(
            tokens=rng.integers(2, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new,
            rid=i,
        )
        for i in range(n)
    ]


def _engine(lm, params, **kw):
    return ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler="continuous",
        page_size=16, prefill_chunk=16, **kw,
    )


# ---- FaultPlan unit semantics ------------------------------------------------


def test_fault_site_validation():
    with pytest.raises(ValueError):
        Fault("pool.everything", 0)
    assert set(FAULT_SITES) == {
        "pool.alloc",
        "pool.admit",
        "device.step",
        "cancel",
        "tier.spill",
        "tier.fetch",
    }


def test_plan_arms_by_step_and_consumes_times():
    plan = FaultPlan().exhaust_pool(2, times=2).refuse_admission(0)
    assert not plan.take("pool.alloc")  # begin_step never called: nothing arms
    plan.begin_step(0)
    assert plan.take("pool.admit")      # due at step 0
    assert not plan.take("pool.admit")  # times exhausted
    assert not plan.take("pool.alloc")  # not armed until step 2
    plan.begin_step(1)
    assert plan.fired_this_step == 0    # reset each boundary
    plan.begin_step(3)                  # past the scheduled step still fires
    assert plan.take("pool.alloc") and plan.take("pool.alloc")
    assert not plan.take("pool.alloc")
    assert plan.exhausted
    assert [f["site"] for f in plan.fired] == [
        "pool.admit", "pool.alloc", "pool.alloc"
    ]
    assert plan.fired_this_step == 2


def test_take_cancels_and_raise_if():
    plan = FaultPlan().cancel(1, rid=7).cancel(1, rid=9).fail_device_step(1)
    plan.begin_step(0)
    assert plan.take_cancels() == []
    plan.begin_step(1)
    assert plan.take_cancels() == [7, 9]
    assert plan.take_cancels() == []    # consumed
    with pytest.raises(StepFault):
        plan.raise_if("device.step")
    plan.raise_if("device.step")        # exhausted: no-op
    assert plan.exhausted


def test_injected_alloc_failure_raises_pool_exhausted():
    plan = FaultPlan().exhaust_pool(0)
    plan.begin_step(0)
    pool = PagePool(8, faults=plan)
    with pytest.raises(PoolExhausted):
        pool.alloc(1)                   # injected: pool is NOT actually full
    assert pool.alloc(1)                # consumed: real allocation proceeds


def test_random_plan_is_seed_deterministic():
    mk = lambda s: FaultPlan.random(s, n_steps=12, rids=(0, 1, 2))
    a, b, c = mk(3), mk(3), mk(4)
    key = lambda p: [(f.site, f.step, f.rid) for f in p.faults]
    assert key(a) == key(b)
    assert key(a) != key(c)
    assert len(a.faults) == 3           # one exhaust + one step-fail + one cancel


# ---- engine integration ------------------------------------------------------


def test_five_resilience_series_exist_at_zero(deepseek_lm):
    lm, params = deepseek_lm
    eng = _engine(lm, params)
    for name in ("serve.preemptions", "serve.restore_tokens", "serve.shed",
                 "serve.deadline_miss", "serve.cancelled"):
        assert eng.obs.value(name) == 0
    assert eng.obs.find("serve.admission_paused") is not None


def test_injected_exhaustion_preempts_with_parity(deepseek_lm):
    """An injected PoolExhausted on a pool with plenty of pages drives the
    exact preemption/restore path real starvation does — observable in the
    metrics, invisible in the greedy tokens."""
    lm, params = deepseek_lm
    ref = _engine(lm, params)
    res_ref = ref.generate(_reqs(lm.cfg.vocab, 2, max_new=12))
    plan = FaultPlan().exhaust_pool(3)
    eng = _engine(
        lm, params, admission="optimistic", max_preemptions=5, faults=plan
    )
    res = eng.generate(_reqs(lm.cfg.vocab, 2, max_new=12))
    assert plan.exhausted
    assert eng.last_stats.preemptions >= 1
    assert eng.obs.value("serve.preemptions") == eng.last_stats.preemptions
    assert eng.obs.value("serve.restore_tokens") > 0
    for a, b in zip(res_ref, res):
        assert b.status == "ok"
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert eng.compiled_step_count() == 2


def test_injected_admission_refusal_requeues(deepseek_lm):
    lm, params = deepseek_lm
    plan = FaultPlan().refuse_admission(0)
    eng = _engine(lm, params, faults=plan)
    res = eng.generate(_reqs(lm.cfg.vocab, 2))
    assert plan.exhausted
    assert all(r.status == "ok" for r in res)  # refused once, admitted later
    assert eng.obs.value("serve.requests", event="requeued") >= 1


def test_transient_step_failure_retried_once(deepseek_lm):
    lm, params = deepseek_lm
    ref = _engine(lm, params)
    res_ref = ref.generate(_reqs(lm.cfg.vocab, 2))
    plan = FaultPlan().fail_device_step(2)
    eng = _engine(lm, params, faults=plan)
    res = eng.generate(_reqs(lm.cfg.vocab, 2))
    assert plan.exhausted
    assert eng.obs.value("serve.step_retries") == 1
    assert all(r.status == "ok" for r in res)
    for a, b in zip(res_ref, res):  # the retry re-ran identical computation
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_persistent_step_failure_fails_rows_typed(deepseek_lm):
    """Two consecutive dispatch failures fail the step's planned rows with
    status="failed" — and the engine keeps serving the queue."""
    lm, params = deepseek_lm
    plan = FaultPlan().fail_device_step(2, times=2)
    eng = _engine(lm, params, faults=plan)
    res = eng.generate(_reqs(lm.cfg.vocab, 3))
    assert plan.exhausted
    assert eng.obs.value("serve.step_retries") == 1
    by = {r.rid: r.status for r in res}
    assert set(by.values()) == {"failed", "ok"}
    # Both active rows at the failing step die; the queued third request
    # is admitted afterwards and completes.
    assert [by[0], by[1], by[2]] == ["failed", "failed", "ok"]
    assert eng.last_stats.failed == 2
    eng.last_pool.check_invariants()


def test_seeded_chaos_run_all_typed(deepseek_lm):
    """FaultPlan.random: pool exhaustion + device failure + cancel, all from
    one seed. Every request resolves typed, the pool invariants hold (the
    engine checks them after every fault-firing step), and reruns of the
    same seed produce the identical fired schedule."""
    lm, params = deepseek_lm

    def run(seed):
        plan = FaultPlan.random(seed, n_steps=10, rids=(0, 1, 2, 3))
        eng = _engine(
            lm, params, admission="optimistic", max_preemptions=5, faults=plan
        )
        res = eng.generate(_reqs(lm.cfg.vocab, 4, max_new=12))
        assert all(
            r.status in ("ok", "cancelled", "failed") for r in res
        ), [r.status for r in res]
        eng.last_pool.check_invariants()
        return [(f["site"], f["step"], f["rid"]) for f in plan.fired]

    assert run(11) == run(11)
