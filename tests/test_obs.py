"""repro.obs: metrics registry, span tracing, sinks, and the modeled-LLC
sampler — plus the serve engine's use of all of them.

* registry semantics: get-or-create handles, label-rendered series,
  histogram bucket placement / cumulative snapshot / NaN exclusion;
* tracer: span nesting by timestamp containment, exception-safe close,
  ring-buffer cap, Chrome-trace JSON schema validity (strict JSON);
* export: schema_version-stamped JSONL roundtrip, append_jsonl stamping;
* LLC sampler: ``llc.modeled_miss_bytes{order=...}`` gauge parity with a
  direct ``fwd_llc_model`` call at the same footprint, via the public
  ``fwd_spec_for``;
* engine integration: serve-stream metrics conservation (sum of per-step
  token counters == total tokens generated), NaN TPOT for single-token
  generations, the StepStats deprecation shim, and live llc gauges for
  >= 2 traversal orders.
"""

import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.traffic import fwd_llc_model
from repro.models import build_model
from repro.obs import (
    LLCSampler,
    Registry,
    Tracer,
    append_jsonl,
    load_jsonl,
    metric_records,
    write_metrics_jsonl,
)
from repro.obs.export import SCHEMA_VERSION
from repro.obs.metrics import render_series
from repro.serve import Request, ServeEngine, StepStats


# ---- registry ----------------------------------------------------------------


def test_render_series_sorts_labels():
    assert render_series("x", {}) == "x"
    assert render_series("x", {"b": 2, "a": 1}) == "x{a=1,b=2}"


def test_counter_get_or_create_and_labels():
    reg = Registry()
    c1 = reg.counter("serve.step.tokens", kind="decode")
    c2 = reg.counter("serve.step.tokens", kind="prefill")
    assert c1 is reg.counter("serve.step.tokens", kind="decode")
    assert c1 is not c2
    c1.inc()
    c1.inc(3)
    assert reg.value("serve.step.tokens", kind="decode") == 4
    assert reg.value("serve.step.tokens", kind="prefill") == 0
    assert reg.value("no.such.series", default=-1) == -1
    with pytest.raises(ValueError):
        c1.inc(-1)


def test_kind_conflict_rejected():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_histogram_bucket_semantics():
    reg = Registry()
    h = reg.histogram("lat", buckets=(0.1, 0.2, 0.5))
    for v in (0.05, 0.1, 0.2, 0.3, 9.0):  # bounds are inclusive upper edges
        h.observe(v)
    assert h.counts == [2, 1, 1, 1]  # [<=0.1]=2 (0.05, 0.1), overflow=1
    assert h.count == 5
    assert h.sum == pytest.approx(9.65)
    snap = reg.snapshot()["histograms"]["lat"]
    assert snap["buckets"] == [[0.1, 2], [0.2, 3], [0.5, 4], ["+Inf", 5]]
    # Cumulative counts are monotone and end at count.
    cums = [c for _, c in snap["buckets"]]
    assert cums == sorted(cums) and cums[-1] == snap["count"]


def test_histogram_nan_dropped():
    reg = Registry()
    h = reg.histogram("tpot")
    h.observe(0.01)
    h.observe(math.nan)
    assert h.count == 1 and h.nan_count == 1
    assert h.sum == pytest.approx(0.01)
    assert not math.isnan(h.quantile(0.5))


def test_histogram_quantile_and_conflicting_buckets():
    reg = Registry()
    h = reg.histogram("q", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 0.5, 1.5, 4.0):
        h.observe(v)
    assert h.quantile(0.5) == 1.0
    assert h.quantile(1.0) == 5.0
    assert math.isnan(reg.histogram("empty").quantile(0.9))
    with pytest.raises(ValueError):
        reg.histogram("q", buckets=(1.0, 2.0))


def test_snapshot_is_strict_json():
    reg = Registry()
    reg.counter("c", a="1").inc()
    reg.gauge("g").set(2.5)
    reg.histogram("h").observe(1e9)  # lands in the +Inf overflow bucket
    # Strict JSON (no Infinity/NaN literals) must accept the snapshot.
    json.loads(json.dumps(reg.snapshot(), allow_nan=False))


# ---- tracer ------------------------------------------------------------------


def test_span_nesting_by_containment():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    inner, outer = tr.events()  # inner closes (appends) first
    assert (inner.name, outer.name) == ("inner", "outer")
    assert outer.ts_ns <= inner.ts_ns
    assert inner.end_ns <= outer.end_ns


def test_span_closes_on_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("step crashed")
    (ev,) = tr.events()
    assert ev.name == "boom" and ev.dur_ns >= 0


def test_ring_buffer_caps_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("e", i=i)
    evs = tr.events()
    assert len(evs) == 4
    assert tr.dropped == 6
    assert [e.args["i"] for e in evs] == [6, 7, 8, 9]  # most recent kept


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("serve.step", step=0):
        tr.instant("serve.compile", width=4)
    path = tmp_path / "trace.json"
    tr.write(str(path))
    trace = json.loads(path.read_text())
    events = trace["traceEvents"]
    assert len(events) == 2
    for ev in events:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(ev)
        assert isinstance(ev["ts"], float)
    ts = [e["ts"] for e in events]
    assert ts == sorted(ts)
    by_ph = {e["ph"]: e for e in events}
    assert by_ph["X"]["dur"] >= 0
    assert by_ph["i"]["s"] == "t"
    assert by_ph["i"]["args"] == {"width": 4}


# ---- export sinks ------------------------------------------------------------


def test_metrics_jsonl_roundtrip(tmp_path):
    reg = Registry()
    reg.counter("serve.steps", width="wide").inc(3)
    reg.gauge("pool.occupancy_frac").set(0.5)
    reg.histogram("serve.ttft_s").observe(0.02)
    path = tmp_path / "metrics.jsonl"
    n = write_metrics_jsonl(reg, str(path), extra={"arch": "t"})
    recs = load_jsonl(str(path))
    assert n == len(recs) == 3
    by_series = {r["series"]: r for r in recs}
    assert set(by_series) == {
        "serve.steps{width=wide}", "pool.occupancy_frac", "serve.ttft_s",
    }
    for r in recs:
        assert r["schema_version"] == SCHEMA_VERSION
        assert r["arch"] == "t"
        assert r["labels"] == ({"width": "wide"} if "{" in r["series"] else {})
    assert by_series["serve.steps{width=wide}"]["value"] == 3
    hist = by_series["serve.ttft_s"]
    assert hist["count"] == 1 and hist["buckets"][-1] == ["+Inf", 1]
    # The records iterator stamps a shared ts.
    (r1, r2, r3) = metric_records(reg, ts=123.0)
    assert r1["ts"] == r2["ts"] == r3["ts"] == 123.0


def test_append_jsonl_stamps(tmp_path):
    path = tmp_path / "sub" / "cache.jsonl"  # parent dir auto-created
    append_jsonl(str(path), {"key": {"arch": "a"}, "winner": 1}, kind="order_sweep")
    append_jsonl(str(path), {"key": {"arch": "b"}, "winner": 2}, kind="order_sweep")
    recs = load_jsonl(str(path))
    assert [r["winner"] for r in recs] == [1, 2]
    for r in recs:
        assert r["schema_version"] == SCHEMA_VERSION
        assert r["kind"] == "order_sweep"
        assert r["ts"] > 0


# ---- LLC sampler -------------------------------------------------------------


class FakePool:
    """The three pool attributes the sampler's footprint probe reads."""

    def __init__(self, lens, slot_pages, refs):
        self.lens = lens
        self._slot_pages = slot_pages
        self._ref = refs


def _sampler(reg, **kw):
    kw.setdefault("page", 16)
    kw.setdefault("n_heads", 8)
    kw.setdefault("n_kv_heads", 2)
    kw.setdefault("head_dim", 32)
    kw.setdefault("elem_bytes", 2)
    kw.setdefault("current_order", "sawtooth")
    kw.setdefault("every", 1)
    return LLCSampler(reg, **kw)


def test_llc_gauge_parity_with_direct_model_call():
    reg = Registry()
    s = _sampler(reg)
    refs = np.ones(16, np.int64)
    pool = FakePool([70, 33, 0], [[1, 2, 3, 4, 5], [6, 7, 8], []], refs)
    assert s.sample(pool)
    assert s.orders[0] == "sawtooth" and len(s.orders) >= 2
    spec = s.fwd_spec_for(70)  # longest live row, page-rounded inside
    assert spec.seq_kv == 80  # 70 tokens -> 5 pages of 16
    for order in s.orders:
        direct = fwd_llc_model(
            spec, order, n_workers=s.n_workers, capacity_bytes=s.capacity_bytes
        )
        gauge = reg.value("llc.modeled_miss_bytes", order=order, model="fwd")
        assert gauge == direct.misses
    assert reg.value("llc.footprint_bytes") == pytest.approx(
        2 * 8 * 16 * 2 * 32 * 2  # K+V * 8 distinct pages * page * hkv * d * bytes
    )
    assert reg.value("llc.active_rows") == 2
    assert reg.value("llc.samples") == 1
    best = int(reg.value("llc.best_order_index"))
    misses = [
        reg.value("llc.modeled_miss_bytes", order=o, model="fwd") for o in s.orders
    ]
    assert misses[best] == min(misses)


def test_llc_sampler_gating_and_empty_pool():
    reg = Registry()
    s = _sampler(reg, every=4)
    pool = FakePool([32], [[1, 2]], np.ones(4, np.int64))
    assert not s.maybe_sample(3, pool)  # off-period
    assert s.maybe_sample(4, pool)
    assert not _sampler(reg, every=0).maybe_sample(0, pool)  # disabled
    assert not s.sample(FakePool([0], [[]], np.ones(1)))  # nothing resident
    s2 = _sampler(Registry(), current_order="cyclic")
    assert s2.orders[0] == "cyclic" and "sawtooth" in s2.orders


def test_llc_shared_prefix_gauges_emitted_when_pages_shared():
    reg = Registry()
    s = _sampler(reg)
    refs = np.ones(16, np.int64)
    refs[1] = refs[2] = 3  # pages 1, 2 shared by all three rows
    pool = FakePool(
        [40, 40, 40], [[1, 2, 3], [1, 2, 4], [1, 2, 5]], refs
    )
    assert s.sample(pool)
    for order in s.orders:
        assert reg.find(
            "llc.modeled_miss_bytes", order=order, model="shared_prefix"
        ) is not None
    assert reg.value("llc.shared_pages") == 2
    # The history entry carries the shared-model readings + live shared
    # fraction (the adaptation controller's blend inputs): 2 of 5 distinct
    # resident pages are shared here.
    entry = s.history[-1]
    assert set(entry["shared_miss"]) == set(s.orders)
    assert entry["shared_frac"] == pytest.approx(2 / 5)


# ---- engine integration ------------------------------------------------------


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _requests(vocab, lens_and_maxnew):
    rng = np.random.default_rng(7)
    return [
        Request(
            tokens=rng.integers(2, vocab, size=n).astype(np.int32),
            max_new_tokens=m,
            rid=i,
        )
        for i, (n, m) in enumerate(lens_and_maxnew)
    ]


def test_serve_stream_metrics_conservation(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=3, max_len=96, scheduler="continuous",
        page_size=16, llc_every=2,
    )
    spec = [(5, 4), (19, 6), (33, 3), (9, 1), (12, 5)]
    reqs = _requests(lm.cfg.vocab, spec)
    results = eng.generate(reqs)
    v = eng.obs.value

    # Conservation: every generated token was produced by exactly one step.
    total = sum(r.steps for r in results)
    assert v("serve.tokens.generated") == total
    # First token of each request comes from its last prefill chunk; the
    # rest are decode-step tokens.
    assert v("serve.step.tokens", kind="decode") == sum(
        max(r.steps - 1, 0) for r in results
    )
    # Every prompt token was either prefilled through the mixed step or
    # adopted from a registered shared prefix.
    assert v("serve.step.tokens", kind="prefill") + v("pool.tokens_adopted") == sum(
        n for n, _ in spec
    )
    assert v("serve.requests", event="finished") == len(spec)
    # One TTFT sample per request; NaN TPOTs (single-token generations) are
    # excluded from the histogram but tallied.
    ttft = eng.obs.find("serve.ttft_s")
    tpot = eng.obs.find("serve.tpot_s")
    assert ttft.count == len(spec)
    n_single = sum(1 for r in results if r.steps <= 1)
    assert tpot.nan_count == n_single
    assert tpot.count == len(spec) - n_single
    # Step counters match the engine's own deterministic tallies.
    st = eng.last_stats
    assert v("serve.steps", width="wide") == st.wide_steps
    assert (
        v("serve.steps", width="wide") + v("serve.steps", width="narrow")
        == st.mixed_steps
    )
    # llc sampler ran and emitted modeled misses for >= 2 traversal orders.
    assert v("llc.samples") >= 1
    orders = {
        m.labels["order"]
        for m in eng.obs.series()
        if m.name == "llc.modeled_miss_bytes" and m.labels.get("model") == "fwd"
    }
    assert len(orders) >= 2
    # Pool gauges exist from init (step-0 dashboards aren't blank).
    assert eng.obs.find("pool.occupancy_frac") is not None
    # Trace captured the step hierarchy.
    names = {e.name for e in eng.tracer.events()}
    assert {"serve.step", "serve.plan_step", "serve.device_step"} <= names


def test_tpot_nan_for_single_token_generation(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler="continuous",
        page_size=16,
    )
    reqs = _requests(lm.cfg.vocab, [(6, 1), (6, 4)])
    one, several = eng.generate(reqs)
    assert one.steps == 1 and math.isnan(one.tpot_s)
    if several.steps > 1:
        assert not math.isnan(several.tpot_s)


def test_step_stats_shim_warns(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler="continuous",
        page_size=16,
    )
    eng.generate(_requests(lm.cfg.vocab, [(6, 3), (8, 2)]))
    st = eng.last_stats
    assert isinstance(st, StepStats)
    assert st.mixed_steps > 0
    with pytest.warns(DeprecationWarning):
        assert st["mixed_steps"] == st.mixed_steps
    assert set(st.keys()) == set(st.as_dict()) == set(iter(st))
    assert st.get("wide_steps") == st.wide_steps
    assert st.get("nope", -1) == -1


def test_static_path_records_latency_metrics(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(lm, params, batch_size=2, max_len=64, scheduler="static")
    reqs = _requests(lm.cfg.vocab, [(6, 3), (8, 4)])
    results = eng.generate(reqs)
    v = eng.obs.value
    assert v("serve.tokens.generated") == sum(r.steps for r in results)
    assert eng.obs.find("serve.ttft_s").count == len(reqs)
    assert v("serve.step.tokens", kind="prefill") > 0
    names = {e.name for e in eng.tracer.events()}
    assert "serve.prefill" in names
