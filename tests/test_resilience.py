"""Serve-engine resilience layer (DESIGN.md §12).

Typed request lifecycle end to end — deadlines, host cancellation, bounded-
queue load shedding, pool-pressure preemption with chunked re-prefill
restore — plus the pool-level pieces it stands on: the typed error
hierarchy, idempotent release, the victim-selection policy, and a
hypothesis random walk over the full slot lifecycle (admit / decode-step /
cancel-release / preempt / restore / expire) holding the pool invariants.

The one non-negotiable: preemption must be *invisible* in the output.
A greedy stream served through an oversubscribed optimistic pool — where
requests are evicted mid-decode and re-prefilled from scratch — must
produce bitwise the tokens of an uncontended reserve engine, through the
same two compiled step widths.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (
    REQUEST_STATUSES,
    AdmissionError,
    FaultPlan,
    GenerationResult,
    PagedKVPool,
    PagePool,
    PoolError,
    PoolExhausted,
    Request,
    ServeEngine,
    select_victim,
)

SETTINGS = settings(max_examples=15, deadline=None)


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


def _reqs(vocab, n, *, plen=24, max_new=8, **kw):
    rng = np.random.default_rng(11)
    return [
        Request(
            tokens=rng.integers(2, vocab, size=plen).astype(np.int32),
            max_new_tokens=max_new,
            rid=i,
            **{k: (v(i) if callable(v) else v) for k, v in kw.items()},
        )
        for i in range(n)
    ]


# ---- typed statuses / errors -------------------------------------------------


def test_result_status_defaults():
    r = GenerationResult(rid=0, tokens=np.zeros(0, np.int32), steps=0)
    assert r.status == "ok" and r.n_preemptions == 0
    assert r.status in REQUEST_STATUSES
    assert set(REQUEST_STATUSES) == {"ok", "deadline", "cancelled", "shed", "failed"}


def test_typed_error_hierarchy():
    # Legacy bases preserved: pre-PR-8 callers caught RuntimeError for
    # exhaustion and ValueError for admission misuse.
    assert issubclass(PoolExhausted, PoolError)
    assert issubclass(PoolExhausted, RuntimeError)
    assert issubclass(AdmissionError, PoolError)
    assert issubclass(AdmissionError, ValueError)
    pool = PagePool(4)
    with pytest.raises(PoolExhausted):
        pool.alloc(4)  # only 3 allocatable (page 0 is the dummy)
    with pytest.raises(AdmissionError):
        PagePool(1)


def test_pool_admission_errors():
    cfg = get_config("deepseek-7b").reduced().with_(kv_layout="paged", page_size=4)
    with pytest.raises(AdmissionError):
        PagedKVPool(cfg, 1, 2, max_len=32, admission="bogus")
    with pytest.raises(AdmissionError):
        PagedKVPool(cfg, 1, 2, max_len=32, n_pages=3)  # < one capacity row
    pool = PagedKVPool(cfg, 1, 2, max_len=32)
    assert pool.admit(0, np.arange(2, 8, dtype=np.int32), 4) is not None
    with pytest.raises(AdmissionError):
        pool.admit(0, np.arange(2, 8, dtype=np.int32), 4)  # slot occupied


def test_release_is_idempotent():
    cfg = get_config("deepseek-7b").reduced().with_(kv_layout="paged", page_size=4)
    pool = PagedKVPool(cfg, 1, 2, max_len=32)
    pool.release(1)  # never-admitted slot: no-op
    pool.admit(0, np.arange(2, 12, dtype=np.int32), 6)
    pool.ensure_writable(0, 9)
    pool.advance(0, 9)
    pool.release(0)
    pool.release(0)  # double-release must not double-free / go negative
    pool.check_invariants()
    assert pool.alloc.free_count == pool.alloc.n_pages - 1
    assert pool.alloc.reserved == 0


# ---- victim selection --------------------------------------------------------


def test_select_victim_policy():
    # (slot, priority, n_generated, shared_donor)
    assert select_victim([(0, 1, 0, False), (1, 0, 9, True)]) == 1   # priority first
    assert select_victim([(0, 0, 3, True), (1, 0, 9, False)]) == 1   # non-donor next
    assert select_victim([(0, 0, 5, False), (1, 0, 2, False)]) == 1  # fewest generated
    assert select_victim([(2, 0, 4, False), (1, 0, 4, False)]) == 1  # slot tiebreak


# ---- deadlines / cancellation / shedding ------------------------------------


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_deadline_expired_resolves_typed(deepseek_lm, scheduler):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler=scheduler, page_size=16
    )
    vocab = lm.cfg.vocab
    res = eng.generate(
        _reqs(vocab, 2, deadline_s=lambda i: 0.0 if i == 0 else None)
    )
    assert res[0].status == "deadline"
    assert res[0].steps < 8  # retired early, partial tokens only
    assert res[1].status == "ok" and res[1].steps == 8
    assert eng.obs.value("serve.deadline_miss") == 1


@pytest.mark.parametrize("scheduler", ["static", "continuous"])
def test_cancel_before_start(deepseek_lm, scheduler):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler=scheduler, page_size=16
    )
    eng.cancel(1)
    res = eng.generate(_reqs(lm.cfg.vocab, 3))
    assert [r.status for r in res] == ["ok", "cancelled", "ok"]
    assert res[1].steps == 0 and len(res[1].tokens) == 0
    assert eng.obs.value("serve.cancelled") == 1
    # The cancel set is consumed: a fresh stream serves rid 1 normally.
    res2 = eng.generate(_reqs(lm.cfg.vocab, 3))
    assert all(r.status == "ok" for r in res2)


def test_load_shed_over_bounded_queue(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=64, scheduler="continuous",
        page_size=16, max_queue=1,
    )
    res = eng.generate(_reqs(lm.cfg.vocab, 6))
    by = {s: [r.rid for r in res if r.status == s] for s in REQUEST_STATUSES}
    # 2 slots admit, 1 queues; the 3 newest arrived are shed.
    assert by["shed"] == [3, 4, 5]
    assert by["ok"] == [0, 1, 2]
    assert all(len(res[i].tokens) == 0 for i in by["shed"])
    assert eng.obs.value("serve.shed") == 3
    assert eng.last_stats.shed == 3


# ---- preemption / restore ----------------------------------------------------

# Oversubscription geometry shared by the preemption tests: page 16,
# max_len 64 (4-page rows), 24-token prompts growing by 24 -> 3 pages
# worst case per request, but only 4 allocatable pages for 2 slots.
_GEO = dict(batch_size=2, max_len=64, scheduler="continuous", page_size=16,
            prefill_chunk=16, pool_pages=4)


def test_preempt_restore_greedy_bitwise_parity(deepseek_lm):
    lm, params = deepseek_lm
    vocab = lm.cfg.vocab
    ref = ServeEngine(lm, params, **{**_GEO, "pool_pages": None})
    res_ref = ref.generate(_reqs(vocab, 3, max_new=24))
    eng = ServeEngine(
        lm, params, **_GEO, admission="optimistic", max_preemptions=10
    )
    res = eng.generate(_reqs(vocab, 3, max_new=24))
    st = eng.last_stats
    assert st.preemptions >= 1 and st.restore_tokens > 0
    assert sum(r.n_preemptions for r in res) == st.preemptions
    for a, b in zip(res_ref, res):
        assert b.status == "ok"
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # Restores re-prefill through the same two compiled step widths.
    assert eng.compiled_step_count() == 2
    assert eng.obs.value("serve.preemptions") == st.preemptions
    assert eng.obs.value("serve.restore_tokens") == st.restore_tokens
    eng.last_pool.check_invariants()


def test_max_preemptions_zero_fails_typed(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, **_GEO, admission="optimistic", max_preemptions=0
    )
    res = eng.generate(_reqs(lm.cfg.vocab, 3, max_new=24))
    by = {r.rid: r.status for r in res}
    assert set(by.values()) <= {"ok", "failed"}
    assert "failed" in by.values()  # first preemption hits the 0 bound
    assert eng.obs.value("serve.failed") >= 1
    # The stream still completed — no raise, every request resolved.
    assert len(res) == 3


def test_request_priority_shields_victim_choice(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, **_GEO, admission="optimistic", max_preemptions=10
    )
    # rid 0 runs at higher priority: under pressure the victim must be the
    # lower-priority row, so rid 0 finishes with zero preemptions.
    res = eng.generate(
        _reqs(lm.cfg.vocab, 2, max_new=24,
              priority=lambda i: 1 if i == 0 else 0)
    )
    assert eng.last_stats.preemptions >= 1
    assert res[0].n_preemptions == 0
    assert all(r.status == "ok" for r in res)


def test_admit_watermark_pauses_admission(deepseek_lm):
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, **_GEO, admission="optimistic", max_preemptions=10,
        admit_watermark=0.5,
    )
    # Defaults: reserve never pauses (1.0), optimistic pauses at 0.9.
    assert ServeEngine(lm, params, **_GEO)._watermark == 1.0
    assert ServeEngine(
        lm, params, **_GEO, admission="optimistic"
    )._watermark == 0.9
    res = eng.generate(_reqs(lm.cfg.vocab, 3, max_new=24))
    assert all(r.status == "ok" for r in res)
    # Admission-paused is a last-value gauge: it exists, and by stream end
    # the pool has drained so it must read un-paused again.
    assert eng.obs.value("serve.admission_paused") == 0.0


def test_engine_rejects_unknown_admission(deepseek_lm):
    lm, params = deepseek_lm
    with pytest.raises(AdmissionError):
        ServeEngine(lm, params, scheduler="continuous", admission="bogus")


# ---- pool lifecycle random walk (property test) ------------------------------


@SETTINGS
@given(seed=st.integers(0, 2**16))
def test_pool_lifecycle_random_walk(seed):
    """Random walk over the resilience lifecycle on an *oversubscribed*
    optimistic pool: admit / decode-step / cancel-release / natural
    ``PoolExhausted`` answered by victim release (preemption) / restore of
    a preempted prompt+generated stream / deadline-expire release.

    Invariants after every op (``check_invariants``) plus token
    conservation: the host mirror of every live slot's length matches the
    walk's own accounting, and a fully drained pool returns to all-free,
    zero-reserved."""
    cfg = get_config("deepseek-7b").reduced().with_(kv_layout="paged", page_size=4)
    rng = np.random.default_rng(seed)
    n_slots = 3
    pool = PagedKVPool(
        cfg, 1, n_slots, max_len=32, admission="optimistic", n_pages=13
    )
    live: dict[int, dict] = {}    # slot -> {len, total}
    preempted: list[dict] = []    # restorable: {prompt_len, done}

    def admit(slot, prompt_len, max_new):
        prompt = rng.integers(2, 5, size=prompt_len).astype(np.int32)
        if pool.admit(slot, prompt, max_new) is None:
            return False
        live[slot] = {
            "len": int(pool.lens[slot]),
            "total": min(prompt_len + max_new, pool.capacity),
        }
        return True

    for _ in range(80):
        op = rng.integers(0, 5)
        free = [s for s in range(n_slots) if s not in live]
        if op == 0 and free:  # fresh admission
            admit(int(rng.choice(free)), int(rng.integers(1, 20)),
                  int(rng.integers(1, 12)))
        elif op == 1 and live:  # decode/prefill step on one slot
            slot = int(rng.choice(list(live)))
            n = int(rng.integers(1, 5))
            n = min(n, live[slot]["total"] - live[slot]["len"])
            if n <= 0:
                continue
            try:
                pool.ensure_writable(slot, n)
            except PoolExhausted:
                # Preempt a victim (possibly the failing slot itself);
                # its stream becomes restorable.
                victim = select_victim(
                    [(s, 0, live[s]["len"], pool.shared_donor(s))
                     for s in live]
                )
                preempted.append({"state": live.pop(victim)})
                pool.release(victim)
                pool.check_invariants()
                continue
            pool.advance(slot, n)
            live[slot]["len"] += n
        elif op == 2 and live:  # cancel / deadline-expire: release
            slot = int(rng.choice(list(live)))
            del live[slot]
            pool.release(slot)
        elif op == 3 and preempted and free:  # restore = re-admission
            ent = preempted.pop()
            st_ = ent["state"]
            # Chunked re-prefill readmits prompt+generated as the prompt.
            admit(int(rng.choice(free)), max(st_["len"], 1),
                  max(st_["total"] - st_["len"], 1))
        pool.check_invariants()
        for slot, st_ in live.items():
            assert int(pool.lens[slot]) == st_["len"]  # token conservation

    for slot in list(live):
        pool.release(slot)
    pool.check_invariants()
    assert pool.alloc.free_count == pool.alloc.n_pages - 1
    assert pool.alloc.reserved == 0
