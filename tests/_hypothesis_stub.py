"""Minimal deterministic stand-in for ``hypothesis``.

Installed by ``conftest.py`` ONLY when the real package is absent (the test
image may not ship it; the repo cannot install new deps at test time). It
implements just the surface the property tests use — ``given``, ``settings``
and a few strategies — by sampling pseudo-randomly from a seed derived from
the test name, so runs are reproducible. No shrinking, no edge-case
database: with the real hypothesis installed, conftest leaves it alone and
this module is never imported.
"""

from __future__ import annotations

import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._sample(rng)))

    def filter(self, pred, _tries: int = 1000):
        def sample(rng):
            for _ in range(_tries):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive")

        return _Strategy(sample)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


strategies = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    booleans=_booleans,
    floats=_floats,
)


def given(**strats):
    def deco(fn):
        # NOT functools.wraps: pytest must see the zero-arg signature of the
        # runner, not the drawn-parameter signature of ``fn``.
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        runner.__name__ = fn.__name__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        runner._stub_given = True
        return runner

    return deco


class settings:
    """``@settings(max_examples=...)`` — applied above ``@given``."""

    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn
