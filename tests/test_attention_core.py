"""Blockwise (flash) JAX attention vs full-softmax reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import decode_attention, flash_attention, mha_reference


def _mk(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


CASES = [
    # b, sq, skv, hq, hkv, d, causal, window
    (2, 128, 128, 4, 4, 32, False, None),
    (2, 128, 128, 4, 1, 32, True, None),
    (1, 96, 160, 6, 2, 64, False, None),       # cross-shaped, uneven
    (1, 256, 256, 4, 2, 64, True, 64),         # SWA
    (2, 64, 192, 2, 2, 16, False, None),
    (1, 130, 130, 2, 2, 48, True, None),       # non-multiple of block
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("order", ["cyclic", "sawtooth", "block_snake"])
def test_flash_matches_reference(case, order):
    b, sq, skv, hq, hkv, d, causal, window = case
    q, k, v = _mk((b, sq, hq, d), 1), _mk((b, skv, hkv, d), 2), _mk((b, skv, hkv, d), 3)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    out = flash_attention(
        q, k, v, order=order, causal=causal, window=window, q_block=64, kv_block=64
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_order_invariance_exact_shape():
    """Cyclic and sawtooth must agree to fp tolerance (math-preserving)."""
    q, k, v = _mk((2, 256, 4, 64), 1), _mk((2, 256, 2, 64), 2), _mk((2, 256, 2, 64), 3)
    a = flash_attention(q, k, v, order="cyclic", causal=True, q_block=64, kv_block=64)
    b = flash_attention(q, k, v, order="sawtooth", causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6, rtol=2e-6)


def test_bf16_inputs():
    q = _mk((1, 128, 4, 64), 1, jnp.bfloat16)
    k = _mk((1, 128, 2, 64), 2, jnp.bfloat16)
    v = _mk((1, 128, 2, 64), 3, jnp.bfloat16)
    ref = mha_reference(q, k, v, causal=True)
    out = flash_attention(q, k, v, order="sawtooth", causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_grad_flows():
    q, k, v = _mk((1, 64, 2, 32), 1), _mk((1, 64, 2, 32), 2), _mk((1, 64, 2, 32), 3)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, order="sawtooth", causal=True, q_block=32, kv_block=32) ** 2).sum()

    def loss_ref(q, k, v):
        return (mha_reference(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_decode_matches_reference():
    q = _mk((3, 1, 8, 64), 1)
    kc, vc = _mk((3, 640, 2, 64), 2), _mk((3, 640, 2, 64), 3)
    lens = jnp.array([640, 500, 7])
    out = decode_attention(q, kc, vc, lens)
    for b in range(3):
        n = int(lens[b])
        ref = mha_reference(q[b : b + 1], kc[b : b + 1, :n], vc[b : b + 1, :n])
        np.testing.assert_allclose(
            np.asarray(out[b : b + 1]), np.asarray(ref), atol=2e-5, rtol=2e-5
        )


def test_decode_window():
    q = _mk((1, 1, 4, 32), 1)
    kc, vc = _mk((1, 256, 4, 32), 2), _mk((1, 256, 4, 32), 3)
    out = decode_attention(q, kc, vc, 256, window=64)
    ref = mha_reference(q, kc[:, 192:], vc[:, 192:])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
