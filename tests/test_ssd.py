"""Mamba-2 SSD: chunked form vs sequential oracle; block prefill/decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ref import ssd_ref
from repro.models import ssm


def _inputs(seed, B=2, S=96, H=3, P=8, N=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (B, S, N))
    c = jax.random.normal(ks[4], (B, S, N))
    return x, dt, a, b, c


@pytest.mark.parametrize("chunk", [8, 32, 96, 128])
def test_chunked_matches_sequential(chunk):
    x, dt, a, b, c = _inputs(0)
    y_ref, s_ref = ssd_ref(x, dt, a, b, c)
    y, s = ssm.ssd_chunked(x, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=3e-4, rtol=3e-4)


def test_state_chaining():
    x, dt, a, b, c = _inputs(1)
    y_ref, s_ref = ssd_ref(x, dt, a, b, c)
    y1, s1 = ssm.ssd_chunked(x[:, :40], dt[:, :40], a, b[:, :40], c[:, :40], chunk=16)
    y2, s2 = ssm.ssd_chunked(
        x[:, 40:], dt[:, 40:], a, b[:, 40:], c[:, 40:], chunk=16, init_state=s1
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_ref), atol=3e-4, rtol=3e-4
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_ref), atol=3e-4, rtol=3e-4)


def test_mamba_block_prefill_then_decode_matches_full():
    cfg = get_config("mamba2-130m").reduced()
    p = ssm.mamba_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 33, cfg.d_model))
    full = ssm.mamba_apply(p, cfg, x)
    out_pre, state = ssm.mamba_prefill(p, cfg, x[:, :-1])
    np.testing.assert_allclose(
        np.asarray(out_pre), np.asarray(full[:, :-1]), atol=2e-3, rtol=2e-3
    )
    out_dec, _ = ssm.mamba_decode(p, cfg, x[:, -1:], state)
    np.testing.assert_allclose(
        np.asarray(out_dec), np.asarray(full[:, -1:]), atol=2e-3, rtol=2e-3
    )


def test_decay_bounded():
    """exp terms in the chunked form must stay <= 1 (no overflow)."""
    x, dt, a, b, c = _inputs(2, S=64)
    dt = dt * 10.0  # aggressive steps
    y, s = ssm.ssd_chunked(x, dt, a, b, c, chunk=16)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(s)).all()
