"""Launcher CLIs run end-to-end in subprocesses (runnability proof)."""

import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_cli(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m"] + args,
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout[-2000:]}\nstderr:\n{r.stderr[-2000:]}"
    return r.stdout


def test_train_cli_with_crash_and_resume(tmp_path):
    args = [
        "repro.launch.train", "--arch", "deepseek-7b", "--reduced",
        "--steps", "8", "--batch", "2", "--seq", "64", "--mesh", "1x1",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
    ]
    out1 = run_cli(args + ["--crash-at", "5"])
    assert "interrupted=True" in out1
    out2 = run_cli(args)
    assert "resumed_from=" in out2 and "resumed_from=None" not in out2
    assert "interrupted=False" in out2


def test_serve_cli(tmp_path):
    out = run_cli(
        [
            "repro.launch.serve", "--arch", "mamba2-130m", "--reduced",
            "--requests", "3", "--batch-size", "2", "--max-new", "4",
            "--max-len", "64",
        ]
    )
    assert "served 3 requests" in out


def test_dryrun_cli_reduced_cell(tmp_path):
    """dryrun CLI on one small full-config cell (production mesh, cached-free)."""
    out = run_cli(
        [
            "repro.launch.dryrun", "--arch", "mamba2-130m", "--shape",
            "decode_32k", "--mesh", "single", "--out", str(tmp_path),
            "--no-resume",
        ],
        timeout=560,
    )
    assert "1 ok, 0 skipped, 0 errors" in out
