"""Sharding rules: divisibility tightening, param spec coverage, HLO parse."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.hlo import collective_bytes, parse_shape_bytes
from repro.configs import ParallelConfig, get_config
from repro.dist import sharding as shd
from repro.models import build_model


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """Abstract mesh for spec computation (no real devices needed)."""
    return jax.sharding.AbstractMesh(shape, axes)


def test_tighten_drops_nondividing_axes():
    mesh = fake_mesh()
    assert shd.tighten((128, 60), ("data", "model"), mesh) == P("data", None)
    assert shd.tighten((256, 256), ("data", "model"), mesh) == P("data", "model")
    assert shd.tighten((3, 5), ("data", "model"), mesh) == P(None, None)


def test_tighten_multi_axis_prefix():
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    # 32 divides by pod*data=32
    assert shd.tighten((32,), (("pod", "data"),), mesh) == P(("pod", "data"))
    # 16 divides by pod=2 but not pod*data=32 -> keep prefix ('pod',)
    assert shd.tighten((16,), (("pod", "data"),), mesh) == P("pod")


@pytest.mark.parametrize("arch", ["deepseek-7b", "olmoe-1b-7b", "zamba2-2_7b"])
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch).reduced()
    lm = build_model(cfg)
    params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    mesh = fake_mesh()
    pcfg = ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
    specs = shd.param_specs(params, pcfg, mesh)
    n_sharded = 0
    for (path, spec), (_, leaf) in zip(
        jax.tree_util.tree_flatten_with_path(specs)[0],
        jax.tree_util.tree_flatten_with_path(params)[0],
    ):
        assert len(spec) == len(leaf.shape), (path, spec, leaf.shape)
        if any(s is not None for s in spec):
            n_sharded += 1
    assert n_sharded > 0


def test_full_config_shards_model_axis():
    """On the production mesh the big matrices must actually split."""
    cfg = get_config("deepseek-7b")
    lm = build_model(cfg)
    params = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    mesh = fake_mesh()
    specs = shd.param_specs(params, ParallelConfig(fsdp_axes=("data",)), mesh)
    wq = specs["layers"]["attn"]["wq"]["w"]
    assert wq == P(None, "data", "model")  # (L, d, H*hd)
    emb = specs["embed"]["table"]
    assert emb[0] == "model"


def test_batch_spec_fallbacks():
    mesh = fake_mesh()
    pcfg = ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
    assert shd.batch_spec(256, pcfg, mesh)[0] == "data"
    assert shd.batch_spec(1, pcfg, mesh)[0] is None  # can't shard batch=1


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[256,1024]") == 256 * 1024 * 4
    assert parse_shape_bytes("bf16[8]{0}") == 16
    assert parse_shape_bytes("(f32[4], s32[2])") == 24
    assert parse_shape_bytes("pred[]") == 1


def test_collective_bytes_parsing():
    txt = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag.1 = bf16[64,128]{1,0} all-gather(bf16[32,128]{1,0} %y), dimensions={0}
  %rs = f32[16]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
  %cp-start = (f32[8]{0}, f32[8]{0}) collective-permute-start(f32[8]{0} %w)
  %cp-done = f32[8]{0} collective-permute-done(%cp-start)
"""
    cb = collective_bytes(txt)
    assert cb["all-reduce"] == 4096
    assert cb["all-gather"] == 64 * 128 * 2
    assert cb["reduce-scatter"] == 64
    assert cb["collective-permute"] == 64  # start counted once, done skipped
    assert cb["total"] == 4096 + 16384 + 64 + 64
