"""int8 KV cache: quantization quality, decode consistency, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, all_configs, get_config
from repro.dist import sharding as shd
from repro.models import build_model
from repro.models.transformer import _dequantize_kv, _quantize_kv, fill_cache, init_cache


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 64)) * 3.0
    q, scale = _quantize_kv(x)
    back = _dequantize_kv(q, scale, jnp.float32)
    # symmetric per-vector int8: |err| <= scale/2 elementwise
    bound = np.asarray(scale)[..., None] * 0.5 + 1e-6
    assert (np.abs(np.asarray(back - x)) <= bound).all()


def test_init_and_fill_int8_cache():
    cfg = get_config("deepseek-7b").reduced().with_(kv_cache_dtype="int8")
    cache = init_cache(cfg, batch=2, max_len=32)
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].shape == (2, 32, cfg.n_kv_heads)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.n_kv_heads, cfg.hd))
    cache = fill_cache(cfg, cache, k, k)
    back = _dequantize_kv(cache["k"][:, :16], cache["k_scale"][:, :16], jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(k), atol=0.05)


@pytest.mark.parametrize("arch", ["deepseek-7b", "mixtral-8x7b"])
def test_int8_decode_close_to_bf16(arch):
    cfg = all_configs()[arch].reduced()
    lm16 = build_model(cfg)
    lm8 = build_model(cfg.with_(kv_cache_dtype="int8"))
    params = lm16.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    lg16, c16 = jax.jit(lambda p, b: lm16.prefill(p, b, 48))(params, {"tokens": toks})
    lg8, c8 = jax.jit(lambda p, b: lm8.prefill(p, b, 48))(params, {"tokens": toks})
    nxt = jnp.argmax(lg16[:, -1], -1)[:, None]
    d16, _ = jax.jit(lm16.decode_step)(params, nxt, c16)
    d8, _ = jax.jit(lm8.decode_step)(params, nxt, c8)
    rel = float(jnp.abs(d8 - d16).max() / (jnp.abs(d16).max() + 1e-9))
    assert rel < 0.1, rel
    # memory halves (8-bit payload + small scales)
    b16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c16))
    b8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c8))
    assert b8 < 0.75 * b16


def test_cache_seq_shard_fallback_for_gqa():
    """hkv=8 doesn't divide model=16 -> the cache shards its seq dim."""
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    pcfg = ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
    caches = {
        "k": jax.ShapeDtypeStruct((126, 128, 32768, 8, 128), jnp.bfloat16),
        "k_scale": jax.ShapeDtypeStruct((126, 128, 32768, 8), jnp.float32),
    }
    sh = shd.cache_shardings(caches, pcfg, mesh)
    assert sh["k"].spec == jax.sharding.PartitionSpec(None, "data", "model", None, None)
    assert sh["k_scale"].spec == jax.sharding.PartitionSpec(None, "data", "model", None)
    # divisible heads keep head sharding
    caches2 = {"k": jax.ShapeDtypeStruct((30, 128, 32768, 32, 128), jnp.bfloat16)}
    sh2 = shd.cache_shardings(caches2, pcfg, mesh)
    assert sh2["k"].spec == jax.sharding.PartitionSpec(None, "data", None, "model", None)
