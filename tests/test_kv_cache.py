"""KV caches: int8 quantization quality, decode consistency, sharding rules,
and paged-layout parity (block-table decode vs the contiguous oracle)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, all_configs, get_config
from repro.core.attention import decode_attention
from repro.dist import sharding as shd
from repro.kernels.flash_decode import flash_decode_fwd
from repro.models import build_model
from repro.models.transformer import _dequantize_kv, _quantize_kv, fill_cache, init_cache


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 64)) * 3.0
    q, scale = _quantize_kv(x)
    back = _dequantize_kv(q, scale, jnp.float32)
    # symmetric per-vector int8: |err| <= scale/2 elementwise
    bound = np.asarray(scale)[..., None] * 0.5 + 1e-6
    assert (np.abs(np.asarray(back - x)) <= bound).all()


def test_init_and_fill_int8_cache():
    cfg = get_config("deepseek-7b").reduced().with_(kv_cache_dtype="int8")
    cache = init_cache(cfg, batch=2, max_len=32)
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].shape == (2, 32, cfg.n_kv_heads)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.n_kv_heads, cfg.hd))
    cache = fill_cache(cfg, cache, k, k)
    back = _dequantize_kv(cache["k"][:, :16], cache["k_scale"][:, :16], jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(k), atol=0.05)


@pytest.mark.parametrize("arch", ["deepseek-7b", "mixtral-8x7b"])
def test_int8_decode_close_to_bf16(arch):
    cfg = all_configs()[arch].reduced()
    lm16 = build_model(cfg)
    lm8 = build_model(cfg.with_(kv_cache_dtype="int8"))
    params = lm16.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    lg16, c16 = jax.jit(lambda p, b: lm16.prefill(p, b, 48))(params, {"tokens": toks})
    lg8, c8 = jax.jit(lambda p, b: lm8.prefill(p, b, 48))(params, {"tokens": toks})
    nxt = jnp.argmax(lg16[:, -1], -1)[:, None]
    d16, _ = jax.jit(lm16.decode_step)(params, nxt, c16)
    d8, _ = jax.jit(lm8.decode_step)(params, nxt, c8)
    rel = float(jnp.abs(d8 - d16).max() / (jnp.abs(d16).max() + 1e-9))
    assert rel < 0.1, rel
    # memory halves (8-bit payload + small scales)
    b16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c16))
    b8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c8))
    assert b8 < 0.75 * b16


# ---- paged layout ----------------------------------------------------------


def _paged_problem(seed=0, b=3, hq=8, hkv=2, d=16, page=8, nb=4):
    """Random pool + shuffled block table + ragged lens + contiguous oracle."""
    rng = np.random.default_rng(seed)
    n_pages = b * nb + 1
    kp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, d)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)).astype(np.float32))
    perm = rng.permutation(np.arange(1, n_pages))[: b * nb].reshape(b, nb)
    bt = jnp.asarray(perm.astype(np.int32))
    lens = jnp.asarray(np.array([5, 17, nb * page], np.int32))  # ragged
    kc = kp[bt].reshape(b, nb * page, hkv, d)
    vc = vp[bt].reshape(b, nb * page, hkv, d)
    return q, kp, vp, bt, lens, kc, vc


@pytest.mark.parametrize("order", ["cyclic", "sawtooth", "block_snake"])
@pytest.mark.parametrize("window", [None, 7])
def test_paged_decode_matches_contiguous_oracle(order, window):
    q, kp, vp, bt, lens, kc, vc = _paged_problem()
    ref = decode_attention(q, kc, vc, lens, window=window)
    out = decode_attention(
        q, kp, vp, lens, block_table=bt, window=window, order=order
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    outk = flash_decode_fwd(
        q, kp, vp, lens, block_table=bt, window=window, order=order, interpret=True
    )
    np.testing.assert_allclose(np.asarray(outk), np.asarray(ref), atol=2e-5)


def test_paged_decode_free_slot_rows_are_zero():
    """len=0 rows (free continuous-batching slots) read back exact zeros."""
    q, kp, vp, bt, lens, _, _ = _paged_problem()
    lens = lens.at[0].set(0)
    for fn in (
        lambda: decode_attention(q, kp, vp, lens, block_table=bt, order="sawtooth"),
        lambda: flash_decode_fwd(
            q, kp, vp, lens, block_table=bt, order="sawtooth", interpret=True
        ),
    ):
        out = np.asarray(fn())
        assert not np.isnan(out).any()
        assert np.abs(out[0]).max() == 0.0


def test_paged_init_and_fill():
    cfg = get_config("deepseek-7b").reduced().with_(kv_layout="paged", page_size=8)
    cache = init_cache(cfg, batch=2, max_len=20)  # 3 pages per row
    assert cache["k_pages"].shape == (6, 8, cfg.n_kv_heads, cfg.hd)
    np.testing.assert_array_equal(
        np.asarray(cache["block_table"]), np.arange(6).reshape(2, 3)
    )
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 13, cfg.n_kv_heads, cfg.hd))
    cache = fill_cache(cfg, cache, k, k)
    np.testing.assert_array_equal(np.asarray(cache["len"]), [13, 13])
    got = np.asarray(cache["k_pages"]).reshape(2, 24, cfg.n_kv_heads, cfg.hd)
    np.testing.assert_allclose(got[:, :13], np.asarray(k), rtol=1e-6)
    assert np.abs(got[:, 13:]).max() == 0.0  # tail pages zero-padded


@pytest.mark.parametrize("kv_dtype", ["float32", "int8"])
def test_paged_model_decode_matches_contiguous(kv_dtype):
    """Same params, paged vs contiguous layout: greedy decode must agree."""
    cfg = get_config("deepseek-7b").reduced().with_(kv_cache_dtype=kv_dtype)
    cfgp = cfg.with_(kv_layout="paged", page_size=16)
    lm, lmp = build_model(cfg), build_model(cfgp)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    lg, c = jax.jit(lambda p, b: lm.prefill(p, b, 48))(params, {"tokens": toks})
    lgp, cp = jax.jit(lambda p, b: lmp.prefill(p, b, 48))(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lgp), atol=1e-5)
    nxt = jnp.argmax(lg[:, -1], -1)[:, None]
    for _ in range(3):
        lg, c = jax.jit(lm.decode_step)(params, nxt, c)
        lgp, cp = jax.jit(lmp.decode_step)(params, nxt, cp)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lgp), atol=1e-4)
        nxt = jnp.argmax(lg[:, -1], -1)[:, None]


def test_paged_layout_rejects_swa():
    cfg = get_config("mixtral-8x7b").reduced().with_(kv_layout="paged")
    with pytest.raises(ValueError, match="full attention"):
        init_cache(cfg, batch=1, max_len=32)


def test_cache_seq_shard_fallback_for_gqa():
    """hkv=8 doesn't divide model=16 -> the cache shards its seq dim."""
    mesh = jax.sharding.AbstractMesh((16, 16), ("data", "model"))
    pcfg = ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
    caches = {
        "k": jax.ShapeDtypeStruct((126, 128, 32768, 8, 128), jnp.bfloat16),
        "k_scale": jax.ShapeDtypeStruct((126, 128, 32768, 8), jnp.float32),
    }
    sh = shd.cache_shardings(caches, pcfg, mesh)
    assert sh["k"].spec == jax.sharding.PartitionSpec(None, "data", "model", None, None)
    assert sh["k_scale"].spec == jax.sharding.PartitionSpec(None, "data", "model", None)
    # divisible heads keep head sharding
    caches2 = {"k": jax.ShapeDtypeStruct((30, 128, 32768, 32, 128), jnp.bfloat16)}
    sh2 = shd.cache_shardings(caches2, pcfg, mesh)
    assert sh2["k"].spec == jax.sharding.PartitionSpec(None, "data", None, "model", None)
