"""Multi-device integration tests (8 virtual CPU devices via subprocess —
the 512-device flag stays scoped to the dry-run, and XLA device count is
process-global, so these run in spawned interpreters)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_py(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, TrainConfig, ParallelConfig
        from repro.models import build_model
        from repro.train.step import make_train_state, make_train_step, shard_state
        from repro.launch.mesh import make_local_mesh

        cfg = get_config("deepseek-7b").reduced()
        lm = build_model(cfg)
        tcfg = TrainConfig(lr=1e-3, warmup_steps=0)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)}

        losses = {}
        for (d, m) in [(1, 1), (4, 2)]:
            mesh = make_local_mesh(d, m)
            pcfg = ParallelConfig(fsdp_axes=("data",), data_axes=("data",), microbatches=2)
            with jax.set_mesh(mesh):
                state = make_train_state(lm, tcfg, jax.random.PRNGKey(0))
                state = shard_state(state, pcfg, mesh)
                step, compile_step = make_train_step(lm, tcfg, pcfg, mesh)
                compiled = compile_step(state, batch)
                state, metrics = compiled(state, batch)
                state, metrics = compiled(state, batch)
                losses[(d, m)] = float(metrics["loss"])
        a, b = losses[(1, 1)], losses[(4, 2)]
        assert abs(a - b) < 5e-3, losses
        print("OK", losses)
        """
    )
    assert "OK" in out


def test_compressed_allreduce_with_error_feedback():
    out = run_py(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.compression import reduce_grads_compressed, init_residuals
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(8, 1)
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64))}
        res = init_residuals(grads)  # per-device residuals, stacked on dim 0

        def f(g, r):
            g = {"w": g["w"][0]}
            r = {"w": r["w"][0]}
            out, new_r = reduce_grads_compressed(g, r, "data")
            return out, {"w": new_r["w"][None]}

        fn = jax.shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P(), P("data")),
        )
        out, new_res = fn(grads, res)
        exact = np.asarray(grads["w"]).mean(0)
        got = np.asarray(out["w"])
        err0 = np.abs(got - exact).max()
        scale = np.abs(np.asarray(grads["w"])).max() / 127.0
        assert err0 <= scale * 1.5, (err0, scale)
        # error feedback: residuals non-zero (they carry the quantization error)
        assert np.abs(np.asarray(new_res["w"])).sum() > 0
        print("OK", err0)
        """
    )
    assert "OK" in out


def test_elastic_remesh_restore():
    out = run_py(
        """
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import get_config, TrainConfig, ParallelConfig
        from repro.models import build_model
        from repro.dist import sharding as shd
        from repro.train.step import make_train_state, make_train_step, state_shardings, shard_state
        from repro.train.checkpoint import CheckpointManager
        from repro.train.fault_tolerance import elastic_remesh, usable_mesh_shape
        from repro.launch.mesh import make_local_mesh

        assert usable_mesh_shape(6, model_parallel=4) == (3, 2)  # TP 4->2
        assert usable_mesh_shape(8, model_parallel=4) == (2, 4)
        assert usable_mesh_shape(7, model_parallel=4) == (7, 1)  # prime: pure DP

        cfg = get_config("deepseek-7b").reduced()
        lm = build_model(cfg)
        tcfg = TrainConfig(lr=1e-3, warmup_steps=0)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)}
        with tempfile.TemporaryDirectory() as d:
            mesh8 = make_local_mesh(4, 2)
            pcfg = ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
            with jax.set_mesh(mesh8):
                state = make_train_state(lm, tcfg, jax.random.PRNGKey(0))
                state = shard_state(state, pcfg, mesh8)
                step, compile_step = make_train_step(lm, tcfg, pcfg, mesh8)
                state, m1 = compile_step(state, batch)(state, batch)
            ck = CheckpointManager(d, keep=2)
            ck.save(state, 0, blocking=True)

            # "2 devices died": rebuild mesh from 6 survivors, restore, resume
            survivors = jax.devices()[:6]
            mesh6 = elastic_remesh(survivors, model_parallel=2)
            with jax.set_mesh(mesh6):
                template = make_train_state(lm, tcfg, jax.random.PRNGKey(0))
                sh = state_shardings(template, pcfg, mesh6)
                restored, step_no = ck.restore_latest(template, shardings=sh)
                step, compile_step = make_train_step(lm, tcfg, pcfg, mesh6)
                # slice of an array committed to the old mesh: re-place it
                batch6 = {"tokens": np.asarray(batch["tokens"][:6])}
                batch6 = jax.device_put(
                    batch6, shd.batch_shardings(batch6, pcfg, mesh6))
                state2, m2 = compile_step(restored, batch6)(restored, batch6)
            assert np.isfinite(float(m2["loss"]))
            print("OK", float(m1["loss"]), float(m2["loss"]))
        """
    )
    assert "OK" in out


def test_reduced_dryrun_cell_on_small_mesh():
    """The dry-run path itself (lower+compile+roofline) on 8 devices."""
    out = run_py(
        """
        import jax
        from repro.launch.dryrun import lower_cell
        from repro.launch.mesh import make_local_mesh

        mesh = make_local_mesh(4, 2)
        rec, lowered, compiled = lower_cell(
            "olmoe-1b-7b", "train_4k", mesh, "local8", reduced=True)
        assert rec["status"] == "ok"
        assert rec["cost"]["flops"] > 0
        assert "roofline" in rec
        rec2, *_ = lower_cell("mixtral-8x7b", "decode_32k", mesh, "local8", reduced=True)
        assert rec2["status"] == "ok"
        print("OK", rec["roofline"]["bottleneck"], rec2["roofline"]["bottleneck"])
        """,
        timeout=900,
    )
    assert "OK" in out


def test_sharded_serve_engine():
    """ServeEngine with a (4,2) mesh: sharded params, batched generation."""
    out = run_py(
        """
        import jax, numpy as np
        from repro.configs import get_config, ParallelConfig
        from repro.models import build_model
        from repro.serve import Request, ServeEngine
        from repro.launch.mesh import make_local_mesh

        cfg = get_config("deepseek-7b").reduced()
        lm = build_model(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        mesh = make_local_mesh(4, 2)
        eng = ServeEngine(lm, params, batch_size=4, max_len=64, mesh=mesh,
                          pcfg=ParallelConfig(fsdp_axes=("data",), data_axes=("data",)))
        prompt = np.arange(2, 10, dtype=np.int32)
        reqs = [Request(tokens=prompt, max_new_tokens=5, rid=i) for i in range(4)]
        a = eng.generate(reqs)
        b = eng.generate(reqs)
        assert all(r.steps >= 1 for r in a)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x.tokens, y.tokens)  # deterministic
        # matches single-device greedy output
        eng1 = ServeEngine(lm, lm.init(jax.random.PRNGKey(0)), batch_size=4, max_len=64)
        c = eng1.generate(reqs)
        same = sum(np.array_equal(x.tokens, y.tokens) for x, y in zip(a, c))
        assert same >= 3, [x.tokens.tolist() for x in a]  # fp-tie tolerance
        print("OK", [r.tokens.tolist() for r in a[:2]])
        """
    )
    assert "OK" in out
