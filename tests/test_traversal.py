"""Traversal IR invariants: every consumer lowers from one object.

Covers the schedule-level acceptance bars of the Traversal refactor:
  * every order (block_snake included) visits a permutation of the cyclic
    sequence for every Q tile, under causal/SWA trimming;
  * mean reuse distance is monotone cyclic >= block_snake >= sawtooth on
    untrimmed grids;
  * block_snake beats sawtooth on modeled non-compulsory LLC miss bytes at
    a capacity-bound shape (the order's raison d'être);
  * the three lowerings (traced index_map arithmetic, vectorized visit
    order, host iterators) agree exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache_sim import reuse_distances
from repro.core.schedule import (
    DEFAULT_SNAKE_GROUP,
    KVSchedule,
    Order,
    Traversal,
    bwd_kv_schedule,
    kv_index,
    kv_index_host,
    page_visit_order,
)
from repro.kernels.traffic import FlashGridSpec, bwd_dkv_traffic, fwd_llc_model

ORDERS = ["cyclic", "sawtooth", "block_snake"]


# --------------------------------------------------------------------------
# Order parsing
# --------------------------------------------------------------------------


def test_order_parse_names_valid_orders_on_typo():
    with pytest.raises(ValueError) as ei:
        Order.parse("sawtoth")
    msg = str(ei.value)
    for o in Order:
        assert o.value in msg, msg
    assert "sawtoth" in msg


def test_order_parse_accepts_case_and_enum():
    assert Order.parse("BLOCK_SNAKE") is Order.BLOCK_SNAKE
    assert Order.parse(Order.CYCLIC) is Order.CYCLIC


# --------------------------------------------------------------------------
# permutation invariance under trimming
# --------------------------------------------------------------------------


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize(
    "causal,window", [(False, None), (True, None), (True, 200), (False, 150)]
)
def test_every_order_is_permutation_of_cyclic_per_q_tile(order, causal, window):
    """For every Q tile the visit sequence is a permutation of the cyclic
    one under the same trimming — orders only permute, never change
    coverage."""
    ref = Traversal(
        "cyclic", n_q=7, n_kv=9, causal=causal, window=window,
        q_block=64, kv_block=64,
    )
    tr = Traversal(
        order, n_q=7, n_kv=9, causal=causal, window=window,
        q_block=64, kv_block=64, snake_group=3,
    )
    for q_tile in range(7):
        assert sorted(tr.kv_order(q_tile)) == ref.kv_order(q_tile), (order, q_tile)


@pytest.mark.parametrize("order", ORDERS)
def test_transposed_orders_are_permutations_too(order):
    ref = bwd_kv_schedule("cyclic", 8, 6, causal=True, window=256,
                          q_block=64, kv_block=64)
    s = bwd_kv_schedule(order, 8, 6, causal=True, window=256,
                        q_block=64, kv_block=64, snake_group=3)
    for kv_tile in range(6):
        assert sorted(s.q_order(kv_tile)) == ref.q_order(kv_tile), (order, kv_tile)


def test_block_snake_degenerate_groups():
    """group=1 is cyclic, group>=n_kv is sawtooth — the three families are
    one arithmetic."""
    n = 13
    for i in range(4):
        cyc = [kv_index_host("cyclic", i, j, n) for j in range(n)]
        saw = [kv_index_host("sawtooth", i, j, n) for j in range(n)]
        g1 = [kv_index_host("block_snake", i, j, n, snake_group=1) for j in range(n)]
        gn = [kv_index_host("block_snake", i, j, n, snake_group=n) for j in range(n)]
        assert g1 == cyc and gn == saw, i


def test_block_snake_reverses_within_groups_only():
    """Odd passes reverse each group internally; the group sequence itself
    still ascends — the property that bounds the concurrent footprint."""
    got = [kv_index_host("block_snake", 1, j, 10, snake_group=4) for j in range(10)]
    assert got == [3, 2, 1, 0, 7, 6, 5, 4, 9, 8]
    # even passes are forward
    assert [kv_index_host("block_snake", 2, j, 10, snake_group=4) for j in range(10)] \
        == list(range(10))


# --------------------------------------------------------------------------
# lowering agreement: traced == vectorized == host
# --------------------------------------------------------------------------


@pytest.mark.parametrize("order", ORDERS)
def test_traced_kv_index_matches_host(order):
    for i in range(4):
        for j in range(11):
            host = kv_index_host(order, i, j, 11, snake_group=4)
            traced = int(kv_index(order, jnp.int32(i), jnp.int32(j), 11, snake_group=4))
            assert host == traced, (order, i, j)


@pytest.mark.parametrize("order", ORDERS)
def test_visit_order_matches_host(order):
    got = np.asarray(page_visit_order(order, np.arange(5), 11, snake_group=4))
    want = np.asarray(
        [[kv_index_host(order, p, j, 11, snake_group=4) for j in range(11)]
         for p in range(5)]
    )
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 200), (False, None)])
def test_traced_block_index_matches_host_iterators(order, causal, window):
    """The Pallas index_map lowering and the host replay lowering agree at
    every grid step — the property that keeps kernels and traffic models
    from drifting."""
    tr = Traversal(
        order, n_q=6, n_kv=6, causal=causal, window=window,
        q_block=64, kv_block=64, n_groups=2, snake_group=2,
    )
    host = list(tr.fwd_grid_steps())
    step = 0
    for i in range(tr.grid_rows):
        for j in range(tr.n_kv):
            jj, valid = tr.kv_block_index(jnp.int32(i), jnp.int32(j))
            hi_, hjj, hvalid = host[step]
            assert (hi_, hjj, hvalid) == (i, int(jj), bool(valid)), (order, i, j)
            step += 1


@pytest.mark.parametrize("order", ORDERS)
def test_traced_stream_index_matches_host_iterators(order):
    tr = Traversal(
        order, n_q=5, n_kv=4, causal=True, window=None,
        q_block=64, kv_block=64, n_groups=3, snake_group=4,
    )
    host = list(tr.stream_grid_steps())
    step = 0
    for jkv in range(tr.n_kv):
        for u in range(tr.grid_rows):
            gg, qi, valid = tr.stream_block_index(jnp.int32(jkv), jnp.int32(u))
            hjkv, hgg, hqi, hvalid = host[step]
            assert (hjkv, hgg, hqi, hvalid) == (jkv, int(gg), int(qi), bool(valid))
            step += 1


def test_schedule_wrappers_share_the_ir():
    """KVSchedule/BwdKVSchedule are views over the same compiled object."""
    s = KVSchedule("block_snake", n_q=5, n_kv=8, causal=True,
                   q_block=64, kv_block=64, snake_group=3)
    tr = s.traversal
    for q in range(5):
        assert s.kv_order(q) == tr.kv_order(q)
    b = s.bwd(window=128)
    for kv in range(8):
        assert b.q_order(kv) == b.traversal.q_order(kv)


# --------------------------------------------------------------------------
# locality: mean reuse distance + the capacity-bound LLC win
# --------------------------------------------------------------------------


def _mean_reuse(order, snake_group=None, n=24):
    s = KVSchedule(order, n_q=n, n_kv=n, causal=False,
                   q_block=64, kv_block=64, snake_group=snake_group)
    dists = reuse_distances(s.flat_trace(n_workers=1))
    assert dists, "untrimmed multi-pass stream must have reuses"
    return sum(dists) / len(dists)


def test_mean_reuse_distance_monotone_cyclic_snake_sawtooth():
    """On untrimmed grids: cyclic >= block_snake >= sawtooth (strictly, for
    an interior group size) — sawtooth is the mean-optimal full-pass order,
    block_snake trades mean locality for a bounded footprint."""
    cyc = _mean_reuse("cyclic")
    snake = _mean_reuse("block_snake", snake_group=8)
    saw = _mean_reuse("sawtooth")
    assert cyc > snake > saw, (cyc, snake, saw)
    # degenerate groups collapse onto the endpoints
    assert _mean_reuse("block_snake", snake_group=1) == pytest.approx(cyc)
    assert _mean_reuse("block_snake", snake_group=24) == pytest.approx(saw)
    # and the group knob interpolates monotonically
    assert snake > _mean_reuse("block_snake", snake_group=16) > saw


def test_block_snake_beats_sawtooth_on_capacity_bound_llc():
    """The acceptance bar for the new order: at a capacity-bound shape
    (causal desync, buffer < working set) block_snake's bounded footprint
    beats both sawtooth and cyclic on modeled non-compulsory miss bytes."""
    spec = FlashGridSpec(
        seq_q=8192, seq_kv=8192, q_block=128, kv_block=128, causal=True
    )
    kw = dict(n_workers=12, capacity_frac=0.75)
    cyc = fwd_llc_model(spec, "cyclic", **kw).non_compulsory_misses
    saw = fwd_llc_model(spec, "sawtooth", **kw).non_compulsory_misses
    snk16 = fwd_llc_model(spec, "block_snake", snake_group=16, **kw).non_compulsory_misses
    snk32 = fwd_llc_model(spec, "block_snake", snake_group=32, **kw).non_compulsory_misses
    assert saw < cyc  # the paper's claim still holds here
    assert snk16 < saw, (snk16, saw)
    assert snk32 < 0.5 * saw, (snk32, saw)  # sized to capacity: >2x better


def test_fwd_llc_model_accesses_order_invariant():
    """Reordering is a pure permutation: every order issues identical
    access volume; only the hit/miss split moves."""
    spec = FlashGridSpec(
        seq_q=4096, seq_kv=4096, q_block=128, kv_block=128, causal=True
    )
    res = [
        fwd_llc_model(spec, o, snake_group=8, n_workers=8, capacity_frac=0.5)
        for o in ORDERS
    ]
    assert len({r.accesses for r in res}) == 1
    assert len({r.cold_misses for r in res}) == 1


def test_bwd_dkv_traffic_block_snake_between_cyclic_and_sawtooth():
    """Pipeline elision on the transposed grid: sawtooth elides every sweep
    boundary, cyclic none; block_snake gives up the boundary elision (its
    win is the bounded LLC footprint, not DMA elision)."""
    spec = FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=256, kv_block=256)
    cyc = bwd_dkv_traffic(spec, "cyclic")
    saw = bwd_dkv_traffic(spec, "sawtooth")
    snk = bwd_dkv_traffic(spec, "block_snake", snake_group=4)
    assert saw.stream_bytes <= snk.stream_bytes <= cyc.stream_bytes
    # order-invariant totals
    assert cyc.total_stream_fetches == snk.total_stream_fetches
    assert cyc.resident_bytes == snk.resident_bytes == saw.resident_bytes


def test_default_snake_group_is_used_when_unset():
    tr = Traversal("block_snake", n_q=2, n_kv=4 * DEFAULT_SNAKE_GROUP,
                   q_block=64, kv_block=64)
    row = tr.kv_order(1)  # odd parity: first group reversed
    assert row[0] == DEFAULT_SNAKE_GROUP - 1


@pytest.mark.parametrize("order", ORDERS)
def test_empty_q_range_on_transposed_grid(order):
    """causal with seq_kv > seq_q: KV tiles past the Q coverage have an
    empty Q range — every lowering must mark those steps invalid (clamped
    in-range indices, no crash) and the wavefront must still write dK/dV."""
    tr = Traversal(order, n_q=1, n_kv=4, causal=True,
                   q_block=128, kv_block=128, snake_group=2)
    host = list(tr.stream_grid_steps())
    assert len(host) == 4 * tr.grid_rows
    for step, (jkv, gg, qi, valid) in enumerate(host):
        assert 0 <= qi < tr.n_q
        assert valid == (jkv == 0)  # only KV tile 0 sees any Q tile
        tg, tqi, tvalid = tr.stream_block_index(
            jnp.int32(jkv), jnp.int32(step % tr.grid_rows)
        )
        assert (int(tg), int(tqi), bool(tvalid)) == (gg, qi, valid)
    # traffic replay + wavefront trace run clean on the same geometry
    spec = FlashGridSpec(seq_q=128, seq_kv=512, q_block=128, kv_block=128,
                         causal=True)
    rep = bwd_dkv_traffic(spec, order, snake_group=2)
    assert rep.write_bytes > 0
    sched = bwd_kv_schedule(order, 1, 4, causal=True,
                            q_block=128, kv_block=128, snake_group=2)
    trace = sched.flat_trace(2)
    assert sorted(t for tt, t in trace if tt == "dK") == [0, 1, 2, 3]
    assert [t for tt, t in trace if tt == "Q"] == [0]  # only tile 0 streams


def test_kv_range_matches_kv_order_under_window():
    s = KVSchedule("cyclic", n_q=8, n_kv=8, causal=True, window=256,
                   q_block=128, kv_block=128)
    for q in range(8):
        assert s.kv_range(q) == len(s.kv_order(q)), q


def test_wavefront_trace_block_snake_covers_everything():
    s = KVSchedule("block_snake", n_q=5, n_kv=6, causal=True,
                   q_block=64, kv_block=64, snake_group=2)
    touched = {}
    current = {}
    for w, tensor, tile in s.wavefront_trace(n_workers=3):
        if tensor == "Q":
            current[w] = tile
            touched.setdefault(tile, [])
        elif tensor == "K":
            touched[current[w]].append(tile)
    for q_tile, kvs in touched.items():
        assert sorted(kvs) == list(range(s.kv_range(q_tile))), (q_tile, kvs)
