"""MoE: routing invariants, capacity behavior, dropless == capacity@no-drop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import moe


def _setup(cap_factor=1.25, seed=0):
    cfg = get_config("olmoe-1b-7b").reduced()
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=cap_factor))
    p = moe.moe_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model))
    return cfg, p, x


def test_output_shape_and_finite():
    cfg, p, x = _setup()
    y, aux = moe.moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.0


def test_dropless_matches_high_capacity():
    """With capacity high enough that nothing drops, the two paths agree."""
    cfg, p, x = _setup(cap_factor=100.0)
    y_cap, _ = moe.moe_apply(p, cfg, x, dropless=False)
    y_free, _ = moe.moe_apply(p, cfg, x, dropless=True)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_free), atol=2e-4, rtol=2e-4)


def test_low_capacity_drops_but_stays_finite():
    cfg, p, x = _setup(cap_factor=0.25)
    y, aux = moe.moe_apply(p, cfg, x)
    assert np.isfinite(np.asarray(y)).all()


def test_capacity_respected():
    cfg, p, x = _setup()
    t = x.shape[0] * x.shape[1]
    cap = moe.expert_capacity(t, cfg)
    assert cap >= t * cfg.moe.top_k // cfg.moe.num_experts
    assert cap % 8 == 0


def test_token_permutation_equivariance_dropless():
    """Dropless MoE is a per-token map: permuting tokens permutes outputs."""
    cfg, p, x = _setup()
    xf = x.reshape(1, -1, x.shape[-1])
    perm = jax.random.permutation(jax.random.PRNGKey(9), xf.shape[1])
    y1, _ = moe.moe_apply(p, cfg, xf, dropless=True)
    y2, _ = moe.moe_apply(p, cfg, xf[:, perm], dropless=True)
    np.testing.assert_allclose(
        np.asarray(y1[:, perm]), np.asarray(y2), atol=2e-4, rtol=2e-4
    )


def test_grad_flows_through_router_and_experts():
    cfg, p, x = _setup()

    def loss(p):
        y, aux = moe.moe_apply(p, cfg, x)
        return (y**2).sum() + aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        leaf = g[name]["w"] if isinstance(g[name], dict) else g[name]
        assert float(jnp.abs(leaf).sum()) > 0.0, name
