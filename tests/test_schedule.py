import numpy as np
import pytest

from repro.core.schedule import KVSchedule, Order, kv_index, kv_index_host, num_kv_tiles_for


def test_cyclic_order():
    s = KVSchedule(Order.CYCLIC, n_q=3, n_kv=4)
    for i in range(3):
        assert s.kv_order(i) == [0, 1, 2, 3]


def test_sawtooth_alternates():
    s = KVSchedule(Order.SAWTOOTH, n_q=4, n_kv=5)
    assert s.kv_order(0) == [0, 1, 2, 3, 4]
    assert s.kv_order(1) == [4, 3, 2, 1, 0]
    assert s.kv_order(2) == [0, 1, 2, 3, 4]


def test_sawtooth_boundary_block_reuse():
    """The defining property: last block of pass i == first of pass i+1."""
    s = KVSchedule(Order.SAWTOOTH, n_q=6, n_kv=7)
    for i in range(5):
        assert s.kv_order(i)[-1] == s.kv_order(i + 1)[0]


def test_each_pass_is_a_permutation():
    for order in Order:
        s = KVSchedule(order, n_q=5, n_kv=9)
        for i in range(5):
            assert sorted(s.kv_order(i)) == list(range(9))


def test_causal_trimming():
    assert num_kv_tiles_for(0, 8, causal=True, q_block=64, kv_block=64) == 1
    assert num_kv_tiles_for(3, 8, causal=True, q_block=64, kv_block=64) == 4
    assert num_kv_tiles_for(7, 8, causal=False, q_block=64, kv_block=64) == 8
    # q blocks longer than kv blocks
    assert num_kv_tiles_for(1, 16, causal=True, q_block=128, kv_block=64) == 4


def test_traced_matches_host():
    import jax.numpy as jnp

    for order in Order:
        for i in range(4):
            for j in range(6):
                host = kv_index_host(order, i, j, 6)
                traced = int(kv_index(order, jnp.int32(i), jnp.int32(j), 6))
                assert host == traced


def test_wavefront_trace_covers_everything():
    s = KVSchedule(Order.SAWTOOTH, n_q=4, n_kv=3, causal=False)
    trace = list(s.wavefront_trace(n_workers=2))
    ks = [t for t in trace if t[1] == "K"]
    assert len(ks) == 4 * 3
    qs = [t for t in trace if t[1] == "Q"]
    assert sorted(t[2] for t in qs) == [0, 1, 2, 3]
    os_ = [t for t in trace if t[1] == "O"]
    assert len(os_) == 4


def test_worker_assignment_round_robin():
    s = KVSchedule(Order.CYCLIC, n_q=10, n_kv=2)
    a = s.worker_assignments(3)
    assert a[0] == [0, 3, 6, 9] and a[1] == [1, 4, 7] and a[2] == [2, 5, 8]


# --------------------------------------------------------------------------
# wavefront_trace edge cases
# --------------------------------------------------------------------------


def _kv_tiles_touched(trace):
    """q_tile -> list of KV tile ids in visit order, from a wavefront trace."""
    per_worker_q = {}
    touched = {}
    for w, tensor, tile in trace:
        if tensor == "Q":
            per_worker_q[w] = tile
            touched.setdefault(tile, [])
        elif tensor == "K":
            touched[per_worker_q[w]].append(tile)
    return touched


@pytest.mark.parametrize("order", list(Order))
def test_wavefront_trace_causal_partial_last_tile(order):
    """seq=200 @ 64-row tiles -> 4 tiles, the last one partial: causal
    trimming must still give q tile i exactly i+1 KV tiles, each visited
    once, covering 0..i."""
    s = KVSchedule(order, n_q=4, n_kv=4, causal=True, q_block=64, kv_block=64)
    touched = _kv_tiles_touched(s.wavefront_trace(n_workers=3))
    assert sorted(touched) == [0, 1, 2, 3]
    for q_tile, kvs in touched.items():
        assert sorted(kvs) == list(range(q_tile + 1)), (q_tile, kvs)
    # K accesses == sum of trimmed ranges, not n_q * n_kv
    assert sum(len(v) for v in touched.values()) == 1 + 2 + 3 + 4


@pytest.mark.parametrize("n_workers", [5, 8, 64])
def test_wavefront_trace_more_workers_than_q_tiles(n_workers):
    """Workers beyond n_q have empty assignments; the trace must terminate
    and still cover every (q, kv) pair exactly once."""
    s = KVSchedule(Order.SAWTOOTH, n_q=3, n_kv=4)
    trace = list(s.wavefront_trace(n_workers=n_workers))
    touched = _kv_tiles_touched(trace)
    assert sorted(touched) == [0, 1, 2]
    assert all(sorted(v) == [0, 1, 2, 3] for v in touched.values())
    assert {w for (w, _, _) in trace} == set(range(3))  # idle workers silent


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n_workers", [1, 2, 7])
def test_wavefront_trace_length_order_invariant(causal, n_workers):
    """Reordering is a pure permutation: sawtooth and cyclic traces have
    identical length and identical per-tensor access counts."""
    traces = {
        order: list(
            KVSchedule(
                order, n_q=5, n_kv=6, causal=causal, q_block=64, kv_block=64
            ).wavefront_trace(n_workers)
        )
        for order in Order
    }
    a, b = traces[Order.CYCLIC], traces[Order.SAWTOOTH]
    assert len(a) == len(b)
    for tensor in ("Q", "K", "V", "O"):
        na = sorted(t[2] for t in a if t[1] == tensor)
        nb = sorted(t[2] for t in b if t[1] == tensor)
        assert na == nb, tensor


# --------------------------------------------------------------------------
# transposed (dK/dV backward) schedule
# --------------------------------------------------------------------------


def test_bwd_schedule_transposes_forward_coverage():
    """(q, kv) pair coverage of the bwd grid == transpose of the fwd grid."""
    from repro.core.schedule import bwd_kv_schedule

    fwd = KVSchedule(Order.SAWTOOTH, n_q=6, n_kv=6, causal=True, q_block=64, kv_block=64)
    bwd = fwd.bwd()
    fwd_pairs = {(i, kv) for i in range(6) for kv in fwd.kv_order(i)}
    bwd_pairs = {(qt, j) for j in range(6) for qt in bwd.q_order(j)}
    assert fwd_pairs == bwd_pairs
    # factory form builds the same schedule
    assert bwd == bwd_kv_schedule(
        "sawtooth", 6, 6, causal=True, q_block=64, kv_block=64
    )


def test_bwd_schedule_causal_trims_low_end():
    from repro.core.schedule import q_tile_bounds_for

    # causal: kv tile j is invisible to q tiles below it
    for j in range(8):
        lo, hi = q_tile_bounds_for(j, 8, causal=True, window=None, q_block=64, kv_block=64)
        assert (lo, hi) == (j, 7)
    # rectangular blocks: q tiles twice the kv tiles
    lo, hi = q_tile_bounds_for(5, 4, causal=True, window=None, q_block=128, kv_block=64)
    assert (lo, hi) == (2, 3)
    # sliding window trims the high end
    lo, hi = q_tile_bounds_for(0, 8, causal=True, window=128, q_block=64, kv_block=64)
    assert (lo, hi) == (0, 2)  # rows < 64 + 128 - 1 see kv tile 0


def test_bwd_schedule_sawtooth_boundary_reuse():
    """Transposed defining property: last q tile of resident sweep t is the
    first q tile of sweep t+1 (when the trimmed ranges allow)."""
    from repro.core.schedule import bwd_kv_schedule

    s = bwd_kv_schedule("sawtooth", 7, 6)
    for j in range(5):
        assert s.q_order(j)[-1] == s.q_order(j + 1)[0]


@pytest.mark.parametrize("n_workers", [1, 2, 5])
@pytest.mark.parametrize("causal", [False, True])
def test_bwd_wavefront_trace_covers_everything(n_workers, causal):
    from repro.core.schedule import bwd_kv_schedule

    s = bwd_kv_schedule("sawtooth", 5, 4, causal=causal, q_block=64, kv_block=64)
    trace = list(s.wavefront_trace(n_workers))
    # resident K/V emitted once per kv tile; dK/dV written once per kv tile
    for t in ("K", "V", "dK", "dV"):
        assert sorted(tile for (_, tt, tile) in trace if tt == t) == [0, 1, 2, 3], t
    # Q stream covers exactly the trimmed transposed ranges
    per_kv: dict[int, list[int]] = {}
    current = {}
    for w, tt, tile in trace:
        if tt == "K":
            current[w] = tile
            per_kv.setdefault(tile, [])
        elif tt == "Q":
            per_kv[current[w]].append(tile)
    for j, qs in per_kv.items():
        lo, hi = s.q_bounds(j)
        assert sorted(qs) == list(range(lo, hi + 1)), (j, qs)


def test_bwd_worker_assignments_round_robin_over_kv_tiles():
    from repro.core.schedule import bwd_kv_schedule

    s = bwd_kv_schedule("cyclic", 4, 10)
    a = s.worker_assignments(3)
    assert a[0] == [0, 3, 6, 9] and a[1] == [1, 4, 7] and a[2] == [2, 5, 8]


def test_bwd_trace_length_order_invariant():
    from repro.core.schedule import bwd_kv_schedule

    traces = {
        order: bwd_kv_schedule(
            order, 6, 5, causal=True, q_block=64, kv_block=64
        ).flat_trace(2)
        for order in Order
    }
    a, b = traces[Order.CYCLIC], traces[Order.SAWTOOTH]
    assert len(a) == len(b)
    for tensor in ("Q", "dO", "K", "V", "dK", "dV"):
        assert sorted(t for tt, t in a if tt == tensor) == sorted(
            t for tt, t in b if tt == tensor
        ), tensor


def test_page_visit_order_matches_kv_index():
    import numpy as np

    from repro.core.schedule import KVSchedule, kv_index_host, page_visit_order

    n = 5
    for order in ("cyclic", "sawtooth"):
        got = np.asarray(page_visit_order(order, np.arange(4), n))
        want = np.asarray(
            [[kv_index_host(order, p, j, n) for j in range(n)] for p in range(4)]
        )
        np.testing.assert_array_equal(got, want)
    # KVSchedule.page_order is the same arithmetic behind the schedule object
    sched = KVSchedule("sawtooth", n_q=1, n_kv=n)
    np.testing.assert_array_equal(
        np.asarray(sched.page_order(np.arange(4))),
        np.asarray(page_visit_order("sawtooth", np.arange(4), n)),
    )
    # odd parity reverses, even is forward
    row = np.asarray(page_visit_order("sawtooth", np.asarray([1]), n))[0]
    np.testing.assert_array_equal(row, np.arange(n)[::-1])
