import os
import sys

# `repro` comes from pyproject's pythonpath = ["src"] pytest config; tests
# see 1 CPU device (the 512-device flag belongs to the dry-run ONLY —
# assignment rule).

try:  # property tests use hypothesis; fall back to the bundled stub
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def local_mesh():
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(1, 1)
