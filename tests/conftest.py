import os
import sys

# Make `repro` importable without installation; tests see 1 CPU device
# (the 512-device flag belongs to the dry-run ONLY — assignment rule).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def local_mesh():
    from repro.launch.mesh import make_local_mesh

    return make_local_mesh(1, 1)
