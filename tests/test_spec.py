"""Speculative decoding on the unified ragged step (DESIGN.md §14).

* Kernel-level verification parity: a q_len=K+1 chunk through the ragged
  paged attention (reference and interpret-mode kernel) must equal K+1
  sequential q_len=1 decode steps over the same pools — across traversal
  orders, SWA windows, GQA grouping, and shuffled block tables.
* Engine stream parity: speculative-on (n-gram and draft-model drafters)
  must produce bitwise the non-speculative engine's streams — greedy AND
  sampled (the per-accepted-token PRNG stream accounting), across
  traversal orders and int8 KV pages — with exactly two compiled step
  widths and draft/accept/rollback counter conservation.
* ``PagedKVPool.rollback``: reservation restore under "reserve",
  page free under "optimistic", the shared-page (refcount > 1) guard, and
  the prefix-registry refresh (a rolled-back tail must never be adoptable)
  — plus the extended ``check_invariants`` that pins the registry rule.
* Scheduler: ``plan_step(draft_lens)`` clamping (chunk width, token
  budget, decode-row guarantee).
* Hypothesis random walks: accept/rollback ops against pool invariants on
  the plain pool, and interleaved with tiering spill/resume suspensions.
* Drafters: n-gram copy-from-lag extrapolation; draft-model
  self-speculation accepting ~everything on greedy streams.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.attention import mha_reference, paged_decode_attention
from repro.core.schedule import Order
from repro.kernels.flash_decode import paged_flash_decode_fwd
from repro.models import build_model
from repro.serve import (
    ContinuousScheduler,
    FaultPlan,
    ModelDrafter,
    NgramDrafter,
    PagedKVPool,
    PoolError,
    Request,
    ServeEngine,
    TieredPagePool,
    make_drafter,
)

SETTINGS = settings(max_examples=15, deadline=None)


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


# ---- kernel-level chunk-vs-sequential verification parity -------------------


def _verify_problem(seed=0, b=3, hq=8, hkv=2, d=16, page=8, nb=4, c=6):
    """Ragged verification step: GQA heads, shuffled block tables, one
    decode row (q_len 1) next to two verification chunks (q_len 6 and 4)."""
    rng = np.random.default_rng(seed)
    n_pages = b * nb + 1
    kp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, d)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, d)).astype(np.float32))
    perm = rng.permutation(np.arange(1, n_pages))[: b * nb].reshape(b, nb)
    bt = jnp.asarray(perm, jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)).astype(np.float32))
    lens = jnp.asarray([9, 21, nb * page], jnp.int32)  # valid KV incl chunk
    qls = jnp.asarray([1, c, 4], jnp.int32)
    return q, kp, vp, bt, lens, qls


@pytest.mark.parametrize("order", list(Order))
@pytest.mark.parametrize("window", [None, 11])
def test_verification_chunk_matches_sequential_decode(order, window):
    """One q_len=K+1 chunk == K+1 sequential q_len=1 steps, per position.

    The speculative path's whole correctness story: verifying K draft
    tokens as one ragged chunk must score exactly what K+1 one-token decode
    steps over the same pools would score. Checked for the reference ragged
    attention AND the interpret-mode flash kernel, across traversal orders
    (the online-softmax page order must not leak into the result), SWA
    windows, GQA grouping, and shuffled block tables."""
    q, kp, vp, bt, lens, qls = _verify_problem()
    kw = dict(order=order, window=window)
    if order is Order.BLOCK_SNAKE:
        kw["snake_group"] = 2
    chunk_ref = np.asarray(
        paged_decode_attention(q, kp, vp, lens, bt, q_lens=qls, **kw)
    )
    chunk_kern = np.asarray(
        paged_flash_decode_fwd(
            q, kp, vp, lens, bt, q_lens=qls, interpret=True, **kw
        )
    )
    for i in range(q.shape[0]):
        for t in range(int(qls[i])):
            # Sequential stand-in: the chunk's position t as a plain
            # one-token decode at the KV length it would see.
            pos_len = jnp.asarray(
                [int(lens[i]) - int(qls[i]) + t + 1], jnp.int32
            )
            seq = np.asarray(
                paged_decode_attention(
                    q[i : i + 1, t : t + 1],
                    kp,
                    vp,
                    pos_len,
                    bt[i : i + 1],
                    q_lens=jnp.asarray([1], jnp.int32),
                    **kw,
                )
            )[0, 0]
            np.testing.assert_allclose(chunk_ref[i, t], seq, atol=2e-5)
            np.testing.assert_allclose(chunk_kern[i, t], seq, atol=2e-5)


# ---- engine stream parity ----------------------------------------------------


def _spec_requests(max_new=32, temperature=0.0, seeds=(5, 8)):
    """The decode-heavy repetitive stream the bench asserts on: short
    cyclic prompts whose greedy continuations prompt-lookup can draft."""
    reqs = []
    for i, s in enumerate(seeds):
        rng = np.random.default_rng(s)
        toks = np.tile(rng.integers(5, 20, size=4), 6).astype(np.int32)
        reqs.append(
            Request(
                tokens=toks,
                max_new_tokens=max_new,
                temperature=temperature,
                rid=i,
                seed=i,
            )
        )
    return reqs


def _engine(lm, params, drafter=None, draft_len=4, **kw):
    return ServeEngine(
        lm,
        params,
        batch_size=2,
        max_len=128,
        scheduler="continuous",
        page_size=8,
        prefill_chunk=8,
        drafter=drafter,
        draft_len=draft_len,
        **kw,
    )


def _assert_conservation(eng):
    v = eng.obs.value
    drafted = v("serve.spec.draft_tokens")
    assert drafted > 0, "speculative engine never drafted"
    assert v("serve.spec.accepted_tokens") + v("serve.spec.rollback_tokens") == drafted


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("kind", ["ngram", "model"])
def test_engine_stream_parity(deepseek_lm, kind, temperature):
    """Speculative-on == speculative-off, bitwise, greedy and sampled.

    Sampled parity is the PRNG satellite: the engine folds (seed, sample
    index) once per *accepted* position, so the K+1 keys of a verification
    chunk are exactly the keys K+1 sequential steps would have drawn."""
    lm, params = deepseek_lm
    base = _engine(lm, params).generate(_spec_requests(temperature=temperature))
    drafter = make_drafter(
        kind,
        lm=lm,
        params=params,
        n_slots=2,
        max_len=128,
        page_size=8,
        prefill_chunk=8,
    )
    eng = _engine(lm, params, drafter=drafter)
    got = eng.generate(_spec_requests(temperature=temperature))
    for a, b in zip(base, got):
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"
    _assert_conservation(eng)
    assert eng.compiled_step_count() == 2


@pytest.mark.parametrize("order", ["sawtooth", "block_snake"])
def test_engine_parity_across_orders(order):
    """The verification chunk rides the same traced ``order_group`` operand
    as plain decode — parity must hold under every traversal order."""
    cfg = get_config("deepseek-7b").reduced().with_(
        attn_order=order, snake_group=2 if order == "block_snake" else None
    )
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    base = _engine(lm, params).generate(_spec_requests(max_new=24))
    eng = _engine(lm, params, drafter=NgramDrafter(ngram_max=4))
    got = eng.generate(_spec_requests(max_new=24))
    for a, b in zip(base, got):
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"
    _assert_conservation(eng)
    assert eng.compiled_step_count() == 2


def test_engine_parity_int8_pages():
    """Quantized KV pages quantize identically whether written by a
    verification chunk or sequential decode steps — streams stay bitwise."""
    cfg = get_config("deepseek-7b").reduced().with_(kv_cache_dtype="int8")
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    base = _engine(lm, params).generate(_spec_requests(max_new=24))
    eng = _engine(lm, params, drafter=NgramDrafter(ngram_max=4))
    got = eng.generate(_spec_requests(max_new=24))
    for a, b in zip(base, got):
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"
    _assert_conservation(eng)


@pytest.mark.parametrize("draft_len", [2, 7])
def test_speculative_keeps_two_compiled_steps(deepseek_lm, draft_len):
    """The regression pin: verification chunks pad into the *prefill*
    width, so a speculative run — prefill chunks, full K+1 verification
    chunks, clamped tails, plain decode steps — compiles exactly the same
    two step variants as a non-speculative one. A third compiled width
    here means the padding contract broke."""
    lm, params = deepseek_lm
    eng = _engine(
        lm, params, drafter=NgramDrafter(ngram_max=4), draft_len=draft_len
    )
    eng.generate(_spec_requests())
    assert eng.compiled_step_count() == 2
    # A second stream through the same engine reuses both traces.
    eng.generate(_spec_requests(max_new=16))
    assert eng.compiled_step_count() == 2


def test_chaos_step_fault_mid_verification(deepseek_lm):
    """A transient device-step failure mid-verification retries once via
    the resilience path; drafts of the failed step are re-verified and the
    stream is bitwise unchanged, with conservation intact."""
    lm, params = deepseek_lm
    base = _engine(lm, params).generate(_spec_requests())
    plan = FaultPlan(seed=0).fail_device_step(6)
    eng = _engine(lm, params, drafter=NgramDrafter(ngram_max=4), faults=plan)
    got = eng.generate(_spec_requests())
    assert eng.obs.value("serve.step_retries") == 1
    for a, b in zip(base, got):
        assert np.array_equal(a.tokens, b.tokens), f"rid {a.rid} diverged"
    _assert_conservation(eng)
    eng.last_pool.check_invariants()


# ---- pool rollback -----------------------------------------------------------


def _pool(admission="reserve", n_slots=3, max_len=32, **kw):
    cfg = get_config("deepseek-7b").reduced().with_(
        kv_layout="paged", page_size=4
    )
    return PagedKVPool(
        cfg, 1, n_slots, max_len=max_len, admission=admission, **kw
    )


def _grow(pool, slot, n):
    pool.ensure_writable(slot, n)
    pool.advance(slot, n)


def test_rollback_reserve_restores_reservation():
    pool = _pool("reserve", n_slots=1, max_len=16)  # capacity 16 = 4 pages
    prompt = np.arange(2, 8, dtype=np.int32)  # 6 tokens
    assert pool.admit(0, prompt, 10) == 0
    _grow(pool, 0, 6)
    _grow(pool, 0, 9)  # 15 tokens, 4 pages held
    held = len(pool._slot_pages[0])
    freed = pool.rollback(0, 7)  # back to 8 tokens = 2 pages
    assert int(pool.lens[0]) == 8
    assert freed == held - 2 and len(pool._slot_pages[0]) == 2
    # Freed pages return to the reservation: regrowth over the same
    # positions cannot fail (the "reserve" guarantee survives rollback).
    _grow(pool, 0, 8)
    assert int(pool.lens[0]) == 16
    pool.check_invariants()


def test_rollback_optimistic_frees_pages():
    pool = _pool("optimistic", n_slots=2, max_len=16, n_pages=6)
    assert pool.admit(0, np.arange(2, 6, dtype=np.int32), 12) == 0
    _grow(pool, 0, 4)
    _grow(pool, 0, 11)  # 15 tokens = 4 pages
    free_before = pool.alloc.free_count
    freed = pool.rollback(0, 10)  # 5 tokens = 2 pages
    assert freed == 2
    assert pool.alloc.free_count == free_before + 2
    assert int(pool.lens[0]) == 5
    pool.check_invariants()


def test_rollback_refuses_shared_pages():
    """Dropping a refcount>1 page means the caller is rolling back adopted
    prefix content, not self-written drafts — PoolError, state untouched."""
    pool = _pool("reserve", n_slots=2, max_len=16)
    # 9 tokens: two full (registrable) pages + a one-token tail, so the
    # adopter's own writes land on its private tail page and the adopted
    # pages stay shared (no CoW fork in the way of the guard).
    prompt = np.append(
        np.tile(np.arange(2, 6, dtype=np.int32), 2), np.int32(6)
    )
    assert pool.admit(0, prompt, 4) is not None
    _grow(pool, 0, 9)
    pool.register_prompt(0, prompt)
    adopted = pool.admit(1, prompt, 4)  # adopts the two registered pages
    assert adopted and adopted >= 8
    _grow(pool, 1, len(prompt) - int(pool.lens[1]) + 2)  # past the prompt
    assert any(pool._ref[pid] > 1 for pid in pool._slot_pages[1])
    lens_before = int(pool.lens[1])  # 11: pages [shared, shared, own]
    assert pool.rollback(1, 2) == 0  # own-page rollback is fine
    with pytest.raises(PoolError, match="shared page"):
        pool.rollback(1, int(pool.lens[1]) - 4)  # would drop a shared page
    assert int(pool.lens[1]) == lens_before - 2
    pool.check_invariants()


def test_rollback_refreshes_prefix_registry():
    """A rollback cutting into a registered page unregisters it — a later
    same-content admit must NOT adopt a page whose tail held rejected
    draft KV — and ``check_invariants`` pins exactly that rule."""
    pool = _pool("reserve", n_slots=2, max_len=32)
    prompt = np.tile(np.arange(2, 6, dtype=np.int32), 3)  # 12 tokens, 3 pages
    assert pool.admit(0, prompt, 12) == 0
    _grow(pool, 0, 12)
    pool.register_prompt(0, prompt)
    registered = [
        pid for pid in pool._slot_pages[0] if pid in pool._page_parent
    ]
    assert len(registered) == 3
    # Roll back into the last prompt page (len 12 -> 10): its registered
    # content now extends past the live len over self-written positions.
    assert pool.rollback(0, 2) == 0  # no page freed (10 tokens still 3 pages)
    assert registered[-1] not in pool._page_parent, (
        "rolled-back tail still adoptable"
    )
    assert registered[0] in pool._page_parent  # untouched pages stay shared
    pool.check_invariants()
    # A same-prefix admit now adopts only the still-valid pages: 8 tokens
    # (two pages), never the rolled-back third.
    assert pool.admit(1, prompt, 4) == 8
    shared = sum(1 for pid in pool._slot_pages[1] if pool._ref[pid] > 1)
    assert shared == 2
    pool.check_invariants()


def test_check_invariants_catches_registry_overhang():
    """The new invariant actually fires: force the illegal state (a
    registered page covering rolled-back self-written positions) by
    bypassing ``rollback``'s refresh and expect the assertion."""
    pool = _pool("reserve", n_slots=1, max_len=16)
    prompt = np.tile(np.arange(2, 6, dtype=np.int32), 2)  # 8 tokens, 2 pages
    assert pool.admit(0, prompt, 8) == 0
    _grow(pool, 0, 8)
    pool.register_prompt(0, prompt)
    pool.check_invariants()
    pool.lens[0] = 6  # raw len cut, no registry refresh: now invalid
    with pytest.raises(AssertionError):
        pool.check_invariants()


def test_rollback_noop_and_clamp():
    pool = _pool("reserve", n_slots=1, max_len=16)
    assert pool.admit(0, np.arange(2, 6, dtype=np.int32), 8) == 0
    _grow(pool, 0, 4)
    assert pool.rollback(0, 0) == 0
    assert pool.rollback(0, -3) == 0
    pool.rollback(0, 99)  # clamped to the live len
    assert int(pool.lens[0]) == 0
    pool.check_invariants()


# ---- scheduler draft planning ------------------------------------------------


def test_plan_step_clamps_draft_lens():
    """Draft upgrades are best-effort: clamped to the wide width
    (prefill_chunk - 1) and to the budget spare after every decode row's
    guaranteed token, so speculation can never evict a decode row."""
    sched = ContinuousScheduler(4, token_budget=8, prefill_chunk=4)
    prompt = np.arange(2, 6, dtype=np.int32)
    for i in range(3):
        # prompt_pos == len(prompt): past prefill, i.e. a decode row.
        sched.place(
            i,
            Request(tokens=prompt, rid=i),
            eos_id=1,
            new_limit=8,
            prompt=prompt,
            prompt_pos=len(prompt),
        )
    plan = sched.plan_step({0: 10, 1: 2, 2: 1})
    by_slot = {it.slot: it for it in plan}
    # Slot 0 wants 10: chunk clamps to 3, budget spare (8 - 3 rows = 5)
    # allows it. Slot 1 gets the remaining spare (2), slot 2 gets 0.
    assert by_slot[0].q_len == 4 and by_slot[0].n_draft == 3
    assert by_slot[1].q_len == 3 and by_slot[1].n_draft == 2
    assert by_slot[2].q_len == 1 and by_slot[2].n_draft == 0
    assert sum(it.q_len for it in plan) <= 8
    # No draft_lens -> plain decode plan, bit-identical to the old planner.
    plain = sched.plan_step()
    assert all(it.q_len == 1 and it.n_draft == 0 for it in plain)


# ---- hypothesis random walks -------------------------------------------------


@SETTINGS
@given(seed=st.integers(0, 10_000))
def test_accept_rollback_walk_preserves_invariants(seed):
    """Random admit/grow/rollback/release walk with a host-side ledger:
    rollback only ever covers self-written tokens (the engine's contract),
    lens track the ledger exactly, and ``check_invariants`` holds after
    every op — including the registry rule the walk exercises by
    registering every finished prompt."""
    rng = np.random.default_rng(seed)
    admission = "reserve" if seed % 2 else "optimistic"
    pool = _pool(admission, n_slots=3, max_len=32)
    live: dict[int, dict] = {}  # slot -> {len, written (self), total}
    for _ in range(60):
        op = rng.integers(0, 5)
        free = [s for s in range(3) if s not in live]
        if op == 0 and free:
            slot = int(rng.choice(free))
            plen = int(rng.integers(1, 12))
            prompt = rng.integers(2, 5, size=plen).astype(np.int32)
            max_new = int(rng.integers(1, 12))
            if pool.admit(slot, prompt, max_new) is not None:
                live[slot] = {
                    "len": int(pool.lens[slot]),
                    "written": 0,
                    "total": min(plen + max_new, pool.capacity),
                    "prompt": prompt,
                }
        elif op == 1 and live:  # grow (prefill or accepted decode tokens)
            slot = int(rng.choice(list(live)))
            room = live[slot]["total"] - live[slot]["len"]
            n = min(int(rng.integers(1, 6)), room)
            if n <= 0:
                continue
            _grow(pool, slot, n)
            live[slot]["len"] += n
            live[slot]["written"] += n
            if live[slot]["len"] == len(live[slot]["prompt"]):
                pool.register_prompt(slot, live[slot]["prompt"])
        elif op == 2 and live:  # reject drafts: roll back self-written only
            slot = int(rng.choice(list(live)))
            n = min(int(rng.integers(1, 6)), live[slot]["written"])
            if n <= 0:
                continue
            pool.rollback(slot, n)
            live[slot]["len"] -= n
            live[slot]["written"] -= n
        elif op == 3 and live:
            slot = int(rng.choice(list(live)))
            del live[slot]
            pool.release(slot)
        pool.check_invariants()
        for slot, led in live.items():
            assert int(pool.lens[slot]) == led["len"]
    for slot in list(live):
        pool.release(slot)
    pool.check_invariants()
    assert pool.alloc.free_count == pool.alloc.n_pages - 1


@SETTINGS
@given(seed=st.integers(0, 10_000))
def test_rollback_interleaves_with_tiering_walk(seed):
    """Accept/rollback interleaved with spill/resume: a slot can be
    spilled mid-stream, resumed, and immediately rolled back (rejected
    drafts re-verified after restore) — both tiers' invariants and the
    ledger must survive every interleaving."""
    rng = np.random.default_rng(seed)
    cfg = get_config("deepseek-7b").reduced().with_(
        kv_layout="paged", page_size=4
    )
    pool = TieredPagePool(
        cfg, 1, 3, max_len=32, admission="optimistic",
        n_pages=13, host_pages=12,
    )
    live: dict[int, dict] = {}
    for _ in range(70):
        op = rng.integers(0, 6)
        free = [s for s in range(3) if s not in live]
        active = [s for s in live if not pool.is_suspended(s)]
        if op == 0 and free:
            slot = int(rng.choice(free))
            plen = int(rng.integers(1, 12))
            prompt = rng.integers(2, 5, size=plen).astype(np.int32)
            if pool.admit(slot, prompt, int(rng.integers(1, 10))) is not None:
                live[slot] = {"len": int(pool.lens[slot]), "written": 0}
        elif op == 1 and active:  # grow, spill a victim on pressure
            slot = int(rng.choice(active))
            n = int(rng.integers(1, 5))
            if live[slot]["len"] + n > pool.capacity:
                continue
            try:
                pool.ensure_writable(slot, n)
            except Exception:  # PoolExhausted: spill or drop a victim
                victim = next((v for v in active if pool.can_spill(v)), None)
                if victim is not None:
                    assert pool.spill_slot(victim)
                else:
                    victim = active[0]
                    del live[victim]
                    pool.release(victim)
                pool.check_invariants()
                continue
            pool.advance(slot, n)
            live[slot]["len"] += n
            live[slot]["written"] += n
        elif op == 2 and active:  # reject drafts on a live device slot
            slot = int(rng.choice(active))
            n = min(int(rng.integers(1, 6)), live[slot]["written"])
            if n <= 0:
                continue
            pool.rollback(slot, n)
            live[slot]["len"] -= n
            live[slot]["written"] -= n
        elif op == 3 and active:
            slot = int(rng.choice(active))
            if pool.can_spill(slot):
                assert pool.spill_slot(slot)
        elif op == 4:  # resume progress (then rollback becomes legal again)
            sus = pool.suspended_slots()
            if not sus:
                continue
            slot = int(rng.choice(sus))
            if not pool._suspended[slot].started:
                pool.start_resume(slot)
            pool.issue_fetches(slot, int(rng.integers(1, 4)))
            if pool.resume_ready(slot):
                pool.complete_resume(slot)  # may refuse under pressure
        elif op == 5 and live:
            slot = int(rng.choice(list(live)))
            del live[slot]
            pool.release(slot)
        pool.check_invariants()
        for slot, led in live.items():
            assert int(pool.lens[slot]) == led["len"]
    for slot in list(live):
        pool.release(slot)
    pool.check_invariants()


# ---- drafters ----------------------------------------------------------------


def test_ngram_drafter_copy_from_lag():
    """Prompt-lookup with copy-from-lag: after the n-gram match the
    drafter extends by copying at the matched lag *including its own
    drafts*, so a period-4 stream yields K tokens of continuation, not
    just the suffix that happened to exist in the context."""
    d = NgramDrafter(ngram_max=4)
    ctx = np.tile(np.arange(1, 5, dtype=np.int32), 3)  # 1 2 3 4 x3
    assert d.draft(0, ctx, 6) == [1, 2, 3, 4, 1, 2]
    # Lag extrapolation reaches past one period indefinitely.
    assert d.draft(0, ctx, 10) == [1, 2, 3, 4, 1, 2, 3, 4, 1, 2]
    # No repeated n-gram in the context -> no draft, never a guess.
    assert d.draft(0, np.arange(1, 9, dtype=np.int32), 4) == []
    # Too-short context drafts nothing.
    assert d.draft(0, np.asarray([7], dtype=np.int32), 4) == []


def test_model_drafter_self_speculation_accepts_everything(deepseek_lm):
    """Self-speculation (draft model == target): on a greedy stream with
    no EOS truncation every drafted token matches the target's argmax, so
    acceptance is ~100% and the engine's step count collapses."""
    lm, params = deepseek_lm
    base = _engine(lm, params)
    res0 = base.generate(_spec_requests())
    steps0 = base.last_stats.mixed_steps
    eng = _engine(
        lm,
        params,
        drafter=ModelDrafter(
            lm, params, n_slots=2, max_len=128, page_size=8, prefill_chunk=8
        ),
        draft_len=7,
    )
    res1 = eng.generate(_spec_requests())
    for a, b in zip(res0, res1):
        assert np.array_equal(a.tokens, b.tokens)
    st_ = eng.last_stats
    assert st_.draft_tokens > 0
    assert st_.acceptance_rate >= 0.99
    assert st_.mixed_steps < steps0 / 2
