"""The paper's analytic L2 model (§3.2–3.3) — validated against the paper's
own published counter values and against the exact tiled count."""

import pytest

from repro.core.cache_model import (
    GB10,
    AttentionWorkload,
    attention_flops,
    cold_miss_sectors,
    divergence_seq_len,
    gb10_throughput_model,
    kv_bytes,
    l2_sector_accesses,
    l2_sector_accesses_simple,
)

# Paper Table 1 (SM=48, T=80, D=64, fp16): measured L2 total sectors.
PAPER_TABLE1 = {32 * 1024: 107_729_467, 128 * 1024: 1_723_556_561}


@pytest.mark.parametrize("seq,measured", sorted(PAPER_TABLE1.items()))
def test_model_matches_paper_table1(seq, measured):
    w = AttentionWorkload(seq_len=seq, tile=80)
    predicted = l2_sector_accesses(w, GB10)
    mape = abs(predicted - measured) / measured
    # Paper Table 3 reports <0.46% MAPE for the non-causal model.
    assert mape < 0.006, (seq, predicted, measured, mape)


def test_simple_form_matches_paper_closed_form():
    # M ~= 8S(1 + S/T) with C=32, E=2, D=64 (paper §3.2)
    for s in (8192, 32768, 131072):
        w = AttentionWorkload(seq_len=s, tile=80)
        assert l2_sector_accesses_simple(w, GB10) == pytest.approx(8 * s * (1 + s / 80))


def test_causal_roughly_half_noncausal():
    w_nc = AttentionWorkload(seq_len=65536, tile=64, causal=False)
    w_c = AttentionWorkload(seq_len=65536, tile=64, causal=True)
    ratio = l2_sector_accesses(w_c, GB10) / l2_sector_accesses(w_nc, GB10)
    assert 0.45 < ratio < 0.55


def test_cold_miss_is_16s():
    w = AttentionWorkload(seq_len=32768, tile=80)
    assert cold_miss_sectors(w, GB10) == 16 * 32768


def test_divergence_near_80k():
    # Paper: divergence observed at ~80K (KV=20MiB vs 24MiB L2). The pure
    # KV-capacity bound gives 96K; Q/O residency accounts for the gap, so the
    # bound must sit between the observed point and a loose 1.5x.
    w = AttentionWorkload(seq_len=1, tile=80)
    s = divergence_seq_len(GB10, w)
    assert 80_000 <= s <= 120_000


def test_batch_heads_scale_linearly():
    w1 = AttentionWorkload(seq_len=16384, tile=64)
    w8 = AttentionWorkload(seq_len=16384, tile=64, batch=4, heads=2)
    assert l2_sector_accesses(w8, GB10) == 8 * l2_sector_accesses(w1, GB10)


def test_throughput_model_monotone_in_misses():
    from repro.core.cache_model import calibrate_miss_service

    w = AttentionWorkload(seq_len=131072, tile=64, batch=8)
    svc = calibrate_miss_service(w, GB10, observed_flops=61e12, miss_sectors=370e6)
    hi = gb10_throughput_model(w, GB10, miss_sectors=370e6, miss_service_s=svc)
    lo = gb10_throughput_model(w, GB10, miss_sectors=120e6, miss_service_s=svc)
    assert lo > hi  # fewer misses -> more throughput
    assert hi == pytest.approx(61e12, rel=1e-6)  # calibration reproduces baseline
    assert attention_flops(w) > 0
    assert kv_bytes(w) == 8 * 2 * 131072 * 64 * 2


def test_throughput_model_reproduces_cutile_regime():
    """Calibrate on the paper's cyclic CuTile numbers, predict sawtooth."""
    from repro.core.cache_model import calibrate_miss_service

    w = AttentionWorkload(seq_len=131072, tile=64, head_dim=64, batch=8)
    # paper §4.3.1: 370M -> 120M miss sectors, 61 -> 69 TFLOPS (non-causal).
    # kernel_peak=74 TFLOPS is the CuTile kernel's calibrated compute ceiling
    # (EXPERIMENTS.md §Paper-validation); svc from the cyclic baseline only.
    svc = calibrate_miss_service(
        w, GB10, observed_flops=61e12, miss_sectors=370e6, kernel_peak=74e12
    )
    predicted = gb10_throughput_model(
        w, GB10, miss_sectors=120e6, miss_service_s=svc, kernel_peak=74e12
    )
    assert 66e12 < predicted < 72e12, predicted / 1e12  # paper: ~69 TFLOPS
