"""Online traversal-order adaptation (repro.serve.adapt + repro.obs.autotune).

* the dynamic ``order_group`` operand: ``page_visit_order_dynamic`` is
  bitwise-identical to the static ``KVSchedule.page_order`` for every order
  family × group size, so switching the operand can never change math;
* controller decision logic: hysteresis threshold, consecutive-sample
  confirmation, pending-candidate resets, epoch gating, metrics surface;
* the autotune cache: key canonicalization (shared writer/reader helper),
  JSONL load with last-writer-wins dedup and unknown-schema tolerance,
  nearest-bucket winner lookup, and controller seeding from it;
* engine integration: a forced mid-stream order switch produces a
  bitwise-identical token stream to both pinned orders and does not add a
  single compiled step (the zero-recompile guarantee the operand design
  exists for).
"""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.schedule import (
    KVSchedule,
    Order,
    page_visit_order_dynamic,
    resolve_order_group,
)
from repro.models import build_model
from repro.obs import Registry
from repro.obs.autotune import (
    canonicalize_key,
    load_autotune_cache,
    lookup_order_winner,
    normalize_autotune_key,
)
from repro.serve import ORDER_INDEX, OrderAdaptController, Request, ServeEngine
from repro.serve.adapt import DEFAULT_SNAKE_GROUP


@pytest.fixture(scope="module")
def deepseek():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return cfg, lm, lm.init(jax.random.PRNGKey(0))


# ---- dynamic visit order == static schedule ---------------------------------


@pytest.mark.parametrize("order,group", [
    ("cyclic", None), ("sawtooth", None),
    ("block_snake", 1), ("block_snake", 2), ("block_snake", 3),
    ("block_snake", 4), ("block_snake", 7),
])
@pytest.mark.parametrize("n_kv", [1, 2, 5, 8, 13])
def test_dynamic_visit_order_matches_static(order, group, n_kv):
    parity = np.arange(2 * n_kv + 3, dtype=np.int32)
    sched = KVSchedule(order, n_q=1, n_kv=n_kv, causal=False, q_block=1,
                      kv_block=1, snake_group=group)
    static = np.asarray(sched.page_order(parity))
    g = resolve_order_group(order, group, n_kv)
    dynamic = np.asarray(page_visit_order_dynamic(parity, n_kv, g))
    np.testing.assert_array_equal(static, dynamic)


def test_dynamic_visit_order_group_is_traceable():
    # The whole point of the operand design: group can be a traced scalar.
    fn = jax.jit(lambda p, g: page_visit_order_dynamic(p, 8, g))
    a = np.asarray(fn(np.arange(4, dtype=np.int32), np.int32(1)))
    b = np.asarray(fn(np.arange(4, dtype=np.int32), np.int32(8)))
    assert fn._cache_size() == 1  # same trace, both groups
    np.testing.assert_array_equal(a[1], np.arange(8))  # group 1 == cyclic
    np.testing.assert_array_equal(b[1], np.arange(8)[::-1])  # n == sawtooth


# ---- controller decision logic ----------------------------------------------


def _ctl(**kw):
    kw.setdefault("order", "cyclic")
    return OrderAdaptController(Registry(), **kw)


def test_consider_requires_sustained_improvement():
    ctl = _ctl(hysteresis=0.10, confirm=2)
    worse = {"cyclic": 100.0, "sawtooth": 95.0, "block_snake": 98.0}
    better = {"cyclic": 100.0, "sawtooth": 80.0, "block_snake": 98.0}
    assert not ctl.consider(worse)  # 5% < 10% threshold
    assert not ctl.consider(better)  # first qualifying sample: pending only
    assert ctl.order is Order.CYCLIC
    assert ctl.consider(better)  # second consecutive: switch
    assert ctl.order is Order.SAWTOOTH
    assert ctl.switches == 1


def test_consider_resets_on_candidate_change_and_dropout():
    ctl = _ctl(hysteresis=0.05, confirm=2)
    saw = {"cyclic": 100.0, "sawtooth": 80.0, "block_snake": 99.0}
    snake = {"cyclic": 100.0, "sawtooth": 99.0, "block_snake": 80.0}
    tie = {"cyclic": 100.0, "sawtooth": 100.0, "block_snake": 100.0}
    assert not ctl.consider(saw)
    assert not ctl.consider(snake)  # candidate changed: count restarts
    assert not ctl.consider(tie)    # below threshold: pending cleared
    assert not ctl.consider(snake)  # back to 1 of 2
    assert ctl.consider(snake)
    assert ctl.order is Order.BLOCK_SNAKE


def test_blend_flips_decision_with_shared_fraction():
    """The shared-prefix LLC model changes the verdict once enough of the
    pool is shared pages: below ``shared_threshold`` the fwd reading passes
    through untouched (no switch — its margin is under hysteresis); above
    it the ``(1-w)*fwd + w*shared`` blend flips the argmin to the order the
    shared model favors."""
    ctl = _ctl(hysteresis=0.05, confirm=1, shared_threshold=0.25)
    fwd = {"cyclic": 100.0, "sawtooth": 98.0, "block_snake": 99.0}
    shared = {"cyclic": 100.0, "sawtooth": 200.0, "block_snake": 40.0}
    # Below the threshold the shared reading is ignored: fwd's best
    # (sawtooth, 2%) is under the 5% hysteresis, so nothing moves.
    assert ctl.blend(fwd, shared, 0.1) == fwd
    assert not ctl.consider(fwd, shared_miss=shared, shared_frac=0.1)
    assert ctl.order is Order.CYCLIC
    # At w=0.5 the blend scores block_snake 0.5*99 + 0.5*40 = 69.5 — a 30%
    # improvement over cyclic's 100 — and the order flips.
    assert ctl.blend(fwd, shared, 0.5)["block_snake"] == pytest.approx(69.5)
    assert ctl.consider(fwd, shared_miss=shared, shared_frac=0.5)
    assert ctl.order is Order.BLOCK_SNAKE
    # Orders the shared model did not score fall back to their fwd value.
    part = ctl.blend({"cyclic": 10.0, "sawtooth": 20.0}, {"cyclic": 30.0}, 1.0)
    assert part == {"cyclic": 30.0, "sawtooth": 20.0}


def test_consider_handles_empty_and_missing_current():
    ctl = _ctl(confirm=1)
    assert not ctl.consider(None)
    assert not ctl.consider({})
    assert not ctl.consider({"sawtooth": 1.0})  # current order not modeled
    assert ctl.switches == 0


def test_metrics_surface_and_switch_to():
    reg = Registry()
    ctl = OrderAdaptController(reg, order="sawtooth", enabled=False)
    # Both series exist immediately, even disabled (CI schema relies on it).
    assert reg.value("serve.order_switches") == 0
    assert reg.value("serve.current_order") == ORDER_INDEX[Order.SAWTOOTH]
    ctl.switch_to("block_snake")
    assert reg.value("serve.order_switches") == 1
    assert reg.value("serve.current_order") == ORDER_INDEX[Order.BLOCK_SNAKE]
    assert ctl.effective_snake_group == DEFAULT_SNAKE_GROUP
    assert ctl.effective_group(8) == min(DEFAULT_SNAKE_GROUP, 8)


class _FakeSampler:
    def __init__(self, fwd_miss):
        self.fwd_miss = fwd_miss
        self.current_order = "cyclic"
        self.history = [{"current_order": "cyclic", "fwd_miss": fwd_miss}]
        self.calls = 0

    def sample(self, pool, step_q=None):
        self.calls += 1
        self.history.append(
            {"current_order": self.current_order, "fwd_miss": self.fwd_miss}
        )
        return True

    @property
    def last_fwd_miss(self):
        return self.history[-1]["fwd_miss"]


def test_maybe_adapt_epoch_gating_and_history_rewrite():
    ctl = _ctl(epoch=4, hysteresis=0.05, confirm=1)
    smp = _FakeSampler({"cyclic": 100.0, "sawtooth": 50.0, "block_snake": 99.0})
    assert not ctl.maybe_adapt(3, pool=None, sampler=smp)  # off-epoch
    assert smp.calls == 0
    assert ctl.maybe_adapt(4, pool=None, sampler=smp)
    assert smp.calls == 1
    # The triggering sample is re-attributed to the order driving the next
    # steps — the accounting convention the serve bench integrates with.
    assert smp.history[-1]["current_order"] == "sawtooth"
    assert smp.current_order == "sawtooth"
    disabled = _ctl(epoch=4, enabled=False)
    assert not disabled.maybe_adapt(4, pool=None, sampler=smp)
    assert smp.calls == 1


# ---- autotune cache: keys, load, lookup -------------------------------------


def test_canonicalize_key_normalizes_and_sorts():
    key = canonicalize_key({"b": np.int64(3), "a": 1.0000004, "c": "CPU"})
    assert list(key) == ["a", "b", "c"]
    assert key == {"a": 1.0, "b": 3, "c": "CPU"}
    assert isinstance(key["b"], int)
    with pytest.raises(TypeError):
        canonicalize_key({"flag": True})
    # Writer-order independence is the whole point of the shared helper.
    assert normalize_autotune_key("order_sweep", {"x": 1, "y": 2.0}) == (
        normalize_autotune_key("order_sweep", {"y": 2, "x": 1})
    )


def _write_cache(path, rows):
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")


def _rec(seq, order, *, snake_group=None, version=1, arch="deepseek-7b",
         capacity_mib=3.0, backend="cpu", **extra):
    return {
        "schema_version": version,
        "kind": "order_sweep",
        "key": {"arch": arch, "seq_bucket": seq, "capacity_mib": capacity_mib,
                "n_workers": 12, "backend": backend},
        "winner": {"order": order, "snake_group": snake_group},
        **extra,
    }


def test_load_autotune_cache_missing_dedup_and_unknown_schema(tmp_path):
    assert load_autotune_cache(tmp_path / "nope.jsonl") == []
    p = tmp_path / "cache.jsonl"
    _write_cache(p, [
        _rec(8192, "sawtooth"),
        _rec(16384, "block_snake", snake_group=16),
        _rec(8192, "cyclic"),  # same key, later line: last writer wins
    ])
    entries = load_autotune_cache(p)
    assert len(entries) == 2
    by_seq = {e["key"]["seq_bucket"]: e["winner"]["order"] for e in entries}
    assert by_seq == {8192: "cyclic", 16384: "block_snake"}

    _write_cache(p, [_rec(8192, "cyclic"), _rec(4096, "sawtooth", version=99)])
    with pytest.warns(UserWarning, match="schema_version"):
        entries = load_autotune_cache(p)
    assert [e["key"]["seq_bucket"] for e in entries] == [8192]


def test_lookup_order_winner_nearest_bucket(tmp_path):
    p = tmp_path / "cache.jsonl"
    _write_cache(p, [
        _rec(8192, "cyclic"),
        _rec(16384, "block_snake", snake_group=16),
        _rec(8192, "sawtooth", arch="other-arch"),
    ])
    entries = load_autotune_cache(p)
    # 256 is log-nearer 8192 than 16384; arch match is mandatory.
    hit = lookup_order_winner(entries, arch="deepseek-7b", seq_bucket=256,
                              capacity_mib=3.0, backend="cpu")
    assert hit["winner"]["order"] == "cyclic"
    hit = lookup_order_winner(entries, arch="deepseek-7b", seq_bucket=20000,
                              capacity_mib=3.0)
    assert hit["winner"]["order"] == "block_snake"
    assert lookup_order_winner(entries, arch="missing", seq_bucket=256,
                               capacity_mib=3.0) is None


def test_seed_from_cache(tmp_path):
    p = tmp_path / "cache.jsonl"
    _write_cache(p, [_rec(16384, "block_snake", snake_group=16),
                     _rec(8192, "cyclic")])
    ctl = _ctl(order="sawtooth", snake_group=4)
    assert ctl.seed_from_cache(p, arch="deepseek-7b", seq_bucket=16000,
                               capacity_mib=3.0, backend="cpu")
    assert ctl.order is Order.BLOCK_SNAKE
    assert ctl.snake_group == 16  # winner's group replaces the configured one
    assert ctl.seeded_from["key"]["seq_bucket"] == 16384
    # Missing file: keep the configured order, report no seed.
    ctl2 = _ctl(order="sawtooth")
    assert not ctl2.seed_from_cache(tmp_path / "nope.jsonl",
                                    arch="deepseek-7b", seq_bucket=256,
                                    capacity_mib=3.0)
    assert ctl2.order is Order.SAWTOOTH and ctl2.seeded_from is None


# ---- engine integration: switch mid-stream, bitwise parity, no recompile ----


def _requests(vocab, n=3, max_new=10):
    rng = np.random.default_rng(11)
    return [
        Request(tokens=rng.integers(2, vocab, size=int(rng.integers(5, 14)))
                .astype(np.int32), max_new_tokens=max_new, rid=i)
        for i in range(n)
    ]


def _stream(cfg, lm, params, order, *, force_switch_to=None, switch_at=4):
    eng = ServeEngine(
        build_model(cfg.with_(attn_order=order, snake_group=4)), params,
        batch_size=3, max_len=64, scheduler="continuous", page_size=8,
        prefill_chunk=16, llc_every=0,
    )
    if force_switch_to is not None:
        ctl = eng.order_ctl
        ctl.enabled = True

        def forced(step_epoch, pool, sampler, step_q=None):
            if step_epoch == switch_at and ctl.switches == 0:
                ctl.switch_to(force_switch_to)
                return True
            return False

        ctl.maybe_adapt = forced
    res = eng.generate(_requests(cfg.vocab))
    return eng, [r.tokens.tolist() for r in res]


def test_forced_switch_token_parity_and_no_recompile(deepseek):
    cfg, lm, params = deepseek
    _, tok_c = _stream(cfg, lm, params, "cyclic")
    _, tok_s = _stream(cfg, lm, params, "sawtooth")
    eng, tok_x = _stream(cfg, lm, params, "cyclic",
                         force_switch_to="sawtooth")
    # Online softmax is traversal-order invariant: pinned orders agree, and
    # a mid-stream switch cannot perturb a single token.
    assert tok_c == tok_s == tok_x
    assert eng.order_ctl.switches == 1
    assert eng.order_ctl.order is Order.SAWTOOTH
    # The operand design's contract: both step widths were compiled before
    # the switch, and the switch added nothing.
    assert eng.compiled_step_count() == 2
    assert eng.obs.value("serve.order_switches") == 1
    assert eng.obs.value("serve.current_order") == ORDER_INDEX[Order.SAWTOOTH]


def test_block_snake_switch_token_parity(deepseek):
    cfg, lm, params = deepseek
    _, tok_b = _stream(cfg, lm, params, "block_snake")
    eng, tok_x = _stream(cfg, lm, params, "sawtooth",
                         force_switch_to="block_snake", switch_at=2)
    assert tok_b == tok_x
    assert eng.compiled_step_count() == 2
