"""Unified ragged serve step: chunked prefill, token budgets, prefix sharing.

Covers the serve stack's mixed-step refactor end to end:

* ragged paged attention parity (XLA + Pallas interpret) against a per-row
  oracle — GQA, SWA windows, shuffled block tables, all traversal orders;
* O(1) compilation across arbitrary prompt-length streams (the regression
  that killed the per-bucket prefill jit cache);
* chunked-prefill greedy parity with the static path at prompt lengths that
  straddle chunk and page boundaries;
* prefix sharing: bitwise-identical greedy streams with the pool's page
  dedup on vs off, and copy-on-write isolation between sibling rows;
* pool invariants under a random admit/progress/release/CoW walk
  (hypothesis property test);
* token-budget step planning (decode priority, chunk preemption,
  round-robin fairness);
* the step-level shared-page visit order and its cache_sim/traffic models.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.attention import mha_reference, paged_decode_attention
from repro.core.cache_sim import simulate_shared_prefix_decode
from repro.core.schedule import Order, step_page_visits
from repro.kernels.flash_decode import paged_flash_decode_fwd
from repro.kernels.traffic import shared_prefix_llc_model
from repro.models import build_model
from repro.serve import ContinuousScheduler, PagedKVPool, Request, ServeEngine

SETTINGS = settings(max_examples=20, deadline=None)


@pytest.fixture(scope="module")
def deepseek_lm():
    cfg = get_config("deepseek-7b").reduced()
    lm = build_model(cfg)
    return lm, lm.init(jax.random.PRNGKey(0))


# ---- ragged paged attention parity ------------------------------------------


def _ragged_problem(seed=0, b=3, hq=8, hkv=2, d=16, page=8, nb=4, c=5):
    rng = np.random.default_rng(seed)
    n_pages = b * nb + 1
    kp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, d)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(n_pages, page, hkv, d)).astype(np.float32))
    perm = rng.permutation(np.arange(1, n_pages))[: b * nb].reshape(b, nb)
    bt = jnp.asarray(perm, jnp.int32)  # shuffled block tables
    q = jnp.asarray(rng.normal(size=(b, c, hq, d)).astype(np.float32))
    lens = jnp.asarray([7, 20, nb * page], jnp.int32)   # total valid incl chunk
    qls = jnp.asarray([1, c, 3], jnp.int32)             # ragged chunk rows
    kc = kp[bt].reshape(b, nb * page, hkv, d)
    vc = vp[bt].reshape(b, nb * page, hkv, d)
    return q, kp, vp, bt, lens, qls, kc, vc


def _ragged_reference(q, kc, vc, lens, qls, window):
    """Per-(row, query) oracle: query t of row b at absolute position
    lens[b]-qls[b]+t attends over kv[:pos+1] (SWA-trimmed)."""
    b, c, hq, d = q.shape
    out = np.zeros((b, c, hq, d), np.float32)
    for i in range(b):
        L, Q = int(lens[i]), int(qls[i])
        for t in range(Q):
            pos = L - Q + t
            lo = 0 if window is None else max(0, pos - window + 1)
            out[i, t] = np.asarray(
                mha_reference(
                    q[i : i + 1, t : t + 1],
                    kc[i : i + 1, lo : pos + 1],
                    vc[i : i + 1, lo : pos + 1],
                )
            )[0, 0]
    return out


@pytest.mark.parametrize("order", list(Order))
@pytest.mark.parametrize("window", [None, 11])
def test_ragged_paged_attention_matches_oracle(order, window):
    q, kp, vp, bt, lens, qls, kc, vc = _ragged_problem()
    ref = _ragged_reference(q, kc, vc, lens, qls, window)
    got = np.asarray(
        paged_decode_attention(
            q, kp, vp, lens, bt, q_lens=qls, order=order, window=window
        )
    )
    kern = np.asarray(
        paged_flash_decode_fwd(
            q, kp, vp, lens, bt, q_lens=qls, order=order, window=window,
            interpret=True,
        )
    )
    c = q.shape[1]
    for i in range(q.shape[0]):
        n = int(qls[i])
        np.testing.assert_allclose(got[i, :n], ref[i, :n], atol=2e-5)
        np.testing.assert_allclose(kern[i, :n], ref[i, :n], atol=2e-5)
        if n < c:  # invalid chunk rows are exact zeros, not NaN
            assert np.abs(got[i, n:]).max() == 0.0
            assert np.abs(kern[i, n:]).max() == 0.0


def test_ragged_zero_qlen_rows_are_zero():
    q, kp, vp, bt, lens, _, _, _ = _ragged_problem()
    qls = jnp.asarray([0, 2, 0], jnp.int32)
    out = np.asarray(paged_decode_attention(q, kp, vp, lens, bt, q_lens=qls))
    assert not np.isnan(out).any()
    assert np.abs(out[0]).max() == 0.0 and np.abs(out[2]).max() == 0.0


# ---- O(1) compilation -------------------------------------------------------


def test_mixed_step_compiles_o1_over_prompt_lengths(deepseek_lm):
    """20 distinct prompt lengths through the continuous path must compile
    at most two mixed-step variants (decode width 1 + chunk width) — the
    per-bucket prefill jit cache regression test."""
    lm, params = deepseek_lm
    eng = ServeEngine(
        lm, params, batch_size=4, max_len=128, scheduler="continuous",
        page_size=16, prefill_chunk=24,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            tokens=rng.integers(2, lm.cfg.vocab, size=5 + 3 * i).astype(np.int32),
            max_new_tokens=3,
            rid=i,
        )
        for i in range(20)
    ]
    res = eng.generate(reqs)
    assert all(r.steps >= 1 for r in res)
    assert eng.compiled_step_count() <= 2
    assert not hasattr(eng, "_prefill_buckets")  # the unbounded cache is gone


# ---- chunked prefill parity -------------------------------------------------


@pytest.mark.parametrize("plen", [3, 16, 17, 33, 47])
def test_chunked_prefill_matches_static_greedy(deepseek_lm, plen):
    """Greedy parity with the static path at prompt lengths straddling page
    (16) and chunk (16) boundaries — the chunk decomposition must be
    invisible in the token stream."""
    lm, params = deepseek_lm
    prompt = (np.arange(plen, dtype=np.int32) * 7 + 2) % lm.cfg.vocab
    a = ServeEngine(lm, params, batch_size=1, max_len=96).generate(
        [Request(tokens=prompt, max_new_tokens=6)]
    )[0]
    b = ServeEngine(
        lm, params, batch_size=1, max_len=96, scheduler="continuous",
        page_size=16, prefill_chunk=16,
    ).generate([Request(tokens=prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_mixed_stream_rows_isolated(deepseek_lm):
    """Every request in a ragged mixed stream (staggered arrivals, ragged
    lengths, mid-stream admissions) decodes exactly what it decodes solo —
    chunked prefill neighbors and shared pages must be invisible."""
    lm, params = deepseek_lm
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(2, lm.cfg.vocab, size=int(n)).astype(np.int32)
        for n in [5, 21, 34, 9, 21, 13]
    ]
    prompts[4] = prompts[1].copy()  # exact duplicate: shares + CoW-forks
    eng = ServeEngine(
        lm, params, batch_size=2, max_len=96, scheduler="continuous",
        page_size=8, prefill_chunk=16,
    )
    reqs = [
        Request(tokens=p, max_new_tokens=5, rid=i, arrival=i // 2)
        for i, p in enumerate(prompts)
    ]
    batch = eng.generate(reqs)
    for i, p in enumerate(prompts):
        solo = eng.generate([Request(tokens=p, max_new_tokens=5)])[0]
        np.testing.assert_array_equal(batch[i].tokens, solo.tokens)


# ---- prefix sharing correctness --------------------------------------------


def _shared_stream(vocab, rng, n=6):
    sysp = rng.integers(2, vocab, size=40).astype(np.int32)
    reqs = []
    for i in range(n):
        if i == 3:
            tokens = sysp[:30].copy()  # mid-page prefix-only: CoW fork path
        else:
            tail = rng.integers(2, vocab, size=3 + i).astype(np.int32)
            tokens = np.concatenate([sysp, tail])
        reqs.append(Request(tokens=tokens, max_new_tokens=5, rid=i, arrival=i))
    return reqs


def test_prefix_sharing_greedy_bitwise_identical(deepseek_lm):
    """The pool's hash-dedup + CoW must be invisible: greedy token streams
    with sharing on and off are identical, request by request."""
    lm, params = deepseek_lm
    rng = np.random.default_rng(7)
    reqs = _shared_stream(lm.cfg.vocab, rng)
    mk = lambda sharing: ServeEngine(
        lm, params, batch_size=2, max_len=96, scheduler="continuous",
        page_size=8, prefill_chunk=16, prefix_sharing=sharing,
    )
    eng_on = mk(True)
    on = eng_on.generate([Request(**vars(r)) for r in reqs])
    off = mk(False).generate([Request(**vars(r)) for r in reqs])
    assert eng_on.last_stats["pages_adopted"] > 0  # sharing actually engaged
    assert eng_on.last_stats["cow_forks"] > 0      # ...including a CoW fork
    for a, b in zip(on, off):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_cow_isolation_between_siblings(deepseek_lm):
    """A row decoding past a shared prefix must never perturb a sibling
    that shares its pages: serve A alone, then A next to prefix-sharing
    siblings — A's stream is bit-identical."""
    lm, params = deepseek_lm
    rng = np.random.default_rng(11)
    sysp = rng.integers(2, lm.cfg.vocab, size=32).astype(np.int32)
    a_req = lambda: Request(tokens=sysp.copy(), max_new_tokens=6, rid=0)
    # Siblings arrive after A's two prefill chunks have completed (and its
    # prompt pages are registered), so they adopt A's pages.
    sib = lambda i: Request(
        tokens=sysp.copy(), max_new_tokens=6, rid=i, arrival=2, temperature=1.5
    )
    eng = ServeEngine(
        lm, params, batch_size=3, max_len=96, scheduler="continuous",
        page_size=8, prefill_chunk=16,
    )
    solo = eng.generate([a_req()])[0]
    paired = eng.generate([a_req(), sib(1), sib(2)])
    assert eng.last_stats["cow_forks"] > 0  # siblings forked shared pages
    np.testing.assert_array_equal(solo.tokens, paired[0].tokens)


# ---- pool invariants under a random walk (property test) --------------------


@SETTINGS
@given(seed=st.integers(0, 2**16))
def test_pool_invariants_random_walk(seed):
    """Random admissions / chunked progress / CoW forks / releases: no page
    leaks (free + distinct-held == allocatable), refcounts consistent and
    non-negative, block tables always pointing at held-or-dummy pages,
    reservations conserved. Prompts from a tiny alphabet so prefix matches
    (and forks) happen constantly."""
    cfg = get_config("deepseek-7b").reduced().with_(kv_layout="paged", page_size=4)
    rng = np.random.default_rng(seed)
    n_slots = 3
    pool = PagedKVPool(cfg, cfg.n_layers, n_slots, max_len=32)
    state: dict[int, dict] = {}  # slot -> {prompt, left, registered}

    for _ in range(60):
        op = rng.integers(0, 3)
        if op == 0:  # admit into a free slot
            free = [s for s in range(n_slots) if s not in state]
            if not free:
                continue
            slot = int(rng.choice(free))
            plen = int(rng.integers(1, 28))
            prompt = rng.integers(2, 5, size=plen).astype(np.int32)
            max_new = int(rng.integers(1, 8))
            shared = pool.admit(slot, prompt, max_new)
            if shared is not None:
                total = min(plen + max_new, pool.capacity)
                state[slot] = {
                    "prompt": prompt,
                    "left": total - 1 - shared,  # tokens still to write
                    "registered": False,
                }
        elif op == 1:  # progress: write a chunk (prefill or decode)
            busy = [s for s in state if state[s]["left"] > 0]
            if not busy:
                continue
            slot = int(rng.choice(busy))
            n = int(rng.integers(1, min(state[slot]["left"], 6) + 1))
            pool.ensure_writable(slot, n)
            pool.advance(slot, n)
            state[slot]["left"] -= n
            st_ = state[slot]
            if not st_["registered"] and pool.lens[slot] >= len(st_["prompt"]):
                pool.register_prompt(slot, st_["prompt"])
                st_["registered"] = True
        else:  # release
            if not state:
                continue
            slot = int(rng.choice(list(state)))
            pool.release(slot)
            del state[slot]
        pool.check_invariants()

    for slot in list(state):
        pool.release(slot)
    pool.check_invariants()
    assert pool.alloc.free_count == pool.alloc.n_pages - 1
    assert pool.alloc.reserved == 0


# ---- token-budget step planning ---------------------------------------------


def _place(sched, slot, plen, pos=0, new_limit=4):
    sched.place(
        slot,
        object(),
        eos_id=1,
        new_limit=new_limit,
        prompt=np.arange(plen, dtype=np.int32),
        prompt_pos=pos,
    )


def test_plan_step_decode_priority_and_chunking():
    sched = ContinuousScheduler(4, token_budget=10, prefill_chunk=6)
    _place(sched, 0, plen=4, pos=4)    # decoding
    _place(sched, 1, plen=20)          # long prefill
    _place(sched, 2, plen=3)           # short prefill
    plan = {it.slot: it for it in sched.plan_step()}
    assert plan[0].q_len == 1 and not plan[0].is_prefill
    # 9 tokens left after decode: chunk 6 to one prefill, 3 to the other.
    assert plan[1].is_prefill and plan[2].is_prefill
    assert plan[1].q_len + plan[2].q_len == 9
    assert not plan[1].finishes_prompt
    assert plan[2].q_len == 3 and plan[2].finishes_prompt


def test_plan_step_preempts_long_prefill():
    """A long prompt advances in chunks while decode rows keep emitting —
    it never monopolizes a step beyond the leftover budget."""
    sched = ContinuousScheduler(4, token_budget=8, prefill_chunk=8)
    for s in range(3):
        _place(sched, s, plen=2, pos=2)  # three decode rows
    _place(sched, 3, plen=40)            # one long prefill
    plan = {it.slot: it for it in sched.plan_step()}
    assert [plan[s].q_len for s in range(3)] == [1, 1, 1]
    assert plan[3].q_len == 5  # leftover budget, not the full chunk
    st = sched.slots[3]
    steps = 0
    while st.prefilling and steps < 20:
        for it in sched.plan_step():
            if it.slot == 3:
                st.prompt_pos += it.q_len
        steps += 1
    assert st.prompt_pos == 40 and steps == 8  # 5 + 7*5 tokens


def test_plan_step_round_robin_fairness():
    sched = ContinuousScheduler(3, token_budget=4, prefill_chunk=4)
    for s in range(3):
        _place(sched, s, plen=30)
    first = {it.slot for it in sched.plan_step()}
    sched.slots[next(iter(first))].prompt_pos += 4
    second = {it.slot for it in sched.plan_step()}
    assert first != second  # cursor rotated to a different slot


def test_plan_step_decode_saturated_budget():
    sched = ContinuousScheduler(4, token_budget=2, prefill_chunk=8)
    for s in range(2):
        _place(sched, s, plen=2, pos=2)
    _place(sched, 2, plen=10)
    plan = sched.plan_step()
    assert len(plan) == 2 and all(not it.is_prefill for it in plan)


# ---- step-level shared-page visit order + models ----------------------------


@SETTINGS
@given(
    order=st.sampled_from(list(Order)),
    n_rows=st.integers(1, 5),
    seed=st.integers(0, 1000),
)
def test_step_page_visits_is_rowwise_permutation(order, n_rows, seed):
    rng = np.random.default_rng(seed)
    row_pages = [
        list(rng.integers(0, 50, size=int(rng.integers(1, 7))))
        for _ in range(n_rows)
    ]
    parities = [int(rng.integers(0, 100)) for _ in range(n_rows)]
    visits = list(step_page_visits(order, row_pages, parities))
    for b in range(n_rows):
        mine = [p for (row, p) in visits if row == b]
        assert sorted(mine) == sorted(row_pages[b])
    # lock-step: the first n_active visits are inner step 0, row-ordered
    first = [row for row, _ in visits[:n_rows]]
    assert first == sorted(first)


def test_shared_prefix_reuse_distance_beats_private():
    for order in ("cyclic", "sawtooth"):
        sh = simulate_shared_prefix_decode(order, 6, 4, [8] * 6, 12, 16, shared=True)
        pr = simulate_shared_prefix_decode(order, 6, 4, [8] * 6, 12, 16, shared=False)
        assert sh["mean_reuse_distance"] < pr["mean_reuse_distance"]


def test_shared_prefix_llc_model_misses_drop():
    shared = shared_prefix_llc_model("sawtooth", shared=True)
    private = shared_prefix_llc_model("sawtooth", shared=False)
    assert shared.cold_misses < private.cold_misses   # dedup: fewer compulsory
    assert shared.misses < private.misses             # and fewer total bytes
