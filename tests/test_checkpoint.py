import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path), 7)
    restored, step = restore_pytree(t, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_ignores_partial(tmp_path):
    save_pytree(_tree(), str(tmp_path), 3)
    # simulate a torn write: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 3


def test_manager_async_and_retention(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        m.save(t, s)
    m.wait()
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_manager_restore_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    m.save(t, 5, blocking=True)
    t2 = jax.tree.map(lambda x: x * 0, t)
    restored, step = m.restore_latest(t2)
    assert step == 5
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(t["params"]["w"])
    )


def test_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(1, 1)
    t = _tree()
    save_pytree(t, str(tmp_path), 0)
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    restored, _ = restore_pytree(t, str(tmp_path), shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_pytree(_tree(), str(tmp_path / "nope"))
