"""Fused flash backward: gradient parity, LSE residuals, backward traffic.

The acceptance bar for the fused-backward change: gradients from the Pallas
backward kernels (interpret mode) and the fused blockwise JAX backward must
match the recompute-VJP and reference paths to <=1e-4 (f32) across
causal/SWA/GQA/score_dtype and both traversal orders, and the backward
traffic model must show >=30% modeled byte reduction for sawtooth on the
dK/dV grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as core_attn
from repro.kernels import flash_attention as kflash
from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref
from repro.kernels.traffic import (
    FlashGridSpec,
    bwd_dkv_llc_model,
    bwd_dkv_traffic,
    bwd_dq_traffic,
)


def _mk(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def _ref_grads(q, k, v, do, *, causal, window):
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(q_, k_, v_, causal=causal, window=window),
        q, k, v,
    )
    return vjp(do)


from helpers import ALL_ORDERS as ORDERS, order_kwargs as _okw

# b, sq, skv, hq, hkv, d, causal, window, qb, kb
BWD_SWEEP = [
    (1, 128, 128, 2, 2, 64, False, None, 128, 128),
    (2, 256, 256, 4, 4, 64, True, None, 128, 128),
    (1, 256, 256, 8, 2, 64, True, None, 128, 128),      # GQA
    (1, 512, 512, 4, 1, 64, True, 192, 128, 128),       # MQA + SWA
    (1, 384, 384, 2, 2, 64, True, None, 256, 128),      # rectangular blocks
    (1, 200, 200, 2, 2, 64, True, None, 128, 128),      # non-multiple seq
]


@pytest.mark.parametrize("case", BWD_SWEEP)
@pytest.mark.parametrize("order", ORDERS)
def test_pallas_bwd_kernels_match_reference_grads(case, order):
    b, sq, skv, hq, hkv, d, causal, window, qb, kb = case
    q, k, v = _mk((b, sq, hq, d), 1), _mk((b, skv, hkv, d), 2), _mk((b, skv, hkv, d), 3)
    do = _mk((b, sq, hq, d), 4)
    dq_r, dk_r, dv_r = _ref_grads(q, k, v, do, causal=causal, window=window)
    o, lse = kflash.flash_attention_fwd(
        q, k, v, order=order, causal=causal, window=window,
        q_block=qb, kv_block=kb, interpret=True, return_lse=True, **_okw(order),
    )
    dq, dk, dv = kflash.flash_attention_bwd(
        q, k, v, o, lse, do, order=order, causal=causal, window=window,
        q_block=qb, kv_block=kb, interpret=True, **_okw(order),
    )
    for got, want, name in [(dq, dq_r, "dq"), (dk, dk_r, "dk"), (dv, dv_r, "dv")]:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4, err_msg=name
        )


@pytest.mark.parametrize("case", BWD_SWEEP)
@pytest.mark.parametrize("order", ORDERS)
def test_blockwise_fused_bwd_matches_reference_grads(case, order):
    b, sq, skv, hq, hkv, d, causal, window, qb, kb = case
    q, k, v = _mk((b, sq, hq, d), 1), _mk((b, skv, hkv, d), 2), _mk((b, skv, hkv, d), 3)
    do = _mk((b, sq, hq, d), 4)
    dq_r, dk_r, dv_r = _ref_grads(q, k, v, do, causal=causal, window=window)
    o, lse = core_attn.flash_attention(
        q, k, v, order=order, causal=causal, window=window,
        q_block=qb, kv_block=kb, return_lse=True, **_okw(order),
    )
    dq, dk, dv = core_attn.flash_attention_bwd(
        q, k, v, o, lse, do, order=order, causal=causal, window=window,
        q_block=qb, kv_block=kb, **_okw(order),
    )
    for got, want, name in [(dq, dq_r, "dq"), (dk, dk_r, "dk"), (dv, dv_r, "dv")]:
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4, err_msg=name
        )


def test_lse_residual_matches_logsumexp():
    q, k, v = _mk((1, 256, 4, 64), 1), _mk((1, 256, 2, 64), 2), _mk((1, 256, 2, 64), 3)
    d = q.shape[-1]
    # direct logsumexp of the scaled masked scores
    g = 4 // 2
    qf = q.astype(jnp.float32).reshape(1, 256, 2, g, d)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32)) * d**-0.5
    rows = jnp.arange(256)[:, None]
    cols = jnp.arange(256)[None, :]
    s = jnp.where((cols <= rows)[:, None, None, :], s[0], -jnp.inf)
    want = jax.nn.logsumexp(s, axis=-1).reshape(256, 4)[None]
    for fwd in (
        lambda: core_attn.flash_attention(
            q, k, v, causal=True, q_block=128, kv_block=128, return_lse=True
        ),
        lambda: kflash.flash_attention_fwd(
            q, k, v, causal=True, q_block=128, kv_block=128,
            interpret=True, return_lse=True,
        ),
    ):
        _, lse = fwd()
        np.testing.assert_allclose(np.asarray(lse), np.asarray(want), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("impl", ["pallas_interpret", "xla", "jnp"])
@pytest.mark.parametrize("order", ORDERS)
def test_ops_grad_dispatch_matches_reference(impl, order):
    """jax.grad through ops.attention: every backward dispatch agrees."""
    q, k, v = _mk((1, 256, 4, 32), 1), _mk((1, 256, 2, 32), 2), _mk((1, 256, 2, 32), 3)

    def loss(impl_):
        def f(q_, k_, v_):
            out = ops.attention(
                q_, k_, v_, order=order, causal=True, window=96, impl=impl_,
                q_block=64, kv_block=64, bwd_q_block=128, bwd_kv_block=64,
                **_okw(order),
            )
            return (out.astype(jnp.float32) ** 2).sum()

        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    got = loss(impl)
    want = loss("reference")
    for a, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("score_dtype", ["float32", "bfloat16"])
def test_ops_grad_score_dtype(score_dtype):
    """Fused bwd vs recompute-VJP under each score dtype: f32 must be tight;
    bf16 scores carry inherent ~1e-2 relative noise in *both* paths, so the
    bar is scale-relative agreement between them."""
    q, k, v = _mk((1, 256, 4, 64), 1), _mk((1, 256, 2, 64), 2), _mk((1, 256, 2, 64), 3)

    def grads(impl):
        def f(q_, k_, v_):
            out = ops.attention(
                q_, k_, v_, causal=True, impl=impl, q_block=128, kv_block=128,
                score_dtype=score_dtype,
            )
            return (out.astype(jnp.float32) ** 2).sum()

        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    fused = grads("xla")
    recompute = grads("jnp")
    for a, r in zip(fused, recompute):
        a, r = np.asarray(a), np.asarray(r)
        if score_dtype == "float32":
            np.testing.assert_allclose(a, r, atol=1e-4, rtol=1e-4)
        else:
            assert np.abs(a - r).max() <= 0.05 * np.abs(r).max()


def test_fused_bwd_consumes_residuals_not_recompute():
    """The structural property behind '2 passes, not 3': the backward is a
    pure function of the saved (o, lse) residuals — calling it standalone,
    with no access to a forward recompute, already yields exact grads."""
    q, k, v = _mk((1, 128, 2, 32), 1), _mk((1, 128, 2, 32), 2), _mk((1, 128, 2, 32), 3)
    do = _mk((1, 128, 2, 32), 4)
    o, lse = kflash.flash_attention_fwd(
        q, k, v, causal=True, q_block=64, kv_block=64, interpret=True, return_lse=True
    )
    fused = kflash.flash_attention_bwd(
        q, k, v, o, lse, do, causal=True, q_block=64, kv_block=64, interpret=True
    )
    ref = _ref_grads(q, k, v, do, causal=True, window=None)
    for a, r in zip(fused, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# backward traffic model
# --------------------------------------------------------------------------


def test_bwd_dkv_pipeline_sawtooth_elides_sweep_boundaries():
    spec = FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=256, kv_block=256)
    cyc = bwd_dkv_traffic(spec, "cyclic")
    saw = bwd_dkv_traffic(spec, "sawtooth")
    # one elided streamed fetch per resident-sweep boundary
    assert cyc.elided_stream_fetches == 0
    assert saw.elided_stream_fetches == spec.nkv - 1
    assert saw.stream_bytes < cyc.stream_bytes
    # resident + write traffic is order-invariant
    assert saw.resident_bytes == cyc.resident_bytes
    assert saw.write_bytes == cyc.write_bytes


def test_bwd_dkv_pipeline_gqa_elides_across_groups():
    """The linearized sweep reverses groups too: still one elision per
    KV-tile boundary with G > 1 (the boundary bundle is the same block)."""
    spec = FlashGridSpec(seq_q=2048, seq_kv=2048, q_block=256, kv_block=256, n_groups=4)
    saw = bwd_dkv_traffic(spec, "sawtooth")
    assert saw.elided_stream_fetches == spec.nkv - 1
    assert bwd_dkv_traffic(spec, "cyclic").elided_stream_fetches == 0


def test_bwd_dkv_llc_sawtooth_reduction_meets_bar():
    """The acceptance criterion: >=30% modeled byte reduction on the dK/dV
    grid (sawtooth vs cyclic), in the finite-shared-buffer regime where the
    Q/dO stream exceeds the buffer (paper Fig 8's analogue)."""
    cases = [
        FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=256, kv_block=256, causal=True),
        FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=512, kv_block=512, causal=True),
        FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=256, kv_block=256),
    ]
    for spec in cases:
        cyc = bwd_dkv_llc_model(spec, "cyclic", n_workers=1)
        saw = bwd_dkv_llc_model(spec, "sawtooth", n_workers=1)
        assert cyc.non_compulsory_misses > 0
        red = 1 - saw.non_compulsory_misses / cyc.non_compulsory_misses
        assert red >= 0.30, (spec, red)
    # wavefront-shared buffer, non-causal (uniform ranges): still >=30%
    spec = cases[2]
    cyc = bwd_dkv_llc_model(spec, "cyclic", n_workers=4)
    saw = bwd_dkv_llc_model(spec, "sawtooth", n_workers=4)
    assert 1 - saw.non_compulsory_misses / cyc.non_compulsory_misses >= 0.30


def test_bwd_dq_traffic_mirrors_forward_grid():
    spec = FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=256, kv_block=256)
    cyc = bwd_dq_traffic(spec, "cyclic")
    saw = bwd_dq_traffic(spec, "sawtooth")
    assert saw.elided_stream_fetches == spec.nq - 1  # same as forward KV elision
    assert saw.stream_bytes < cyc.stream_bytes
    assert cyc.write_bytes == saw.write_bytes > 0
