"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the deepseek-7b family scaled to ~100M params (8 layers, d=512,
vocab 16k), the full production code path: synthetic packed data pipeline,
AdamW + cosine, checkpointing + resume, sawtooth attention.

  PYTHONPATH=src python examples/train_lm.py             # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --tiny      # CI-sized

On CPU the 100M configuration takes a few seconds/step; pass --steps to
shorten. Resume works: re-running continues from the last checkpoint.
"""

import argparse
import logging

import jax

from repro.configs import ParallelConfig, TrainConfig, get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.train.loop import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    base = get_config("deepseek-7b")
    if args.tiny:
        cfg = base.reduced()
        args.steps = min(args.steps, 20)
        args.seq = 128
    else:
        # ~100M params: 8 x d512 (ff 2048) + 16k vocab
        cfg = base.with_(
            n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
            d_ff=2048, vocab=16384, dtype="float32", param_dtype="float32",
            remat="none", q_block=128, kv_block=128,
        )
    lm = build_model(cfg)
    mesh = make_local_mesh(1, 1)
    tcfg = TrainConfig(
        lr=3e-4,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        checkpoint_every=max(args.steps // 4, 1),
        checkpoint_dir=args.ckpt_dir,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    res = run_training(lm, tcfg, ParallelConfig(), mesh, steps=args.steps, data_cfg=dcfg)
    n_params = sum(x.size for x in jax.tree.leaves(lm.init(jax.random.PRNGKey(0))))
    print(
        f"params={n_params/1e6:.1f}M steps={res.final_step + 1} "
        f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
        f"(resumed_from={res.resumed_from})"
    )


if __name__ == "__main__":
    main()
