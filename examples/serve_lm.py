"""Serving example: batched generation through the ServeEngine.

Optionally restores the checkpoint written by examples/train_lm.py so the
two examples compose into train -> serve.

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --reduced
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine, supports_continuous
from repro.train.checkpoint import latest_step, restore_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-size", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--attn-order", default="sawtooth")
    ap.add_argument(
        "--scheduler", default="auto", choices=["auto", "static", "continuous"]
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_(attn_order=args.attn_order)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    if latest_step(args.ckpt_dir) is not None:
        try:
            state, step = restore_pytree({"params": params}, args.ckpt_dir)
            params = state["params"]
            print(f"restored params from {args.ckpt_dir} step {step}")
        except KeyError:
            print("checkpoint incompatible with this config; using random init")

    scheduler = args.scheduler
    if scheduler == "auto":
        scheduler = "continuous" if supports_continuous(cfg) else "static"
    print(f"scheduler: {scheduler}")
    eng = ServeEngine(lm, params, batch_size=4, max_len=256, scheduler=scheduler)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            tokens=rng.integers(2, cfg.vocab, size=int(rng.integers(4, 24))).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=0.7 if i % 2 else 0.0,
            rid=i,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    results = eng.generate(reqs)
    dt = time.time() - t0
    total = sum(r.steps for r in results)
    print(f"served {len(results)} requests / {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s)")
    for r in results[:4]:
        print(f"  rid={r.rid}: {r.tokens.tolist()}")


if __name__ == "__main__":
    main()
