"""Reproduce the paper's analysis end-to-end in one script (text figures).

Produces the paper's core plots as ASCII tables:
  A. L2 sector model vs simulator across sequence lengths  (Fig 3/4)
  B. miss-vs-cold divergence sweep                          (Fig 5)
  C. hit rate vs active workers, with the 1-1/N law         (Fig 6)
  D. cyclic vs sawtooth misses + modelled throughput        (Fig 7-12)

  PYTHONPATH=src python examples/sawtooth_analysis.py
"""

import dataclasses

from repro.core.cache_model import (
    GB10,
    AttentionWorkload,
    calibrate_miss_service,
    cold_miss_sectors,
    gb10_throughput_model,
    l2_sector_accesses,
)
from repro.core.cache_sim import simulate_attention


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main():
    section("A. sector-access model vs LRU simulator (T=80, D=64)")
    print(f"{'S':>8} {'model':>15} {'simulated':>15} {'err%':>7}")
    for s in (2048, 4096, 8192, 16384):
        w = AttentionWorkload(seq_len=s, tile=80)
        sim = simulate_attention(w, GB10, "cyclic", n_workers=48)
        model = l2_sector_accesses(w, GB10)
        err = 100 * abs(model - sim.accesses) / sim.accesses
        print(f"{s:>8} {model:>15,.0f} {sim.accesses:>15,.0f} {err:>6.2f}%")

    section("B. divergence of misses from cold misses (1/8-scale L2)")
    hw = dataclasses.replace(GB10, cache_bytes=3 * 2**20)
    print(f"{'S':>8} {'misses':>12} {'cold(16S)':>12} {'ratio':>6}")
    for s in (4096, 8192, 10240, 12288, 16384):
        w = AttentionWorkload(seq_len=s, tile=80)
        r = simulate_attention(w, hw, "cyclic", n_workers=48)
        cold = cold_miss_sectors(w, hw)
        print(f"{s:>8} {r.misses:>12,.0f} {cold:>12,.0f} {r.misses/cold:>6.2f}")

    section("C. hit rate vs N workers (overflow regime) vs 1 - 1/N")
    hw = dataclasses.replace(GB10, cache_bytes=2 * 2**20)
    w = AttentionWorkload(seq_len=16384, tile=64)
    print(f"{'N':>4} {'hit rate':>9} {'1-1/N':>7}")
    for n in (1, 2, 4, 8, 16, 48):
        r = simulate_attention(w, hw, "cyclic", n_workers=n)
        print(f"{n:>4} {r.hit_rate:>9.4f} {1 - 1/n:>7.4f}")

    section("D. cyclic vs sawtooth (1/2-scale CuTile geometry)")
    hw = dataclasses.replace(GB10, cache_bytes=12 * 2**20)
    for causal in (False, True):
        w = AttentionWorkload(seq_len=65536, tile=64, batch=4, causal=causal)
        cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
        saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
        red = 100 * (1 - saw.misses / cyc.misses)
        base = 41e12 if causal else 61e12
        svc = calibrate_miss_service(
            w, hw, observed_flops=base, miss_sectors=cyc.misses, kernel_peak=74e12
        )
        pred = gb10_throughput_model(
            w, hw, saw.misses, miss_service_s=svc, kernel_peak=74e12
        )
        tag = "causal" if causal else "non-causal"
        print(
            f"{tag:>11}: misses {cyc.misses:,.0f} -> {saw.misses:,.0f} "
            f"({red:.1f}% less) | throughput {base/1e12:.0f} -> "
            f"{pred/1e12:.1f} TFLOPS (modelled)"
        )
    print("\npaper: ~67% miss reduction; 61->69 (non-causal), 41->66 (causal) TFLOPS")


if __name__ == "__main__":
    main()
