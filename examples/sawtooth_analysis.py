"""Reproduce the paper's analysis end-to-end in one script (text figures).

Produces the paper's core plots as ASCII tables:
  A. L2 sector model vs simulator across sequence lengths  (Fig 3/4)
  B. miss-vs-cold divergence sweep                          (Fig 5)
  C. hit rate vs active workers, with the 1-1/N law         (Fig 6)
  D. cyclic vs sawtooth misses + modelled throughput        (Fig 7-12)
  E. all three traversal orders (block_snake included) on the Fig 7-12
     model and on the backward dK/dV stream — the Traversal IR's
     capacity-bound regime (DESIGN.md §3)

  PYTHONPATH=src python examples/sawtooth_analysis.py
  PYTHONPATH=src python examples/sawtooth_analysis.py --quick   # CI smoke

``--quick`` scales the simulated geometries down ~4x (same code paths,
same qualitative deltas, a fraction of the pure-Python LRU replay cost).
"""

import argparse
import dataclasses

from repro.core.cache_model import (
    GB10,
    AttentionWorkload,
    calibrate_miss_service,
    cold_miss_sectors,
    gb10_throughput_model,
    l2_sector_accesses,
)
from repro.core.cache_sim import simulate_attention
from repro.kernels.traffic import FlashGridSpec, bwd_dkv_llc_model, fwd_llc_model


def section(title):
    print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="~4x smaller simulated geometries (CI smoke)")
    args = ap.parse_args()
    # scale factor for the big LRU replays; the cache sizes scale with the
    # workloads so every section stays in its intended regime.
    f = 4 if args.quick else 1

    section("A. sector-access model vs LRU simulator (T=80, D=64)")
    print(f"{'S':>8} {'model':>15} {'simulated':>15} {'err%':>7}")
    for s in (2048, 4096, 8192, 16384)[: 2 if args.quick else 4]:
        w = AttentionWorkload(seq_len=s, tile=80)
        sim = simulate_attention(w, GB10, "cyclic", n_workers=48)
        model = l2_sector_accesses(w, GB10)
        err = 100 * abs(model - sim.accesses) / sim.accesses
        print(f"{s:>8} {model:>15,.0f} {sim.accesses:>15,.0f} {err:>6.2f}%")

    section("B. divergence of misses from cold misses (1/8-scale L2)")
    hw = dataclasses.replace(GB10, cache_bytes=3 * 2**20 // f)
    print(f"{'S':>8} {'misses':>12} {'cold(16S)':>12} {'ratio':>6}")
    for s in (4096, 8192, 10240, 12288, 16384)[:: f if args.quick else 1]:
        w = AttentionWorkload(seq_len=s, tile=80)
        r = simulate_attention(w, hw, "cyclic", n_workers=48)
        cold = cold_miss_sectors(w, hw)
        print(f"{s:>8} {r.misses:>12,.0f} {cold:>12,.0f} {r.misses/cold:>6.2f}")

    section("C. hit rate vs N workers (overflow regime) vs 1 - 1/N")
    hw = dataclasses.replace(GB10, cache_bytes=2 * 2**20 // f)
    w = AttentionWorkload(seq_len=16384 // f, tile=64)
    print(f"{'N':>4} {'hit rate':>9} {'1-1/N':>7}")
    for n in (1, 2, 4, 8, 16, 48):
        r = simulate_attention(w, hw, "cyclic", n_workers=n)
        print(f"{n:>4} {r.hit_rate:>9.4f} {1 - 1/n:>7.4f}")

    section("D. cyclic vs sawtooth (1/2-scale CuTile geometry)")
    hw = dataclasses.replace(GB10, cache_bytes=12 * 2**20 // f)
    for causal in (False, True):
        w = AttentionWorkload(seq_len=65536 // f, tile=64, batch=4 // f or 1,
                              causal=causal)
        cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
        saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
        red = 100 * (1 - saw.misses / cyc.misses)
        base = 41e12 if causal else 61e12
        svc = calibrate_miss_service(
            w, hw, observed_flops=base, miss_sectors=cyc.misses, kernel_peak=74e12
        )
        pred = gb10_throughput_model(
            w, hw, saw.misses, miss_service_s=svc, kernel_peak=74e12
        )
        tag = "causal" if causal else "non-causal"
        print(
            f"{tag:>11}: misses {cyc.misses:,.0f} -> {saw.misses:,.0f} "
            f"({red:.1f}% less) | throughput {base/1e12:.0f} -> "
            f"{pred/1e12:.1f} TFLOPS (modelled)"
        )
    print("\npaper: ~67% miss reduction; 61->69 (non-causal), 41->66 (causal) TFLOPS")

    section("E. all three orders: Fig 7-12 model + backward dK/dV stream")
    # E1: the paper's GB10 geometry (causal, 1/2-scale CuTile), with
    # block_snake groups sized around the L2 capacity.
    hw = dataclasses.replace(GB10, cache_bytes=12 * 2**20 // f)
    w = AttentionWorkload(seq_len=65536 // f, tile=64, batch=4 // f or 1,
                          causal=True)
    orders = [("cyclic", None), ("sawtooth", None),
              ("block_snake", 16), ("block_snake", 64)]
    print("GB10 sim, causal 64k (non-compulsory miss sectors):")
    base = None
    for order, g in orders:
        r = simulate_attention(w, hw, order, n_workers=48, snake_group=g)
        if base is None:
            base = max(r.non_compulsory_misses, 1)
        tag = order if g is None else f"{order}(g={g})"
        print(f"  {tag:>18}: {r.non_compulsory_misses:>14,.0f} "
              f"({100 * (1 - r.non_compulsory_misses / base):+.1f}% vs cyclic)")

    # E2: the TPU-side capacity-bound forward wavefront (fwd_llc_model):
    # causal trimming desynchronizes the workers, sawtooth's full-range
    # reversals thrash the shared buffer, block_snake's bounded footprint
    # turns the spread back into hits.
    spec = FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=128, kv_block=128,
                         causal=True)
    print("\nforward wavefront LLC model (causal 8k, 12 workers, 0.75x K+V "
          "capacity; non-compulsory MiB):")
    for order, g in orders + [("block_snake", 32)]:
        r = fwd_llc_model(spec, order, snake_group=g, n_workers=12,
                          capacity_frac=0.75)
        tag = order if g is None else f"{order}(g={g})"
        print(f"  {tag:>18}: {r.non_compulsory_misses / 2**20:>8.2f} MiB")

    # E3: the backward dK/dV stream (transposed grid — Q/dO streamed against
    # resident KV tiles). Sawtooth's whole-sweep reversal still rules the
    # per-worker regime; block_snake sits between the endpoints.
    print("\nbackward dK/dV wavefront LLC model (causal 8k, 4 workers, 0.5x "
          "Q+dO capacity; non-compulsory MiB):")
    spec_b = FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=256, kv_block=256,
                           causal=True)
    for order, g in orders:
        r = bwd_dkv_llc_model(spec_b, order, snake_group=g, n_workers=4)
        tag = order if g is None else f"{order}(g={g})"
        print(f"  {tag:>18}: {r.non_compulsory_misses / 2**20:>8.2f} MiB")
    print("\ntakeaway: sawtooth wins the synchronized/per-worker regimes "
          "(pass-boundary reuse), block_snake wins once a finite shared "
          "LLC meets a desynchronized wavefront — size the group to the "
          "cache (hillclimb.py --sweep-orders).")


if __name__ == "__main__":
    main()
