"""Quickstart: the paper's technique in 60 lines.

1. Build a flash-attention problem, run it with cyclic vs sawtooth KV
   scheduling (identical outputs — the schedule is a pure locality change).
2. Reproduce the paper's core claim on the GB10 cache simulator.
3. Show the TPU-native structural gain (Pallas pipeline fetch elision).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GB10, AttentionWorkload, simulate_attention
from repro.kernels import ops
from repro.kernels.traffic import FlashGridSpec, pipeline_traffic

# --- 1. sawtooth is output-preserving -------------------------------------
q = jax.random.normal(jax.random.PRNGKey(0), (2, 512, 8, 64), jnp.bfloat16)
k = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 2, 64), jnp.bfloat16)
v = jax.random.normal(jax.random.PRNGKey(2), (2, 512, 2, 64), jnp.bfloat16)

out_cyc = ops.attention(q, k, v, order="cyclic", causal=True, impl="xla")
out_saw = ops.attention(q, k, v, order="sawtooth", causal=True, impl="xla")
err = float(jnp.abs(out_cyc.astype(jnp.float32) - out_saw.astype(jnp.float32)).max())
print(f"[1] sawtooth vs cyclic max |diff| = {err:.2e}  (math-preserving)")

# the Pallas TPU kernel (interpret mode on CPU) agrees too
out_pallas = ops.attention(
    q, k, v, order="sawtooth", causal=True, impl="pallas_interpret",
    q_block=128, kv_block=128,
)
err = float(jnp.abs(out_pallas.astype(jnp.float32) - out_cyc.astype(jnp.float32)).max())
print(f"[1] Pallas kernel vs XLA path max |diff| = {err:.2e}")

# --- 2. the paper's claim on the GB10 L2 simulator -------------------------
# (scaled geometry: KV=4MiB vs 3MiB L2 — same overflow ratio as the paper's
#  128K-token experiment; see benchmarks/ for the full-size run)
import dataclasses

hw = dataclasses.replace(GB10, cache_bytes=3 * 2**20)
w = AttentionWorkload(seq_len=16384, tile=64)
cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
red = 100 * (1 - saw.non_compulsory_misses / cyc.non_compulsory_misses)
print(
    f"[2] GB10 sim: non-compulsory misses {cyc.non_compulsory_misses:,.0f} -> "
    f"{saw.non_compulsory_misses:,.0f}  ({red:.0f}% reduction; paper: ~50%)"
)

# --- 3. TPU structural gain: pipeline fetch elision -------------------------
spec = FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=512, kv_block=512, causal=True)
tc = pipeline_traffic(spec, "cyclic")
ts = pipeline_traffic(spec, "sawtooth")
print(
    f"[3] TPU HBM->VMEM: cyclic {tc.kv_bytes/2**20:.0f} MiB, sawtooth "
    f"{ts.kv_bytes/2**20:.0f} MiB ({ts.elided_kv_fetches} elided fetches)"
)
