"""Paper figure reproductions (Figs 3-12).

No GB10 here: "measured" values come from the trace-driven LRU simulator
(GB10 geometry) and the analytic model; throughput figures use the additive
stall model calibrated ONLY on the paper's cyclic baselines (sawtooth
numbers are predictions). Figures whose full size would need >10^8 trace
events run at a KV:L2-ratio-preserving scale (noted in `derived`), since
miss *ratios* are scale-invariant in this regime (verified in tests).
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.cache_model import (
    GB10,
    AttentionWorkload,
    attention_flops,
    calibrate_miss_service,
    cold_miss_sectors,
    gb10_throughput_model,
    l2_sector_accesses,
)
from repro.core.cache_sim import simulate_attention


def bench_fig3_fig4_sector_model_vs_seq():
    """Fig 3 (non-causal) / Fig 4 (causal): L2 sectors vs S, model vs sim."""
    rows = []
    for causal, fig in ((False, "fig3"), (True, "fig4")):
        t0 = time.perf_counter()
        worst = 0.0
        for seq in (2048, 4096, 8192, 16384, 32768):
            w = AttentionWorkload(seq_len=seq, tile=80, causal=causal)
            sim = simulate_attention(w, GB10, "cyclic", n_workers=48)
            model = l2_sector_accesses(w, GB10)
            worst = max(worst, abs(model - sim.accesses) / sim.accesses)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"{fig}_sector_vs_seq", us, f"worst_err={100*worst:.3f}%"))
    return rows


def bench_fig5_divergence():
    """Fig 5: L2 misses ~= cold misses (16S) until KV ~ L2 capacity, then
    diverge. Paper: divergence at S ~ 80K (KV 20MiB vs 24MiB).
    Scaled geometry: L2/8 = 3MiB -> expected divergence at S ~ 10-12K."""
    hw = dataclasses.replace(GB10, cache_bytes=3 * 2**20)
    t0 = time.perf_counter()
    diverged_at = None
    for seq in (4096, 6144, 8192, 10240, 12288, 16384, 24576):
        w = AttentionWorkload(seq_len=seq, tile=80)
        r = simulate_attention(w, hw, "cyclic", n_workers=48)
        cold = cold_miss_sectors(w, hw)
        if r.misses > 1.15 * cold and diverged_at is None:
            diverged_at = seq
    us = (time.perf_counter() - t0) * 1e6
    # KV bytes at divergence, relative to cache (paper: 20MiB/24MiB = 0.83)
    kv_frac = 2 * diverged_at * 64 * 2 / hw.cache_bytes if diverged_at else float("nan")
    return [("fig5_divergence_scaled1/8", us, f"S_div={diverged_at},KV/L2={kv_frac:.2f}")]


def bench_fig6_hit_rate_vs_sms():
    """Fig 6: hit rate ~ 1 - 1/N_SM in the overflow regime."""
    hw = dataclasses.replace(GB10, cache_bytes=2 * 2**20)
    w = AttentionWorkload(seq_len=16384, tile=64)
    t0 = time.perf_counter()
    worst = 0.0
    for n in (2, 4, 8, 16, 32, 48):
        r = simulate_attention(w, hw, "cyclic", n_workers=n)
        worst = max(worst, abs(r.hit_rate - (1 - 1 / n)))
    us = (time.perf_counter() - t0) * 1e6
    return [("fig6_hitrate_1_minus_1_over_n", us, f"worst_abs_dev={worst:.4f}")]


# Paper CUDA numbers (Fig 7): cyclic ~1.3 TFLOPS -> sawtooth ~2.4 TFLOPS.
# Paper CuTile (Fig 9-12): tile 64, B=8, S=128K, D=64:
#   non-causal: 370M->120M miss sectors, 61->69 TFLOPS
#   causal:     41->66 TFLOPS
CUTILE_W = dict(tile=64, head_dim=64, batch=8)
CUTILE_KERNEL_PEAK = 74e12  # calibrated compute ceiling (EXPERIMENTS.md)


def _scaled_cutile(causal: bool, scale: int = 2):
    """KV:L2-ratio-preserving scale-down of the CuTile geometry
    (S 128K -> 128K/scale, L2 24 -> 24/scale MiB, B 8 -> 8/max(scale/2,1)).

    The miss-*reduction* is scale-sensitive below ~1/2 scale because worker/
    tile-count misalignment dilutes wavefront sharing (EXPERIMENTS.md
    §Paper-validation reports the full-geometry run from
    artifacts/fullscale_sim.json); 1/2 scale keeps the bench < 2 min.
    """
    hw = dataclasses.replace(GB10, cache_bytes=24 * 2**20 // scale)
    kw = dict(CUTILE_W)
    kw["batch"] = max(kw["batch"] // max(scale // 2, 1), 1)
    w = AttentionWorkload(seq_len=131072 // scale, causal=causal, **kw)
    return hw, w


def bench_fig7_fig8_cuda_sawtooth():
    """CUDA experiment (paper Fig 7/8: batch sweep B in {1,2,4,8}):
    ~50% non-compulsory miss reduction across all B, 1.3->2.4 TFLOPS.
    The CUDA kernel uses T=80 tiles (paper §3.2); geometry scaled 1/4 with
    the KV:L2 ratio preserved. Stall model calibrated on cyclic=1.3 only."""
    rows = []
    hw = dataclasses.replace(GB10, cache_bytes=6 * 2**20)
    reds = []
    t0 = time.perf_counter()
    last = None
    for batch in (1, 2, 4, 8):
        w = AttentionWorkload(seq_len=32768, tile=80, head_dim=64, batch=batch)
        cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
        saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
        red = 100 * (1 - saw.non_compulsory_misses / cyc.non_compulsory_misses)
        reds.append(f"B{batch}:{red:.0f}%")
        last = (w, cyc, saw)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        ("fig8_cuda_noncomp_miss_reduction", us, "|".join(reds) + "(paper~50%allB)")
    )

    # throughput: CUDA kernel is stall-dominated; calibrate svc on cyclic B=8
    w, cyc, saw = last
    svc = calibrate_miss_service(
        w, hw, observed_flops=1.3e12, miss_sectors=cyc.misses, kernel_peak=CUTILE_KERNEL_PEAK
    )
    pred = gb10_throughput_model(
        w, hw, saw.misses, miss_service_s=svc, kernel_peak=CUTILE_KERNEL_PEAK
    )
    rows.append(
        ("fig7_cuda_throughput_sawtooth", us, f"{pred/1e12:.2f}TFLOPS(paper~2.4)")
    )
    return rows


def bench_fig9_12_cutile():
    rows = []
    for causal, figs, base_tf, paper_tf in (
        (False, "fig9_10", 61e12, 69.0),
        (True, "fig11_12", 41e12, 66.0),
    ):
        t0 = time.perf_counter()
        hw, w = _scaled_cutile(causal)
        cyc = simulate_attention(w, hw, "cyclic", n_workers=48)
        saw = simulate_attention(w, hw, "sawtooth", n_workers=48)
        red = 100 * (1 - saw.misses / cyc.misses)
        svc = calibrate_miss_service(
            w, hw, observed_flops=base_tf, miss_sectors=cyc.misses,
            kernel_peak=CUTILE_KERNEL_PEAK,
        )
        pred = gb10_throughput_model(
            w, hw, saw.misses, miss_service_s=svc, kernel_peak=CUTILE_KERNEL_PEAK
        )
        us = (time.perf_counter() - t0) * 1e6
        name = "causal" if causal else "noncausal"
        rows.append(
            (f"{figs}_cutile_{name}", us,
             f"miss_red={red:.1f}%(paper~67%)|pred={pred/1e12:.1f}TFLOPS(paper~{paper_tf})")
        )
    return rows


def run():
    rows = []
    rows += bench_fig3_fig4_sector_model_vs_seq()
    rows += bench_fig5_divergence()
    rows += bench_fig6_hit_rate_vs_sms()
    rows += bench_fig7_fig8_cuda_sawtooth()
    rows += bench_fig9_12_cutile()
    return rows
