"""Kernel-level benchmarks (TPU-native view of the paper's technique).

1. Pallas flash kernels (fwd and the fused bwd) correctness-timed in
   interpret mode (CPU executes the kernel body; wall time is NOT TPU time —
   correctness + relative cost only).
2. HBM->VMEM traffic under Pallas pipeline-elision semantics: cyclic vs
   sawtooth on the forward grid AND the backward (dQ / transposed dK/dV)
   grids, the structural TPU analogue of the paper's L2 saving.
3. XLA-path blockwise attention wall time on CPU, cyclic vs sawtooth
   (order-invariance: times should match; the schedule is free), plus the
   fused-backward vs recompute-VJP train-microstep comparison.

``python benchmarks/kernel_bench.py [--quick] [--json BENCH_kernels.json]``
writes the rows as a JSON artifact so CI tracks the kernel perf trajectory
alongside BENCH_serve.json; ``benchmarks/run.py`` still consumes ``run()``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")  # allow running from repo root without installation

import jax
import jax.numpy as jnp

from repro.core.attention import flash_attention
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.traffic import (
    FlashGridSpec,
    bwd_dkv_llc_model,
    bwd_dkv_traffic,
    pipeline_traffic,
)


def _mk(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))  # one warmup call, block the whole pytree
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_pallas_interpret():
    rows = []
    q, k, v = _mk((1, 256, 2, 64), 1), _mk((1, 256, 2, 64), 2), _mk((1, 256, 2, 64), 3)
    for order in ("cyclic", "sawtooth"):
        fn = jax.jit(
            lambda q, k, v, o=order: flash_attention_fwd(
                q, k, v, order=o, causal=True, q_block=128, kv_block=128, interpret=True
            )
        )
        us = _time(fn, q, k, v)
        rows.append((f"pallas_flash_interpret_{order}", us, "s256_h2_d64"))
    return rows


def bench_pallas_bwd_interpret():
    """Fused Pallas backward (delta + dQ + dK/dV kernels), interpret mode."""
    from repro.kernels.flash_attention import flash_attention_bwd

    rows = []
    q, k, v = _mk((1, 256, 2, 64), 1), _mk((1, 256, 2, 64), 2), _mk((1, 256, 2, 64), 3)
    do = _mk((1, 256, 2, 64), 4)
    for order in ("cyclic", "sawtooth"):
        o, lse = flash_attention_fwd(
            q, k, v, order=order, causal=True, q_block=128, kv_block=128,
            interpret=True, return_lse=True,
        )
        fn = jax.jit(
            lambda q, k, v, o, lse, do, ord_=order: flash_attention_bwd(
                q, k, v, o, lse, do, order=ord_, causal=True,
                q_block=128, kv_block=128, interpret=True,
            )
        )
        us = _time(fn, q, k, v, o, lse, do)
        rows.append((f"pallas_flash_bwd_interpret_{order}", us, "s256_h2_d64"))
    return rows


def bench_fused_bwd_vs_recompute():
    """Train-microstep (fwd+bwd) on the XLA path: fused bwd vs recompute-VJP.

    The fused path replaces the recompute's extra attention-equivalent pass
    with the standard 2-pass backward; on CPU the wall-clock delta is the
    observable proxy for the 3-pass -> 2-pass conversion.
    """
    from repro.kernels import ops

    rows = []
    q, k, v = _mk((2, 1024, 4, 64), 1), _mk((2, 1024, 2, 64), 2), _mk((2, 1024, 2, 64), 3)
    times = {}
    for impl in ("xla", "jnp"):
        fn = jax.jit(
            jax.grad(
                lambda q, k, v, i=impl: (
                    ops.attention(q, k, v, causal=True, impl=i,
                                  q_block=256, kv_block=256) ** 2
                ).sum(),
                argnums=(0, 1, 2),
            )
        )
        times[impl] = _time(fn, q, k, v, reps=5)
        tag = "fused" if impl == "xla" else "recompute"
        rows.append((f"microstep_bwd_{tag}", times[impl], "s1024_h4_d64_cpu"))
    rows.append(
        ("microstep_fused_speedup", 0.0, f"{times['jnp'] / times['xla']:.3f}x")
    )
    return rows


def bench_traffic_model():
    rows = []
    cases = [
        ("train4k", FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=512, kv_block=512, causal=True)),
        ("prefill32k", FlashGridSpec(seq_q=32768, seq_kv=32768, q_block=512, kv_block=512, causal=True)),
        ("swa32k", FlashGridSpec(seq_q=32768, seq_kv=32768, q_block=512, kv_block=512, causal=True, window=4096)),
        ("noncausal8k", FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=256, kv_block=256)),
    ]
    for name, spec in cases:
        t0 = time.perf_counter()
        cyc = pipeline_traffic(spec, "cyclic")
        saw = pipeline_traffic(spec, "sawtooth")
        us = (time.perf_counter() - t0) * 1e6
        red = 100 * (1 - saw.kv_bytes / cyc.kv_bytes)
        rows.append(
            (f"tpu_traffic_{name}", us,
             f"kv_fetch_red={red:.2f}%|elided={saw.elided_kv_fetches}/{saw.total_kv_fetches}")
        )
    return rows


def bench_bwd_traffic_model():
    """Backward (dK/dV transposed grid) traffic: pipeline elision + LLC model."""
    rows = []
    cases = [
        ("train4k", FlashGridSpec(seq_q=4096, seq_kv=4096, q_block=512, kv_block=512, causal=True)),
        ("prefill32k", FlashGridSpec(seq_q=32768, seq_kv=32768, q_block=512, kv_block=512, causal=True)),
        ("gqa8k", FlashGridSpec(seq_q=8192, seq_kv=8192, q_block=256, kv_block=256, n_groups=4)),
    ]
    for name, spec in cases:
        t0 = time.perf_counter()
        cyc = bwd_dkv_traffic(spec, "cyclic")
        saw = bwd_dkv_traffic(spec, "sawtooth")
        llc_c = bwd_dkv_llc_model(spec, "cyclic", n_workers=1)
        llc_s = bwd_dkv_llc_model(spec, "sawtooth", n_workers=1)
        us = (time.perf_counter() - t0) * 1e6
        pipe_red = 100 * (1 - saw.stream_bytes / cyc.stream_bytes)
        llc_red = 100 * (1 - llc_s.non_compulsory_misses / max(llc_c.non_compulsory_misses, 1))
        rows.append(
            (f"tpu_bwd_dkv_traffic_{name}", us,
             f"stream_red={pipe_red:.2f}%|llc_miss_red={llc_red:.1f}%"
             f"|elided={saw.elided_stream_fetches}/{saw.total_stream_fetches}")
        )
    return rows


def bench_xla_order_invariance():
    rows = []
    q, k, v = _mk((2, 1024, 4, 64), 1), _mk((2, 1024, 2, 64), 2), _mk((2, 1024, 2, 64), 3)
    times = {}
    for order in ("cyclic", "sawtooth"):
        fn = jax.jit(
            lambda q, k, v, o=order: flash_attention(
                q, k, v, order=o, causal=True, q_block=256, kv_block=256
            )
        )
        times[order] = _time(fn, q, k, v, reps=5)
        rows.append((f"xla_flash_{order}", times[order], "s1024_h4_d64_cpu"))
    ratio = times["sawtooth"] / times["cyclic"]
    rows.append(("xla_order_overhead_ratio", 0.0, f"{ratio:.3f}(want~1.0)"))
    return rows


def bench_ssd_backward_sawtooth():
    """Beyond-paper: the SSD backward is a *free* sawtooth.

    lax.scan's VJP walks chunks in reverse, so the fwd(1..N) + bwd(N..1)
    pair is exactly the paper's sawtooth retraversal: the boundary chunk is
    hot when the backward starts. A naive forward-order recompute (bwd
    1..N, what a remat policy that replays the forward would do) has reuse
    distance = the whole sequence. Quantified on the chunk-granular LRU with
    a buffer of half the chunk stream (mamba2-130m train_4k geometry per
    device: S=4096, chunk=128 -> 32 chunks of x/dt/B/C).
    """
    from repro.core.cache_sim import simulate_trace

    n_chunks, chunk_bytes = 32, 128 * (64 + 64 + 128 + 128) * 4  # x,dt-ish,B,C f32
    cap = n_chunks * chunk_bytes // 2  # buffer holds half the stream

    def trace(bwd_reversed):
        fwd = [(("c", i), chunk_bytes) for i in range(n_chunks)]
        order = range(n_chunks - 1, -1, -1) if bwd_reversed else range(n_chunks)
        bwd = [(("c", i), chunk_bytes) for i in order]
        return fwd + bwd

    t0 = time.perf_counter()
    saw = simulate_trace(trace(True), cap)
    cyc = simulate_trace(trace(False), cap)
    us = (time.perf_counter() - t0) * 1e6
    red = 100 * (1 - saw.non_compulsory_misses / max(cyc.non_compulsory_misses, 1))
    return [
        (
            "ssd_bwd_sawtooth_reread_reduction",
            us,
            f"{red:.0f}%({saw.non_compulsory_misses/chunk_bytes:.0f}vs"
            f"{cyc.non_compulsory_misses/chunk_bytes:.0f}chunk_rereads)",
        )
    ]


def run(quick: bool = False):
    rows = []
    rows += bench_pallas_interpret()
    rows += bench_pallas_bwd_interpret()
    rows += bench_traffic_model()
    rows += bench_bwd_traffic_model()
    rows += bench_xla_order_invariance()
    if not quick:
        rows += bench_fused_bwd_vs_recompute()
    rows += bench_ssd_backward_sawtooth()
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the s1024 microstep comparison (CI smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows to a JSON artifact (e.g. BENCH_kernels.json)")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(quick=args.quick)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {
                    "bench": "kernels",
                    "quick": args.quick,
                    "wall_s": round(time.time() - t0, 2),
                    "rows": [
                        {"name": n, "us_per_call": round(us, 1), "derived": d}
                        for n, us, d in rows
                    ],
                },
                f,
                indent=1,
            )
        print(f"wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
