"""CI schema check for the obs telemetry sinks.

  PYTHONPATH=src python benchmarks/check_metrics.py metrics.jsonl trace.json

Fails (exit 1, naming every violation) when the serve-smoke telemetry dump
is missing required series or the trace file breaks the Chrome-trace event
schema — the structured companion to BENCH_serve.json: a refactor that
silently stops emitting TTFT histograms or the modeled-LLC gauges turns the
job red instead of rotting the dashboard.

Checks:

* metrics.jsonl — every line parses, carries ``schema_version`` (matching
  ``repro.obs.export.SCHEMA_VERSION``) and a kind/name/labels triple;
  required series exist: TTFT/TPOT histograms, per-kind token counters
  (decode AND prefill), pool occupancy + prefix-sharing gauges/counters,
  the resilience counters (preemptions / restore tokens / shed /
  deadline misses / cancels) and admission-paused gauge, the ``tier.*``
  tiering counters/gauges (with ``tier.prefetch_hits + tier.prefetch_wasted
  == tier.fetches`` — prefetch conservation), the ``serve.spec.*``
  speculative-decoding counters (with ``serve.spec.accepted_tokens +
  serve.spec.rollback_tokens == serve.spec.draft_tokens`` — draft-token
  conservation),
  and ``llc.modeled_miss_bytes`` gauges for >= 2 distinct traversal orders;
  histogram lines carry consistent buckets (cumulative, ending at +Inf,
  count == last cumulative).
* trace.json — valid JSON with a non-empty ``traceEvents`` list; every
  event has name/ph/ts/pid/tid; complete events (``ph="X"``) carry a
  non-negative ``dur``; timestamps are finite numbers.
"""

from __future__ import annotations

import argparse
import json
import sys

REQUIRED_HISTOGRAMS = ("serve.ttft_s", "serve.tpot_s", "serve.step_time_s")
REQUIRED_COUNTER_SERIES = (
    ("serve.step.tokens", {"kind": "decode"}),
    ("serve.step.tokens", {"kind": "prefill"}),
    ("serve.tokens.generated", {}),
    ("serve.steps", {"width": "wide"}),
    ("serve.steps", {"width": "narrow"}),
    ("pool.pages_adopted", {}),
    ("pool.cow_forks", {}),
    ("serve.order_switches", {}),
    # Resilience counters (DESIGN.md §12): pre-created at engine start so
    # they exist (at 0) even on a run with no pressure — the schema can
    # require them unconditionally.
    ("serve.preemptions", {}),
    ("serve.restore_tokens", {}),
    ("serve.shed", {}),
    ("serve.deadline_miss", {}),
    ("serve.cancelled", {}),
    # Tiering counters (DESIGN.md §13): pre-created at engine start like
    # the resilience series, so an untiered run still carries them at 0.
    ("tier.spills", {}),
    ("tier.fetches", {}),
    ("tier.prefetch_hits", {}),
    ("tier.prefetch_wasted", {}),
    # Speculative-decoding counters (DESIGN.md §14): pre-created at engine
    # start, so a run with no drafter still carries them at 0.
    ("serve.spec.draft_tokens", {}),
    ("serve.spec.accepted_tokens", {}),
    ("serve.spec.rollback_tokens", {}),
)
REQUIRED_GAUGES = (
    "pool.occupancy_frac",
    "pool.pages_free",
    "pool.shared_pages",
    "serve.queue_depth",
    "serve.budget_utilization",
    "serve.current_order",
    "serve.admission_paused",
    "tier.host_pages",
    "tier.device_pages",
    "tier.overlap_frac",
    "llc.footprint_bytes",
)
MIN_LLC_ORDERS = 2


def check_metrics(
    path: str,
    errors: list,
    min_order_switches: int = 0,
    min_prefetch_hits: int = 0,
    min_draft_tokens: int = 0,
) -> None:
    try:
        with open(path) as f:
            lines = [json.loads(ln) for ln in f if ln.strip()]
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"{path}: unreadable/unparseable: {e}")
        return
    if not lines:
        errors.append(f"{path}: empty metrics dump")
        return

    by_kind = {"counter": {}, "gauge": {}, "histogram": {}}
    for i, rec in enumerate(lines):
        for field in ("schema_version", "kind", "name", "labels"):
            if field not in rec:
                errors.append(f"{path}:{i + 1}: missing {field!r}")
        kind = rec.get("kind")
        if kind not in by_kind:
            errors.append(f"{path}:{i + 1}: unknown kind {kind!r}")
            continue
        by_kind[kind][(rec["name"], tuple(sorted(rec["labels"].items())))] = rec

    def has(kind, name, labels):
        return (name, tuple(sorted(labels.items()))) in by_kind[kind]

    for name in REQUIRED_HISTOGRAMS:
        if not has("histogram", name, {}):
            errors.append(f"{path}: missing histogram {name}")
    for name, labels in REQUIRED_COUNTER_SERIES:
        if not has("counter", name, labels):
            errors.append(f"{path}: missing counter {name} {labels}")
    for name in REQUIRED_GAUGES:
        if not has("gauge", name, {}):
            errors.append(f"{path}: missing gauge {name}")

    llc_orders = {
        labels_dict.get("order")
        for (name, labels), rec in by_kind["gauge"].items()
        if name == "llc.modeled_miss_bytes"
        for labels_dict in (dict(labels),)
    }
    llc_orders.discard(None)
    if len(llc_orders) < MIN_LLC_ORDERS:
        errors.append(
            f"{path}: llc.modeled_miss_bytes gauges cover {sorted(llc_orders)} "
            f"— need >= {MIN_LLC_ORDERS} traversal orders"
        )

    if min_order_switches > 0:
        rec = by_kind["counter"].get(("serve.order_switches", ()))
        got = rec.get("value", 0) if rec else 0
        if got < min_order_switches:
            errors.append(
                f"{path}: serve.order_switches = {got} — the adaptation "
                f"smoke requires >= {min_order_switches} order switch(es)"
            )

    # Prefetch conservation (DESIGN.md §13): every page the prefetcher
    # fetched is eventually attended (hit) or released unused (wasted) —
    # a drained run must balance exactly.
    def cval(name):
        rec = by_kind["counter"].get((name, ()))
        return rec.get("value", 0) if rec else 0

    fetches = cval("tier.fetches")
    hits, wasted = cval("tier.prefetch_hits"), cval("tier.prefetch_wasted")
    if hits + wasted != fetches:
        errors.append(
            f"{path}: prefetch accounting drift: tier.prefetch_hits ({hits}) "
            f"+ tier.prefetch_wasted ({wasted}) != tier.fetches ({fetches})"
        )
    if min_prefetch_hits > 0 and hits < min_prefetch_hits:
        errors.append(
            f"{path}: tier.prefetch_hits = {hits} — the tiering smoke "
            f"requires >= {min_prefetch_hits} prefetch hit(s)"
        )

    # Speculative conservation (DESIGN.md §14): every drafted token is
    # either accepted into the committed stream or rolled back off the KV
    # cache — accepted + rolled_back must balance drafted exactly.
    drafted = cval("serve.spec.draft_tokens")
    acc, rolled = cval("serve.spec.accepted_tokens"), cval(
        "serve.spec.rollback_tokens"
    )
    if acc + rolled != drafted:
        errors.append(
            f"{path}: speculative accounting drift: accepted ({acc}) + "
            f"rolled back ({rolled}) != drafted ({drafted})"
        )
    if min_draft_tokens > 0 and drafted < min_draft_tokens:
        errors.append(
            f"{path}: serve.spec.draft_tokens = {drafted} — the speculative "
            f"smoke requires >= {min_draft_tokens} drafted token(s)"
        )

    for (name, labels), rec in by_kind["histogram"].items():
        buckets = rec.get("buckets", [])
        if not buckets or buckets[-1][0] != "+Inf":
            errors.append(f"{path}: histogram {name}: buckets must end at +Inf")
            continue
        cums = [c for _, c in buckets]
        if cums != sorted(cums):
            errors.append(f"{path}: histogram {name}: non-cumulative buckets")
        if rec.get("count") != cums[-1]:
            errors.append(
                f"{path}: histogram {name}: count {rec.get('count')} != "
                f"last cumulative bucket {cums[-1]}"
            )


def check_trace(path: str, errors: list) -> None:
    try:
        with open(path) as f:
            trace = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"{path}: unreadable/unparseable: {e}")
        return
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        errors.append(f"{path}: traceEvents missing or empty")
        return
    names = set()
    for i, ev in enumerate(events):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                errors.append(f"{path}: event {i}: missing {field!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{path}: event {i}: non-numeric ts {ts!r}")
        if ev.get("ph") == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{path}: event {i}: X-event bad dur {dur!r}")
        names.add(ev.get("name"))
    for required in ("serve.step", "serve.plan_step", "serve.device_step"):
        if required not in names:
            errors.append(f"{path}: no {required!r} spans recorded")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("metrics", help="metrics JSONL from --metrics-out")
    ap.add_argument("trace", help="Chrome-trace JSON from --trace-out")
    ap.add_argument("--min-order-switches", type=int, default=0, metavar="N",
                    help="require the serve.order_switches counter to be "
                         ">= N (the --attn-order auto adaptation smoke)")
    ap.add_argument("--min-prefetch-hits", type=int, default=0, metavar="N",
                    help="require the tier.prefetch_hits counter to be "
                         ">= N (the --host-pages tiering smoke)")
    ap.add_argument("--min-draft-tokens", type=int, default=0, metavar="N",
                    help="require the serve.spec.draft_tokens counter to be "
                         ">= N (the --draft speculative smoke)")
    args = ap.parse_args()

    errors: list[str] = []
    check_metrics(
        args.metrics,
        errors,
        min_order_switches=args.min_order_switches,
        min_prefetch_hits=args.min_prefetch_hits,
        min_draft_tokens=args.min_draft_tokens,
    )
    check_trace(args.trace, errors)
    if errors:
        print(f"check_metrics: {len(errors)} violation(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"check_metrics: OK ({args.metrics}, {args.trace})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
