# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time


def main() -> None:
    # allow running from repo root without installation
    sys.path.insert(0, "src")
    from benchmarks import kernel_bench, paper_figures, paper_tables, roofline_bench

    print("name,us_per_call,derived")
    t_all = time.time()
    for mod in (paper_tables, paper_figures, kernel_bench, roofline_bench):
        for name, us, derived in mod.run():
            print(f"{name},{us:.1f},{derived}")
    print(f"# total bench wall time: {time.time() - t_all:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
