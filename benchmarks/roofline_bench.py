"""Roofline table from dry-run artifacts (assignment deliverable g).

Reads artifacts/dryrun/*.json. Prefers the trip-count-corrected records
(*.rf.json, unrolled depth-1/2 extrapolation) and falls back to the raw
scanned-compile records where the rf pass hasn't run.
"""

from __future__ import annotations

import glob
import json
import os

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def load_records():
    recs = {}
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if p.endswith(".rf.json"):
            continue
        with open(p) as f:
            r = json.load(f)
        key = (r["arch"], r["shape"], r["mesh"])
        recs[key] = r
        rf_path = p.replace(".json", ".rf.json")
        if os.path.exists(rf_path):
            with open(rf_path) as f:
                rf = json.load(f)
            if rf.get("status") == "ok":
                r["roofline"] = rf["roofline"]
                r["rf_corrected"] = True
    return recs


def run():
    rows = []
    recs = load_records()
    if not recs:
        return [("roofline_table", 0.0, "NO_ARTIFACTS_run_dryrun_first")]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if r["status"] == "skipped":
            rows.append((f"roofline_{arch}_{shape}_{mesh}", 0.0, "skipped:" + r["reason"][:40]))
            continue
        if r["status"] != "ok":
            rows.append((f"roofline_{arch}_{shape}_{mesh}", 0.0, "ERROR"))
            continue
        rf = r["roofline"]
        tag = "rf" if r.get("rf_corrected") else "raw"
        rows.append(
            (
                f"roofline_{arch}_{shape}_{mesh}",
                r.get("compile_s", 0.0) * 1e6,
                f"{tag}|bneck={rf['bottleneck']}|Tc={rf['compute_s']:.4f}|"
                f"Tm={rf['memory_s']:.4f}|Tx={rf['collective_s']:.4f}|"
                f"util={rf['hw_flops_util']:.4f}|useful={rf['useful_ratio']:.3f}",
            )
        )
    return rows
