"""Paper table reproductions (Tables 1-3).

Table 1/2 — L1/L2 counter structure at SM=48 (persistent + non-persistent):
the paper's central measurement is that L2 traffic ≈ L1Tex pass-through
traffic and matches the analytic sector model. We reproduce the L2 rows
from the model + simulator and check against the paper's published values.
(The L1-hit rows are hardware counters with no analogue here; the model's
"L1 = pass-through" assumption IS the reproduction of that finding.)

Table 3 — MAPE of the model vs (paper-published) measurements.
"""

from __future__ import annotations

import time

from repro.core.cache_model import (
    GB10,
    AttentionWorkload,
    l2_sector_accesses,
    l2_sector_accesses_simple,
)
from repro.core.cache_sim import simulate_attention

# Paper Table 1 (persistent CTA) and Table 2 (non-persistent), SM=48, T=80.
PAPER_T1_TOTAL = {32768: 107_729_467, 131072: 1_723_556_561}
PAPER_T1_FROMTEX = {32768: 107_478_656, 131072: 1_719_093_980}
PAPER_T2_TOTAL = {32768: 107_991_698, 131072: 1_723_401_754}


def bench_table1_counter_model():
    """Returns rows (name, us, derived=MAPE%)."""
    rows = []
    for seq, measured in sorted(PAPER_T1_TOTAL.items()):
        w = AttentionWorkload(seq_len=seq, tile=80)
        t0 = time.perf_counter()
        pred = l2_sector_accesses(w, GB10)
        us = (time.perf_counter() - t0) * 1e6
        mape = 100 * abs(pred - measured) / measured
        rows.append((f"table1_l2_total_s{seq//1024}k", us, f"{mape:.3f}%MAPE"))
        # from-tex row (model counts exactly the L1Tex-path traffic)
        mape_tex = 100 * abs(pred - PAPER_T1_FROMTEX[seq]) / PAPER_T1_FROMTEX[seq]
        rows.append((f"table1_l2_fromtex_s{seq//1024}k", us, f"{mape_tex:.3f}%MAPE"))
    return rows


def bench_table2_scheduling_invariance():
    """Paper finding: persistent vs non-persistent scheduling changes L2
    traffic by <0.3%. Our wavefront simulator reproduces this: grid-stride
    (persistent) vs block-per-tile ordering gives identical tile access
    multisets, so identical model counts; we check the paper's two
    measurements agree with one model value."""
    rows = []
    for seq in sorted(PAPER_T2_TOTAL):
        w = AttentionWorkload(seq_len=seq, tile=80)
        t0 = time.perf_counter()
        pred = l2_sector_accesses(w, GB10)
        us = (time.perf_counter() - t0) * 1e6
        delta = 100 * abs(PAPER_T2_TOTAL[seq] - PAPER_T1_TOTAL[seq]) / PAPER_T1_TOTAL[seq]
        mape = 100 * abs(pred - PAPER_T2_TOTAL[seq]) / PAPER_T2_TOTAL[seq]
        rows.append(
            (f"table2_nonpersistent_s{seq//1024}k", us, f"{mape:.3f}%MAPE(sched_delta={delta:.3f}%)")
        )
    return rows


def bench_table3_mape():
    """MAPE of model vs simulator-measured accesses over a seq sweep
    (simulator stands in for ncu; paper: 0.45% non-causal, 2.49% causal)."""
    rows = []
    for causal in (False, True):
        errs = []
        t0 = time.perf_counter()
        for seq in (2048, 4096, 8192, 16384):
            w = AttentionWorkload(seq_len=seq, tile=80, causal=causal)
            sim = simulate_attention(w, GB10, "cyclic", n_workers=48)
            model = l2_sector_accesses_simple(w, GB10)
            errs.append(abs(model - sim.accesses) / sim.accesses)
        us = (time.perf_counter() - t0) * 1e6
        mape = 100 * sum(errs) / len(errs)
        name = "causal" if causal else "noncausal"
        rows.append((f"table3_mape_{name}", us, f"{mape:.3f}%MAPE"))
    return rows


def run():
    rows = []
    rows += bench_table1_counter_model()
    rows += bench_table2_scheduling_invariance()
    rows += bench_table3_mape()
    return rows
