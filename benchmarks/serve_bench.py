"""Serve benchmark: static vs continuous scheduling, with latency percentiles.

Two request streams through the ServeEngine on CPU:

* ``mixed`` — many short prompts, a few long high-``max_new`` stragglers,
  staggered arrivals. The static path pays for its stragglers — every group
  decodes until its slowest member finishes — while the continuous
  scheduler's token-budget ragged mixed step chunk-preempts long prefills
  and refills slots mid-decode, closing the stream in far fewer steps.
* ``shared_prefix`` — every request carries the same long system prompt
  plus a short unique tail (the RAG / chat-serving shape). Run through the
  continuous engine twice: with the pool's content-hash prefix sharing on
  and off. Sharing admits later requests with their prefix KV already
  resident (zero prefill compute for those pages, copy-on-write isolation
  for the tail), which shows up directly in the TTFT percentiles.
* ``order_adaptation`` — a decode stream whose KV footprint grows across
  the modeled-LLC order-flip boundary mid-run. Pinned cyclic and pinned
  block_snake engines vs the online adaptation controller
  (``repro.serve.adapt``); incurred modeled miss bytes are integrated from
  the LLC-sampler histories and split at the flip. Deterministic (model
  output, no wall clock) and asserted: adaptive must match the best fixed
  order on both halves, beat the worse fixed order end-to-end, and switch
  without a single step recompile.
* ``overload`` — the resilience layer (DESIGN.md §12) under a pool sized
  to half the batch's worst case (2x oversubscription). Three parts, all
  asserted: optimistic admission must preempt, restore, and still produce
  bitwise the reserve engine's greedy tokens; a deadline/load-shed burst
  must resolve every request with a typed status and positive goodput;
  and a seeded chaos ``FaultPlan`` (injected pool exhaustion + a transient
  device-step failure + a mid-prefill cancel) must finish with zero
  uncaught exceptions, exactly one step retry, and clean pool invariants.
* ``long_context`` — the tiered KV memory layer (DESIGN.md §13) with the
  device pool sized under half the working set. The same long-prompt
  stream through a preempt-only engine (restores by chunked re-prefill —
  paying the prompt's prefill compute again on every restore) and a
  tiered one (spills victim pages to a host store, prefetches them back
  in the traversal's future visit order). Asserted: bitwise token parity
  with an unconstrained reference on both engines, >= 1 spill and zero
  tiered preemptions, prefetch hit rate >= 0.8, and modeled device work
  (padded step slots + copy-charged tier traffic — deterministic, unlike
  CI wall clock) >= 1.5x better than preempt-only.
* ``speculative`` — draft-and-verify on the unified ragged step
  (DESIGN.md §14): an n-gram prompt-lookup drafter and a draft-model
  drafter (self-speculation) vs the plain engine on a decode-heavy
  repetitive stream. Asserted: bitwise greedy AND sampled token parity,
  draft/accept/rollback counter conservation, two compiled step widths,
  >= 1.5x on both mixed-step count and TPOT p50 for the n-gram drafter,
  ~100% model-drafter acceptance, and a seeded mid-verification
  device-step fault that retries once with the stream unchanged.

``--scenario`` picks one scenario (CI's chaos smoke runs
``--quick --scenario overload``); the default runs them all.

Per scheduler/scenario the report carries tokens/s plus TTFT and TPOT
p50/p95 (per-request wall-clock, captured by the engine), and the
``cache_sim`` page-locality twins: the cyclic-vs-sawtooth reuse-distance
delta of decode page traversal, and the shared-vs-private reuse-distance
delta of the step-level shared-page visit order (cross-row LLC reuse of a
deduplicated prefix).

Writes ``BENCH_serve.json`` (CI artifact; scheduler regressions show up as
``speedup`` < 1 or ``shared_prefix.ttft_p95_improvement`` < 1) and prints a
one-line summary per engine.

  PYTHONPATH=src python benchmarks/serve_bench.py            # full smoke
  PYTHONPATH=src python benchmarks/serve_bench.py --quick    # CI-sized
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def build_requests(np, vocab, *, n_short: int, n_long: int, max_new_long: int):
    """Interleave short and long requests with staggered arrivals.

    Interleaving puts roughly one long straggler in every static group —
    the adversarial-but-realistic shape for fixed-group scheduling.
    """
    from repro.serve import Request

    rng = np.random.default_rng(0)
    reqs = []
    n_groups = max(n_long, 1)
    per_group = (n_short + n_long) // n_groups if n_groups else 0
    rid = 0
    for g in range(n_groups):
        reqs.append(
            Request(
                tokens=rng.integers(2, vocab, size=24).astype(np.int32),
                max_new_tokens=max_new_long,
                rid=rid,
                arrival=g,
            )
        )
        rid += 1
        for _ in range(max(per_group - 1, 0)):
            reqs.append(
                Request(
                    tokens=rng.integers(2, vocab, size=int(rng.integers(4, 9))).astype(
                        np.int32
                    ),
                    max_new_tokens=4,
                    rid=rid,
                    arrival=g,
                )
            )
            rid += 1
    return reqs


def build_shared_prefix_requests(
    np, vocab, *, n_requests: int, prefix_len: int, tail_max: int, max_new: int
):
    """One shared system prompt + unique tails, arrivals staggered so the
    registry is populated before most admissions (the steady-state serving
    shape for prefix caching)."""
    from repro.serve import Request

    rng = np.random.default_rng(1)
    sysp = rng.integers(2, vocab, size=prefix_len).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        if i % 4 == 0 and i > 0:
            # A bare-system-prompt request ending mid-page: its admission
            # adopts the partially covered page too, and the first write
            # into it exercises the pool's copy-on-write fork.
            tokens = sysp[: prefix_len - 3].copy()
        else:
            tail = rng.integers(2, vocab, size=int(rng.integers(2, tail_max + 1)))
            tokens = np.concatenate([sysp, tail.astype(np.int32)])
        reqs.append(
            Request(
                tokens=tokens,
                max_new_tokens=max_new,
                rid=i,
                arrival=i,
            )
        )
    return reqs


def order_adaptation_scenario(jax, np, *, arch: str, params) -> dict:
    """Flip-boundary adaptive-serving scenario (DESIGN.md §11).

    One request whose KV footprint grows across the modeled-LLC order-flip
    boundary mid-decode: at 32 KiB modeled capacity / 16-token pages the
    fwd LLC model prefers cyclic up to 14 resident pages and block_snake
    from 15 on. Three continuous engines serve the *same* stream —
    pinned cyclic, pinned block_snake, and adaptive (``adapt_order=True``,
    seeded from an autotune cache rebuilt out of the committed hillclimb
    sweep artifacts) — and the incurred modeled miss bytes are integrated
    from each engine's LLC-sampler history: every sample contributes
    ``fwd_miss[current_order]``, the modeled bytes of the order actually
    bound at that point of the run. The adaptive engine must match the best
    fixed order on *both* sides of the flip and strictly beat the worse
    fixed order end-to-end, with zero step recompiles across the switch.

    Wall-clock-free by construction: every number here is deterministic
    model output, so the committed BENCH artifact is stable across hosts.
    """
    import glob
    import os
    import tempfile

    from repro.configs import get_config
    from repro.models import build_model
    from repro.obs.export import append_jsonl
    from repro.serve import Request, ServeEngine

    page, max_len, chunk, epoch = 16, 256, 32, 2
    capacity = 32 * 1024  # modeled LLC: flips cyclic -> block_snake at 15 pages
    snake_group = 4
    base = get_config(arch).reduced()

    # Rebuild the persistent autotune cache from the committed sweep
    # artifacts (the JSONL itself is a sink, not committed): the adaptive
    # engine's startup consultation resolves the nearest seq bucket.
    cache = os.path.join(tempfile.mkdtemp(prefix="autotune_"), "cache.jsonl")
    sweeps = []
    for path in sorted(glob.glob(f"artifacts/hillclimb/order_sweep_{arch}_s*.json")):
        rec = json.load(open(path))
        sweeps.append({"seq": rec["seq"], "winner": rec["winner"]["order"]})
        append_jsonl(
            cache,
            {
                "key": {
                    "arch": rec["arch"],
                    "seq_bucket": rec["seq"],
                    "capacity_mib": rec["capacity_mib"],
                    "n_workers": rec["n_workers"],
                    "backend": rec["backend"],
                },
                "winner": rec["winner"],
            },
            kind="order_sweep",
        )

    def make():
        rng = np.random.default_rng(7)
        return [
            Request(
                tokens=rng.integers(2, base.vocab, size=208).astype(np.int32),
                max_new_tokens=48,
                rid=0,
            )
        ]

    def run(attn_order, **adapt_kw):
        lm = build_model(
            base.with_(attn_order=attn_order, snake_group=snake_group)
        )
        eng = ServeEngine(
            lm,
            params,
            batch_size=2,
            max_len=max_len,
            scheduler="continuous",
            page_size=page,
            prefill_chunk=chunk,
            llc_every=epoch,
            llc_capacity_bytes=capacity,
            **adapt_kw,
        )
        res = eng.generate(make())
        return eng, res[0].tokens

    eng_c, tok_c = run("cyclic")
    eng_b, tok_b = run("block_snake")
    # Adaptive starts from the arch default (sawtooth) so the cache seeding
    # is observable: the s8192 sweep winner (cyclic) replaces it at start.
    eng_a, tok_a = run(
        "sawtooth",
        adapt_order=True,
        adapt_epoch=epoch,
        adapt_hysteresis=0.02,
        adapt_confirm=1,
        autotune_cache=cache,
    )

    # Traversal order only permutes the online-softmax reduction, which is
    # order-invariant: one stream, bitwise-identical tokens on all engines.
    assert (tok_a == tok_c).all() and (tok_a == tok_b).all(), "token parity"

    hists = {"cyclic": eng_c.llc.history, "block_snake": eng_b.llc.history,
             "adaptive": eng_a.llc.history}
    n = len(hists["adaptive"])
    assert n and all(len(h) == n for h in hists.values()), "history alignment"

    start_order = hists["adaptive"][0]["current_order"]
    flip = next(
        (i for i, e in enumerate(hists["adaptive"])
         if e["current_order"] != start_order),
        n,
    )

    def incurred(hist, lo, hi):
        return sum(e["fwd_miss"][e["current_order"]] for e in hist[lo:hi])

    halves = {
        name: {
            "pre_flip_mib": round(incurred(h, 0, flip) / 2**20, 4),
            "post_flip_mib": round(incurred(h, flip, n) / 2**20, 4),
            "total_mib": round(incurred(h, 0, n) / 2**20, 4),
        }
        for name, h in hists.items()
    }
    ad, fixed = halves["adaptive"], {k: halves[k] for k in ("cyclic", "block_snake")}
    eps = 1e-6
    ok_halves = all(
        ad[half] <= min(f[half] for f in fixed.values()) + eps
        for half in ("pre_flip_mib", "post_flip_mib")
    )
    worse_fixed = max(fixed, key=lambda k: fixed[k]["total_mib"])
    ok_total = ad["total_mib"] < fixed[worse_fixed]["total_mib"] - eps

    out = {
        "page_size": page,
        "max_len": max_len,
        "capacity_bytes": capacity,
        "adapt_epoch": epoch,
        "autotune_cache_sweeps": sweeps,
        "seeded_order": start_order,
        "final_order": hists["adaptive"][-1]["current_order"],
        "order_switches": eng_a.order_ctl.switches,
        "flip_sample": flip,
        "samples": n,
        "flip_footprint_pages": (
            -(-hists["adaptive"][flip]["max_len"] // page) if flip < n else None
        ),
        "modeled_mib": halves,
        "adaptive_matches_best_fixed_both_halves": ok_halves,
        "adaptive_beats_worse_fixed_end_to_end": ok_total,
        "worse_fixed": worse_fixed,
        "token_parity": True,
        "compiled_steps": eng_a.compiled_step_count(),
    }
    assert eng_a.order_ctl.switches >= 1, "adaptive engine never switched"
    assert out["compiled_steps"] == 2, "order switch must not recompile"
    assert ok_halves, f"adaptive worse than best fixed on a half: {halves}"
    assert ok_total, f"adaptive not better than worse fixed: {halves}"
    return out


def overload_scenario(jax, np, *, lm, params, vocab, quick: bool) -> dict:
    """Resilience under 2x pool oversubscription (DESIGN.md §12).

    The pool is sized to half the batch's concurrent worst case
    (``batch * pages_for(prompt + max_new) // 2``), the one knob that makes
    mid-flight exhaustion *reachable* — the default pool guarantees every
    slot its full capacity, so optimistic admission would never preempt.

    Part A (parity): the same greedy stream through a reserve engine (never
    preempts — the bitwise reference) and an optimistic one that must hit
    ``PoolExhausted``, pick victims, and restore them by chunked
    re-prefill. Asserted: >= 1 preemption, every request ``ok``, tokens
    bitwise-identical to reserve, and the restore traffic re-used the two
    existing compiled step widths (no third compile).

    Part B (goodput): a burst with two impossible deadlines and a bounded
    queue (``max_queue``). Asserted: both deadlines missed, the over-bound
    tail shed, everything else served ``ok`` — typed statuses, no raise.
    Goodput is ok-tokens/s against the offered token load.

    Part C (chaos): a seeded ``FaultPlan`` injects a pool exhaustion at
    step 2, a transient device-step failure at step 4 (retried once), and
    a cancel of rid 2 at step 1 — mid-prefill, since its 48-token prompt
    is still chunking through a 32-token prefill budget. Asserted: every
    fault fired, exactly one step retry, rid 2 ``cancelled``, surviving
    rows bitwise equal to the reserve reference, pool invariants clean.
    """
    from repro.serve import REQUEST_STATUSES, FaultPlan, Request, ServeEngine

    page, max_len, chunk, batch, prompt_len = 16, 128, 32, 4, 48
    n_req, max_new = (8, 24) if quick else (11, 40)
    pages_per_req = -(-(prompt_len + max_new) // page)
    pool = batch * pages_per_req // 2  # 2x oversubscribed worst case

    def make(n=n_req, deadline=None):
        rng = np.random.default_rng(3)
        return [
            Request(
                tokens=rng.integers(2, vocab, size=prompt_len).astype(np.int32),
                max_new_tokens=max_new,
                rid=i,
                deadline_s=deadline(i) if deadline else None,
            )
            for i in range(n)
        ]

    def engine(**kw):
        return ServeEngine(
            lm, params, batch_size=batch, max_len=max_len,
            scheduler="continuous", page_size=page, prefill_chunk=chunk,
            pool_pages=pool, **kw,
        )

    def statuses(res):
        by = {}
        for r in res:
            assert r.status in REQUEST_STATUSES, r.status
            by[r.status] = by.get(r.status, 0) + 1
        return by

    # -- A: preempt/restore bitwise parity under natural exhaustion -------
    ref = engine()
    res_ref = ref.generate(make())
    opt = engine(admission="optimistic", max_preemptions=10)
    t0 = time.time()
    res_opt = opt.generate(make())
    opt_s = time.time() - t0
    st = opt.last_stats
    assert st.preemptions >= 1, "oversubscribed pool never exhausted"
    assert all(r.status == "ok" for r in res_ref + res_opt)
    for a, b in zip(res_ref, res_opt):
        assert (a.tokens == b.tokens).all(), f"rid {a.rid} diverged"
    assert opt.compiled_step_count() == 2, "restore added a compile"
    parity = {
        "preemptions": st.preemptions,
        "restore_tokens": st.restore_tokens,
        "mixed_steps_reserve": ref.last_stats.mixed_steps,
        "mixed_steps_optimistic": st.mixed_steps,
        "token_parity": True,
        "compiled_steps": opt.compiled_step_count(),
    }

    # -- B: goodput under deadlines + bounded-queue load shedding ---------
    n_burst = n_req + 4
    eng = engine(admission="optimistic", max_preemptions=10, max_queue=3)
    reqs = make(n_burst, deadline=lambda i: 0.0 if i < 2 else 60.0)
    t0 = time.time()
    res = eng.generate(reqs)
    dt = time.time() - t0
    by = statuses(res)
    sb = eng.last_stats
    assert by.get("deadline", 0) == 2, by
    assert by.get("shed", 0) >= 1, by
    assert by.get("failed", 0) == 0 and by.get("cancelled", 0) == 0, by
    ok_tokens = sum(r.steps for r in res if r.status == "ok")
    offered = n_burst * max_new
    goodput = {
        "requests": n_burst,
        "max_queue": 3,
        "statuses": by,
        "offered_tokens": offered,
        "ok_tokens": ok_tokens,
        "goodput_tok_per_s": round(ok_tokens / dt, 2) if dt > 0 else 0.0,
        "goodput_token_frac": round(ok_tokens / offered, 3),
        "preemptions": sb.preemptions,
    }
    assert goodput["goodput_tok_per_s"] > 0

    # -- C: seeded chaos plan through the fault hooks ---------------------
    plan = FaultPlan(seed=0).exhaust_pool(2).fail_device_step(4).cancel(1, rid=2)
    eng = engine(admission="optimistic", max_preemptions=10, faults=plan)
    res = eng.generate(make())
    by = statuses(res)
    v = eng.obs.value
    assert plan.exhausted, [f.site for f in plan.faults if f.times > 0]
    assert v("serve.step_retries") == 1, "transient failure not retried once"
    assert by.get("cancelled", 0) == 1 and res[2].status == "cancelled", by
    for a, b in zip(res_ref, res):
        if b.status == "ok":
            assert (a.tokens == b.tokens).all(), f"rid {a.rid} diverged"
    eng.last_pool.check_invariants()
    chaos = {
        "plan": [dict(f) for f in plan.fired],
        "statuses": by,
        "step_retries": 1,
        "preemptions": eng.last_stats.preemptions,
        "survivor_token_parity": True,
        "invariants_ok": True,
    }

    return {
        "page_size": page,
        "max_len": max_len,
        "prefill_chunk": chunk,
        "batch_size": batch,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "pool_pages": pool,
        "oversubscription": round(batch * pages_per_req / pool, 2),
        "parity": parity,
        "goodput": goodput,
        "chaos": chaos,
        "optimistic_seconds": round(opt_s, 4),
    }


def long_context_scenario(jax, np, *, arch: str, quick: bool) -> dict:
    """Tiered KV memory under device-pool pressure (DESIGN.md §13).

    The device pool is sized to under half the batch's concurrent working
    set — the regime the host tier exists for. Three engines on the same
    greedy stream:

    * reference — unconstrained pool, never preempts or spills: the
      bitwise token oracle.
    * preempt-only — optimistic admission over the constrained pool with
      no host tier. Every exhaustion evicts a victim whose KV is
      *discarded*; the restore re-runs chunked prefill over the full
      prompt plus everything generated so far, so the prompt's compute is
      paid again (and again) under sustained pressure.
    * tiered — same constrained pool plus a host page store. Pressure
      spills a victim's pages to host rows (ref-decrement, no recompute);
      the resume path stages the rows back with async ``device_put`` in
      the sawtooth traversal's future visit order, overlapped behind the
      in-flight step, and splices them in atomically at a boundary.

    The model is rebuilt wider than the shared smoke config on purpose:
    the comparison is about *restore re-prefill compute*, which a
    dispatch-overhead-bound toy model would hide.

    The asserted throughput metric is **modeled device work**, not wall
    clock (same philosophy as ``order_adaptation``'s modeled miss bytes —
    deterministic, stable across hosts): each compiled step executes its
    full padded width, so a narrow step costs ``batch`` token-slots and a
    wide step ``batch * prefill_chunk``; tier traffic is charged at
    ``COPY_COST`` token-slots per KV token moved (PCIe/C2C page copies
    run an order of magnitude cheaper than recomputing the same tokens —
    on GB10-class unified memory the real gap is wider still). Wall-clock
    tokens/s is measured and reported alongside, but CI boxes are too
    noisy to gate on it.

    Asserted: both constrained engines match the reference bitwise with
    two compiled widths; the tiered engine spills (>= 1) and never
    preempts, its prefetch hit rate is >= 0.8 (pages staged ahead of the
    resume that consumes them), and its modeled-work speedup over
    preempt-only is >= 1.5x — the gap is exactly the re-prefill compute
    the host tier avoids.
    """
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Request, ServeEngine

    page, chunk, batch = 16, 8, 8
    prompt_len, max_new = 96, 128
    n_req = 6 if quick else 8
    pages_per_req = -(-(prompt_len + max_new) // page)
    ws = min(batch, n_req) * pages_per_req
    pool = 48 if not quick else 36      # device tier: < 50% of working set
    host = ws                           # host tier: holds the full working set
    max_len = prompt_len + max_new
    COPY_COST = 1 / 8                   # token-slots per KV token copied

    cfg = get_config(arch).reduced().with_(
        d_model=320, n_layers=6, n_heads=8, head_dim=40, d_ff=1280
    )
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    def make():
        rng = np.random.default_rng(9)
        return [
            Request(
                tokens=rng.integers(2, cfg.vocab, size=prompt_len).astype(
                    np.int32
                ),
                max_new_tokens=max_new,
                rid=i,
            )
            for i in range(n_req)
        ]

    def engine(**kw):
        return ServeEngine(
            lm, params, batch_size=batch, max_len=max_len,
            scheduler="continuous", page_size=page, prefill_chunk=chunk, **kw,
        )

    def run(eng, repeats):
        eng.generate(make())            # warm-up: compile both step widths
        v = eng.obs.value
        w0 = v("serve.steps", width="wide")
        n0 = v("serve.steps", width="narrow")
        best, results = None, None
        for _ in range(repeats):        # best-of-N wall clock; counters are
            t0 = time.time()            # deterministic per repeat
            res = eng.generate(make())
            dt = time.time() - t0
            if best is None or dt < best:
                best, results = dt, res
        wide = int(round((v("serve.steps", width="wide") - w0) / repeats))
        narrow = int(round((v("serve.steps", width="narrow") - n0) / repeats))
        return best, results, wide, narrow

    ref = engine()                      # unconstrained: the bitwise oracle
    _, res_ref, _, _ = run(ref, repeats=1)

    pre = engine(admission="optimistic", max_preemptions=400, pool_pages=pool)
    t_pre, res_pre, wide_pre, narrow_pre = run(pre, repeats=2)
    st_pre = pre.last_stats
    assert st_pre.preemptions >= 1, "constrained pool never pressured preempt"

    tier = engine(
        admission="optimistic", max_preemptions=400, pool_pages=pool,
        host_pages=host, prefetch_depth=8, spill_watermark=1.0,
    )
    t_tier, res_tier, wide_tier, narrow_tier = run(tier, repeats=2)
    st = tier.last_stats
    tpool = tier.last_pool
    assert st.spills >= 1, "constrained pool never pressured the tiered engine"
    assert st.preemptions == 0, "host tier failed to absorb the pressure"
    hit_rate = st.prefetch_hits / max(st.tier_fetches, 1)
    assert hit_rate >= 0.8, f"prefetch hit rate {hit_rate:.2f} < 0.8"

    for a, b, c in zip(res_ref, res_pre, res_tier):
        assert a.status == b.status == c.status == "ok"
        assert (a.tokens == b.tokens).all(), f"rid {a.rid}: preempt diverged"
        assert (a.tokens == c.tokens).all(), f"rid {a.rid}: tiered diverged"

    # Modeled device work (token-slots): padded step execution + copies.
    page_bytes = tpool.fetch_bytes // max(tpool.fetches, 1)
    pages_moved = tpool.fetches + tpool.spill_bytes // max(page_bytes, 1)
    work_pre = batch * (narrow_pre + chunk * wide_pre)
    work_tier = (
        batch * (narrow_tier + chunk * wide_tier)
        + pages_moved * page * COPY_COST
    )
    modeled_speedup = round(work_pre / work_tier, 3)
    assert modeled_speedup >= 1.5, (
        f"tiered modeled-work speedup only {modeled_speedup}x"
    )

    tokens = sum(r.steps for r in res_tier)
    tps_pre = tokens / t_pre if t_pre > 0 else float("inf")
    tps_tier = tokens / t_tier if t_tier > 0 else float("inf")

    return {
        "page_size": page,
        "max_len": max_len,
        "prompt_len": prompt_len,
        "max_new": max_new,
        "prefill_chunk": chunk,
        "batch_size": batch,
        "requests": n_req,
        "pool_pages": pool,
        "host_pages": host,
        "working_set_pages": ws,
        "device_frac_of_working_set": round(pool / ws, 3),
        "copy_cost_per_kv_token": COPY_COST,
        "tokens": tokens,
        "preempt_only": {
            "tok_per_s": round(tps_pre, 2),
            "seconds": round(t_pre, 4),
            "preemptions": st_pre.preemptions,
            "restore_tokens": st_pre.restore_tokens,
            "wide_steps": wide_pre,
            "narrow_steps": narrow_pre,
            "modeled_work_token_slots": work_pre,
        },
        "tiered": {
            "tok_per_s": round(tps_tier, 2),
            "seconds": round(t_tier, 4),
            "spills": st.spills,
            "fetches": st.tier_fetches,
            "prefetch_hits": st.prefetch_hits,
            "prefetch_wasted": st.prefetch_wasted,
            "prefetch_hit_rate": round(hit_rate, 3),
            "spill_bytes": tpool.spill_bytes,
            "fetch_bytes": tpool.fetch_bytes,
            "overlapped_fetch_frac": round(
                tpool._overlapped / max(tpool.fetches, 1), 3
            ),
            "preemptions": st.preemptions,
            "wide_steps": wide_tier,
            "narrow_steps": narrow_tier,
            "modeled_work_token_slots": round(work_tier, 1),
        },
        "modeled_speedup_vs_preempt_only": modeled_speedup,
        "wall_clock_speedup_vs_preempt_only": round(
            tps_tier / max(tps_pre, 1e-9), 3
        ),
        "token_parity": True,
        "compiled_steps": tier.compiled_step_count(),
    }


def speculative_scenario(jax, np, *, lm, params, quick: bool) -> dict:
    """Speculative decoding on the unified ragged step (DESIGN.md §14).

    A decode-heavy stream of short repetitive prompts (the shape where
    draft-and-verify pays: almost all steps are decode, and an n-gram
    drafter can actually predict the continuation) through three engines:

    * baseline — the plain continuous engine, one token per decode step;
    * ngram — self-drafting prompt-lookup drafter, K=7 draft tokens
      verified per row per step as a q_len=K+1 ragged chunk;
    * model — a draft *model* (self-speculation: the target's own weights,
      so greedy acceptance must be ~100%) with its own paged cache.

    Everything the speculative path promises is asserted in-bench:
    bitwise greedy token parity with the baseline for both drafters,
    bitwise *sampled* parity (the per-accepted-token PRNG stream
    accounting), draft/accept/rollback counter conservation,
    ``compiled_step_count() == 2`` (verification reuses the prefill
    width — no third compile), and for the n-gram drafter on this
    repetitive stream a >= 1.5x speedup on both the deterministic
    mixed-step count and the wall-clock TPOT p50. A seeded chaos variant
    injects a transient device-step failure mid-verification and must
    retry once, keep the stream bitwise identical, and leave the pool
    invariants clean.
    """
    from repro.serve import (
        FaultPlan,
        ModelDrafter,
        NgramDrafter,
        Request,
        ServeEngine,
    )

    page, chunk, max_len, draft_len = 8, 8, 256, 7
    max_new = 64 if quick else 128
    repeats = 2 if quick else 3
    # Short cyclic prompts (period 4, tiled to 24 tokens): greedy
    # continuations stay near-periodic, the regime prompt-lookup drafting
    # is built for. Seeds picked for streams that remain predictable over
    # the whole horizon (acceptance ~80%+) — the honest best case the
    # >= 1.5x TPOT assert is calibrated against.
    seeds = (5, 8)

    def make(temperature: float = 0.0):
        reqs = []
        for i, s in enumerate(seeds):
            rng = np.random.default_rng(s)
            toks = np.tile(rng.integers(5, 20, size=4), 6).astype(np.int32)
            reqs.append(
                Request(
                    tokens=toks,
                    max_new_tokens=max_new,
                    temperature=temperature,
                    rid=i,
                    seed=i,
                )
            )
        return reqs

    def engine(drafter=None, **kw):
        return ServeEngine(
            lm,
            params,
            batch_size=len(seeds),
            max_len=max_len,
            scheduler="continuous",
            page_size=page,
            prefill_chunk=chunk,
            drafter=drafter,
            draft_len=draft_len,
            **kw,
        )

    def run_timed(eng, temperature: float = 0.0):
        eng.generate(make(temperature))  # warm-up: compile both widths
        best, results, tpots, steps = None, None, [], 0
        for _ in range(repeats):
            reqs = make(temperature)
            t0 = time.time()
            res = eng.generate(reqs)
            dt = time.time() - t0
            if best is None or dt < best:
                best, results = dt, res
            steps = eng.last_stats.mixed_steps
            tpots += [r.tpot_s for r in res if r.status == "ok" and r.steps > 1]
        tokens = sum(r.steps for r in results)
        out = {
            "tokens": tokens,
            "seconds": round(best, 4),
            "tok_per_s": round(tokens / best, 2) if best > 0 else 0.0,
            "tpot_p50_s": round(_pct(tpots, 50), 5),
            "tpot_p95_s": round(_pct(tpots, 95), 5),
            "mixed_steps": steps,
        }
        return out, results

    def spec_counters(eng) -> dict:
        v = eng.obs.value
        drafted = v("serve.spec.draft_tokens")
        accepted = v("serve.spec.accepted_tokens")
        rolled = v("serve.spec.rollback_tokens")
        # Conservation: every drafted token is either accepted into the
        # stream or rolled back off the KV cache — nothing leaks.
        assert drafted == accepted + rolled, (drafted, accepted, rolled)
        return {
            "draft_tokens": drafted,
            "accepted_tokens": accepted,
            "rollback_tokens": rolled,
            "acceptance_rate": round(accepted / drafted, 3) if drafted else 0.0,
        }

    # -- baseline: plain continuous engine, greedy ------------------------
    base, res_base = run_timed(engine())
    assert all(r.status == "ok" for r in res_base)

    # -- n-gram drafter: parity + the headline speedup asserts ------------
    eng_ng = engine(NgramDrafter(ngram_max=4))
    ng, res_ng = run_timed(eng_ng)
    for a, b in zip(res_base, res_ng):
        assert (a.tokens == b.tokens).all(), f"rid {a.rid} diverged (ngram)"
    ng.update(spec_counters(eng_ng))
    assert eng_ng.compiled_step_count() == 2, eng_ng.compiled_step_count()
    steps_ratio = base["mixed_steps"] / max(ng["mixed_steps"], 1)
    tpot_ratio = base["tpot_p50_s"] / max(ng["tpot_p50_s"], 1e-9)
    ng["steps_ratio"] = round(steps_ratio, 3)
    ng["tpot_speedup"] = round(tpot_ratio, 3)
    assert steps_ratio >= 1.5, f"ngram steps ratio {steps_ratio:.2f} < 1.5"
    assert tpot_ratio >= 1.5, f"ngram TPOT speedup {tpot_ratio:.2f} < 1.5"

    # -- model drafter: self-speculation, greedy acceptance ~100% ---------
    eng_md = engine(
        ModelDrafter(
            lm,
            params,
            n_slots=len(seeds),
            max_len=max_len,
            page_size=page,
            prefill_chunk=chunk,
        )
    )
    md, res_md = run_timed(eng_md)
    for a, b in zip(res_base, res_md):
        assert (a.tokens == b.tokens).all(), f"rid {a.rid} diverged (model)"
    md.update(spec_counters(eng_md))
    assert eng_md.compiled_step_count() == 2, eng_md.compiled_step_count()
    assert md["acceptance_rate"] >= 0.95, md["acceptance_rate"]
    md["steps_ratio"] = round(base["mixed_steps"] / max(md["mixed_steps"], 1), 3)
    md["tpot_speedup"] = round(
        base["tpot_p50_s"] / max(md["tpot_p50_s"], 1e-9), 3
    )

    # -- sampled parity: the per-accepted-token PRNG stream accounting ----
    res_sb = engine().generate(make(temperature=0.8))
    res_sn = engine(NgramDrafter(ngram_max=4)).generate(make(temperature=0.8))
    for a, b in zip(res_sb, res_sn):
        assert (a.tokens == b.tokens).all(), f"rid {a.rid} sampled divergence"

    # -- chaos: transient device-step failure mid-verification ------------
    plan = FaultPlan(seed=0).fail_device_step(6)
    eng_ch = engine(NgramDrafter(ngram_max=4), faults=plan)
    res_ch = eng_ch.generate(make())
    assert eng_ch.obs.value("serve.step_retries") == 1, "fault not retried once"
    for a, b in zip(res_base, res_ch):
        assert (a.tokens == b.tokens).all(), f"rid {a.rid} diverged after fault"
    chaos_counters = spec_counters(eng_ch)
    eng_ch.last_pool.check_invariants()

    return {
        "page_size": page,
        "prefill_chunk": chunk,
        "max_len": max_len,
        "max_new": max_new,
        "draft_len": draft_len,
        "n_requests": len(seeds),
        "baseline": base,
        "ngram": ng,
        "model": md,
        "greedy_parity": True,
        "sampled_parity": True,
        "compiled_steps": 2,
        "chaos": {
            "step_retries": 1,
            "token_parity": True,
            "invariants_ok": True,
            **chaos_counters,
        },
    }


def _pct(xs, p):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, max(0, round(p / 100 * (len(xs) - 1))))
    return xs[i]


def _work_counters(reg) -> dict:
    """Deterministic work counters, read from the engine's obs registry
    (the hand-rolled engine-side tallies are gone — the registry is the
    single source: ``serve.steps{width=...}`` + the ``pool.*`` counters)."""
    v = reg.value
    wide = v("serve.steps", width="wide")
    return {
        "mixed_steps": wide + v("serve.steps", width="narrow"),
        "wide_steps": wide,
        "pages_adopted": v("pool.pages_adopted"),
        "prompt_tokens_adopted": v("pool.tokens_adopted"),
        "cow_forks": v("pool.cow_forks"),
    }


def time_engine(eng, make_requests, repeats: int = 5) -> dict:
    eng.generate(make_requests())  # warm-up: compile both step widths
    base = _work_counters(eng.obs)  # registry counters are cumulative
    best, results = None, None
    ttfts, tpots = [], []
    for _ in range(repeats):  # best-of-N: the streams are short, CI CPUs noisy
        reqs = make_requests()
        t0 = time.time()
        res = eng.generate(reqs)
        dt = time.time() - t0
        if best is None or dt < best:
            best, results = dt, res
        # Latency percentiles pool every repeat's requests — a p95 from one
        # short run is a max(), far too noisy for a CI trend line. Only
        # status=ok rows carry meaningful latencies (shed/deadline/failed
        # requests resolve without observing TTFT/TPOT).
        ttfts += [r.ttft_s for r in res if r.status == "ok"]
        tpots += [r.tpot_s for r in res if r.status == "ok" and r.steps > 1]
    tokens = sum(r.steps for r in results)
    out = {
        "requests": len(results),
        "tokens": tokens,
        "seconds": round(best, 4),
        "tok_per_s": round(tokens / best, 2) if best > 0 else float("inf"),
        "ttft_p50_s": round(_pct(ttfts, 50), 4),
        "ttft_p95_s": round(_pct(ttfts, 95), 4),
        "tpot_p50_s": round(_pct(tpots, 50), 4),
        "tpot_p95_s": round(_pct(tpots, 95), 4),
    }
    # Per-stream work counters = registry delta over the deterministic
    # repeats (identical streams, so the division is exact). Static
    # engines run no mixed steps — the keys stay continuous-only.
    work = {
        k: int(round((after - base[k]) / repeats))
        for k, after in _work_counters(eng.obs).items()
    }
    if work["mixed_steps"]:
        out.update(work)
    return out


def main() -> None:
    sys.path.insert(0, "src")
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.cache_sim import (
        simulate_paged_decode,
        simulate_shared_prefix_decode,
    )
    from repro.models import build_model
    from repro.serve import ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--quick", action="store_true", help="CI-sized stream")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--scenario", default="all",
                    choices=["all", "mixed", "shared_prefix",
                             "order_adaptation", "overload", "long_context",
                             "speculative"],
                    help="run one scenario (CI chaos smoke: --quick "
                         "--scenario overload); default runs them all")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    def on(name):
        return args.scenario in ("all", name)

    cfg = get_config(args.arch).reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    n_short, n_long, max_new_long = (9, 3, 24) if args.quick else (12, 4, 48)
    make = lambda: build_requests(
        np, cfg.vocab, n_short=n_short, n_long=n_long, max_new_long=max_new_long
    )

    def engine(scheduler, **kw):
        return ServeEngine(
            lm,
            params,
            batch_size=args.batch_size,
            max_len=args.max_len,
            scheduler=scheduler,
            page_size=args.page_size,
            **kw,
        )

    report = {
        "arch": args.arch,
        "batch_size": args.batch_size,
        "max_len": args.max_len,
        "page_size": args.page_size,
        "prefill_chunk": args.prefill_chunk,
    }
    if on("mixed"):
        report["static"] = time_engine(engine("static"), make)
        report["continuous"] = time_engine(
            engine("continuous", prefill_chunk=args.prefill_chunk), make
        )
        report["speedup"] = round(
            report["continuous"]["tok_per_s"] / report["static"]["tok_per_s"], 3
        )
        # Page-locality twin of the mixed decode loop (cache_sim).
        lens = [24] * n_long + [96] * 1
        report["page_trace"] = {
            order: simulate_paged_decode(
                order, lens, max_new_long, args.page_size
            )
            for order in ("cyclic", "sawtooth")
        }

    if on("shared_prefix"):
        # Shared-system-prompt scenario: continuous engine with prefix
        # sharing on vs off (the A/B is apples-to-apples — same mixed step,
        # same budget; only the pool's page dedup differs).
        n_req, prefix_len, max_new = (8, 48, 8) if args.quick else (12, 64, 12)
        make_shared = lambda: build_shared_prefix_requests(
            np, cfg.vocab, n_requests=n_req, prefix_len=prefix_len, tail_max=8,
            max_new=max_new,
        )
        eng_shared = engine("continuous", prefill_chunk=args.prefill_chunk)
        shared = time_engine(eng_shared, make_shared)
        eng_unshared = engine(
            "continuous", prefill_chunk=args.prefill_chunk, prefix_sharing=False
        )
        unshared = time_engine(eng_unshared, make_shared)
        report["shared_prefix"] = {
            "n_requests": n_req,
            "prefix_len": prefix_len,
            "sharing_on": shared,
            "sharing_off": unshared,
            "ttft_p95_improvement": round(
                unshared["ttft_p95_s"] / max(shared["ttft_p95_s"], 1e-9), 3
            ),
            "tok_per_s_improvement": round(
                shared["tok_per_s"] / max(unshared["tok_per_s"], 1e-9), 3
            ),
            # Deterministic (wall-clock-free) trend metrics: sharing must
            # strictly reduce the wide (chunk-prefill) step count.
            "wide_steps_saved": unshared["wide_steps"] - shared["wide_steps"],
        }
        # Cross-row reuse of a deduplicated prefix (cache_sim twin).
        report["shared_page_trace"] = {
            f"{order}_{'shared' if sh else 'private'}":
                simulate_shared_prefix_decode(
                    order,
                    args.batch_size,
                    prefix_len // args.page_size,
                    [8] * args.batch_size,
                    max_new,
                    args.page_size,
                    shared=sh,
                )
            for order in ("cyclic", "sawtooth")
            for sh in (True, False)
        }

    if on("order_adaptation"):
        # Flip-boundary adaptive-serving scenario: pinned cyclic /
        # block_snake vs the online order-adaptation controller on a
        # footprint-growing stream (deterministic modeled-byte accounting;
        # asserts adaptive ≥ best fixed on both halves, zero recompiles).
        report["order_adaptation"] = order_adaptation_scenario(
            jax, np, arch=args.arch, params=params
        )

    if on("overload"):
        # Resilience layer under 2x pool oversubscription: preempt/restore
        # parity, deadline/shed goodput, seeded chaos faults (all asserted).
        report["overload"] = overload_scenario(
            jax, np, lm=lm, params=params, vocab=cfg.vocab, quick=args.quick
        )

    if on("long_context"):
        # Tiered KV memory with the device pool under half the working set:
        # spill-to-host + traversal-order prefetch vs discard-and-reprefill
        # preemption (bitwise parity, hit rate, and modeled-work speedup all
        # asserted). Builds its own wider model — see the scenario docstring.
        report["long_context"] = long_context_scenario(
            jax, np, arch=args.arch, quick=args.quick
        )

    if on("speculative"):
        # Draft-and-verify on the unified ragged step: n-gram and draft-model
        # drafters vs the plain engine on a decode-heavy repetitive stream
        # (bitwise parity, counter conservation, >= 1.5x TPOT, two compiled
        # widths, and a mid-verification chaos fault all asserted).
        report["speculative"] = speculative_scenario(
            jax, np, lm=lm, params=params, quick=args.quick
        )

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    if on("mixed"):
        for name in ("static", "continuous"):
            r = report[name]
            print(
                f"{name:11s} {r['tokens']:4d} tokens in {r['seconds']:.2f}s "
                f"-> {r['tok_per_s']:.1f} tok/s  ttft p50/p95 "
                f"{r['ttft_p50_s']*1e3:.0f}/{r['ttft_p95_s']*1e3:.0f} ms"
            )
    if on("shared_prefix"):
        sp = report["shared_prefix"]
        print(
            f"shared-prefix: {sp['sharing_on']['pages_adopted']} pages "
            f"({sp['sharing_on']['prompt_tokens_adopted']} tokens) adopted, "
            f"{sp['sharing_on']['cow_forks']} CoW forks, "
            f"{sp['wide_steps_saved']} wide steps saved; ttft p95 "
            f"{sp['sharing_off']['ttft_p95_s']*1e3:.0f} -> "
            f"{sp['sharing_on']['ttft_p95_s']*1e3:.0f} ms "
            f"({sp['ttft_p95_improvement']}x)"
        )
    if on("order_adaptation"):
        oa = report["order_adaptation"]
        m = oa["modeled_mib"]
        print(
            f"order-adapt: seeded {oa['seeded_order']} -> {oa['final_order']} "
            f"({oa['order_switches']} switch at sample {oa['flip_sample']}/"
            f"{oa['samples']}, {oa['flip_footprint_pages']} pages); modeled "
            f"MiB pre/post flip: adaptive {m['adaptive']['pre_flip_mib']:.2f}/"
            f"{m['adaptive']['post_flip_mib']:.2f}, cyclic "
            f"{m['cyclic']['pre_flip_mib']:.2f}/"
            f"{m['cyclic']['post_flip_mib']:.2f}, "
            f"block_snake {m['block_snake']['pre_flip_mib']:.2f}/"
            f"{m['block_snake']['post_flip_mib']:.2f}; "
            f"compiled steps {oa['compiled_steps']} (no recompile)"
        )
    if on("overload"):
        ov = report["overload"]
        pa, gp, ch = ov["parity"], ov["goodput"], ov["chaos"]
        sts = ", ".join(f"{k}={v}" for k, v in sorted(gp["statuses"].items()))
        print(
            f"overload ({ov['oversubscription']}x oversubscribed, "
            f"{ov['pool_pages']} pages): parity ok with "
            f"{pa['preemptions']} preemptions "
            f"({pa['restore_tokens']} tokens re-prefilled, compiled steps "
            f"{pa['compiled_steps']}); goodput "
            f"{gp['goodput_tok_per_s']:.1f} tok/s "
            f"({gp['goodput_token_frac']:.0%} of offered; {sts}); chaos: "
            f"{len(ch['plan'])} faults fired, {ch['step_retries']} step "
            f"retry, statuses "
            + ", ".join(f"{k}={v}" for k, v in sorted(ch["statuses"].items()))
        )
    if on("long_context"):
        lc = report["long_context"]
        t, p = lc["tiered"], lc["preempt_only"]
        print(
            f"long-context ({lc['pool_pages']}/{lc['working_set_pages']} "
            f"device pages): modeled work {p['modeled_work_token_slots']} -> "
            f"{t['modeled_work_token_slots']} token-slots "
            f"({lc['modeled_speedup_vs_preempt_only']}x; wall clock "
            f"{t['tok_per_s']:.1f} vs {p['tok_per_s']:.1f} tok/s = "
            f"{lc['wall_clock_speedup_vs_preempt_only']}x); "
            f"{t['spills']} spills, "
            f"{t['fetches']} fetches (hit rate {t['prefetch_hit_rate']:.0%}, "
            f"{t['overlapped_fetch_frac']:.0%} overlapped), "
            f"{t['spill_bytes'] / 2**20:.1f}/{t['fetch_bytes'] / 2**20:.1f} "
            f"MiB spilled/fetched vs {p['preemptions']} preemptions "
            f"({p['restore_tokens']} tokens re-prefilled)"
        )
    if on("speculative"):
        sp = report["speculative"]
        ng, md = sp["ngram"], sp["model"]
        print(
            f"speculative (K={sp['draft_len']}): ngram "
            f"{sp['baseline']['mixed_steps']} -> {ng['mixed_steps']} steps "
            f"({ng['steps_ratio']}x), TPOT p50 "
            f"{sp['baseline']['tpot_p50_s']*1e3:.2f} -> "
            f"{ng['tpot_p50_s']*1e3:.2f} ms ({ng['tpot_speedup']}x), "
            f"acceptance {ng['acceptance_rate']:.0%}; model drafter "
            f"acceptance {md['acceptance_rate']:.0%} "
            f"({md['steps_ratio']}x steps); greedy+sampled parity ok, "
            f"chaos retry ok, compiled steps {sp['compiled_steps']}"
        )
    if on("mixed"):
        pt = report["page_trace"]
        tail = ""
        if on("shared_prefix"):
            st = report["shared_page_trace"]
            tail = (
                f"; shared-prefix reuse distance private "
                f"{st['sawtooth_private']['mean_reuse_distance']:.1f} -> "
                f"shared {st['sawtooth_shared']['mean_reuse_distance']:.1f}"
            )
        print(
            f"speedup {report['speedup']}x; page reuse distance "
            f"cyclic {pt['cyclic']['mean_reuse_distance']:.1f} -> "
            f"sawtooth {pt['sawtooth']['mean_reuse_distance']:.1f}" + tail
        )


if __name__ == "__main__":
    main()
