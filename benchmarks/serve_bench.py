"""Serve-throughput smoke benchmark: static vs continuous scheduling.

Serves one mixed-length request stream (many short prompts, a few long
high-``max_new`` stragglers, staggered arrivals) through both schedulers of
the ServeEngine on CPU and reports tokens/s. The static path pays for its
stragglers — every group decodes until its slowest member finishes, short
requests idling in their slots — while the continuous scheduler refills
slots from the waiting queue mid-decode, so the same hardware closes the
stream in far fewer decode steps. Also reports the ``cache_sim``
page-granular reuse-distance delta for cyclic vs sawtooth page traversal in
decode (the serving-side analogue of the paper's Fig. 8).

Writes ``BENCH_serve.json`` (CI artifact; scheduler regressions show up as
``speedup`` < 1) and prints a one-line summary per scheduler.

  PYTHONPATH=src python benchmarks/serve_bench.py            # full smoke
  PYTHONPATH=src python benchmarks/serve_bench.py --quick    # CI-sized
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def build_requests(np, vocab, *, n_short: int, n_long: int, max_new_long: int):
    """Interleave short and long requests with staggered arrivals.

    Interleaving puts roughly one long straggler in every static group —
    the adversarial-but-realistic shape for fixed-group scheduling.
    """
    from repro.serve import Request

    rng = np.random.default_rng(0)
    reqs = []
    n_groups = max(n_long, 1)
    per_group = (n_short + n_long) // n_groups if n_groups else 0
    rid = 0
    for g in range(n_groups):
        reqs.append(
            Request(
                tokens=rng.integers(2, vocab, size=24).astype(np.int32),
                max_new_tokens=max_new_long,
                rid=rid,
                arrival=g,
            )
        )
        rid += 1
        for _ in range(max(per_group - 1, 0)):
            reqs.append(
                Request(
                    tokens=rng.integers(2, vocab, size=int(rng.integers(4, 9))).astype(
                        np.int32
                    ),
                    max_new_tokens=4,
                    rid=rid,
                    arrival=g,
                )
            )
            rid += 1
    return reqs


def time_engine(eng, make_requests, repeats: int = 3) -> dict:
    eng.generate(make_requests())  # warm-up: compile every bucket/decode shape
    best, results = None, None
    for _ in range(repeats):  # best-of-N: the streams are short, CI CPUs noisy
        reqs = make_requests()
        t0 = time.time()
        results = eng.generate(reqs)
        dt = time.time() - t0
        best = dt if best is None else min(best, dt)
    tokens = sum(r.steps for r in results)
    return {
        "requests": len(results),
        "tokens": tokens,
        "seconds": round(best, 4),
        "tok_per_s": round(tokens / best, 2) if best > 0 else float("inf"),
    }


def main() -> None:
    sys.path.insert(0, "src")
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.cache_sim import simulate_paged_decode
    from repro.models import build_model
    from repro.serve import ServeEngine

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--quick", action="store_true", help="CI-sized stream")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    n_short, n_long, max_new_long = (9, 3, 24) if args.quick else (12, 4, 48)
    make = lambda: build_requests(
        np, cfg.vocab, n_short=n_short, n_long=n_long, max_new_long=max_new_long
    )

    eng_static = ServeEngine(
        lm, params, batch_size=args.batch_size, max_len=args.max_len
    )
    eng_cont = ServeEngine(
        lm,
        params,
        batch_size=args.batch_size,
        max_len=args.max_len,
        scheduler="continuous",
        page_size=args.page_size,
    )

    report = {
        "arch": args.arch,
        "batch_size": args.batch_size,
        "max_len": args.max_len,
        "page_size": args.page_size,
        "static": time_engine(eng_static, make),
        "continuous": time_engine(eng_cont, make),
    }
    report["speedup"] = round(
        report["continuous"]["tok_per_s"] / report["static"]["tok_per_s"], 3
    )

    # Page-locality twin of the serving decode loop (cache_sim §page trace):
    # a batch at the benchmark's lengths, decode max_new_long steps.
    lens = [24] * n_long + [96] * 1
    report["page_trace"] = {
        order: simulate_paged_decode(order, lens, max_new_long, args.page_size)
        for order in ("cyclic", "sawtooth")
    }

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    for name in ("static", "continuous"):
        r = report[name]
        print(
            f"{name:11s} {r['tokens']:4d} tokens in {r['seconds']:.2f}s "
            f"-> {r['tok_per_s']:.1f} tok/s"
        )
    pt = report["page_trace"]
    print(
        f"speedup {report['speedup']}x; page reuse distance "
        f"cyclic {pt['cyclic']['mean_reuse_distance']:.1f} -> "
        f"sawtooth {pt['sawtooth']['mean_reuse_distance']:.1f}"
    )


if __name__ == "__main__":
    main()
