"""§Perf hillclimbing driver.

Runs named configuration experiments against the three selected
(arch × shape) pairs and records trip-count-corrected roofline terms per
step into artifacts/hillclimb/. The hypothesis → napkin-math → measure →
validate narrative lives in EXPERIMENTS.md §Perf; this file is the
reproducible measurement harness for it.

Selected pairs (from the 33-cell baseline table):
  * mamba2-130m × train_4k   — worst roofline fraction (util 0.001)
  * olmoe-1b-7b × prefill_32k — most collective-bound (Tx/Tm = 2.4)
  * deepseek-7b × prefill_32k — most representative of the paper's
    technique (attention KV streaming dominates both terms)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--only PAIR]
(must run in its own process: imports repro.launch.dryrun which forces the
512-device XLA flag).

Backward block autotune (``--autotune-bwd``): since the fused flash
backward, bwd tile sizes are independent knobs (ModelConfig.bwd_q_block /
bwd_kv_block). The objective is a jitted train-microstep — value_and_grad
of an attention-dominated loss, i.e. fwd + fused bwd wall time — measured
over a (bwd_q_block × bwd_kv_block) grid with the forward blocks held at
the config's tuned values. Writes artifacts/hillclimb/bwd_autotune_*.json
and prints the winner. Runs on whatever backend jax finds (CPU here; on
TPU the same sweep times the real kernels via impl='pallas').

  PYTHONPATH=src python -m benchmarks.hillclimb --autotune-bwd deepseek-7b \\
      --seq 1024 --impl xla
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, "src")

EXPERIMENTS = {
    "mamba2_train": {
        "arch": "mamba2-130m",
        "shape": "train_4k",
        "steps": [
            # (tag, cfg_overrides, par_overrides)
            ("baseline", {}, {}),
            # H1: 130M params don't need TP/FSDP; model axis as extra DP
            # kills the vocab-gather remat + per-layer all-gathers and cuts
            # per-device activations 16x.
            ("pure_dp", {}, {
                "tensor_axis": "none",
                "fsdp_axes": (),
                "data_axes": ("data", "model"),
            }),
            # H2: SSD intra-chunk W matrix bytes are linear in chunk size;
            # chunk 128->64 halves the dominant f32 intermediate.
            ("pure_dp_chunk64", {"ssm": {"chunk": 64}}, {
                "tensor_axis": "none",
                "fsdp_axes": (),
                "data_axes": ("data", "model"),
            }),
            # H3: no-remat (memory is cheap for a 130M model at b=1/device;
            # full remat was re-reading every layer input twice).
            ("pure_dp_chunk64_noremat", {"ssm": {"chunk": 64}, "remat": "dots"}, {
                "tensor_axis": "none",
                "fsdp_axes": (),
                "data_axes": ("data", "model"),
            }),
        ],
    },
    "olmoe_prefill": {
        "arch": "olmoe-1b-7b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}),
            # H1: the dropless global argsort over 8.4M token-copies is the
            # collective driver; capacity-based dispatch shards statically.
            ("capacity_serve", {"moe_serve_dropless": False}, {}),
            # H2: + sequence-shard the residual/token stream so router and
            # dispatch work on (data x model)-sharded tokens.
            ("capacity_seqshard", {"moe_serve_dropless": False},
             {"seq_shard_activations": True}),
            # H3: + bf16 attention scores (memory term of the attn blocks).
            ("capacity_seqshard_bf16s",
             {"moe_serve_dropless": False, "score_dtype": "bfloat16"},
             {"seq_shard_activations": True}),
            # H4 (round 2): seqshard hurt (GSPMD replication, Tc x283) —
            # drop it; trim serve capacity factor instead (1.25 -> 1.0):
            # buffer + expert GEMM bytes scale with capacity.
            ("capacity_cf10", {"moe_serve_dropless": False,
                               "moe": {"capacity_factor": 1.0}}, {}),
        ],
    },
    "deepseek_prefill": {
        "arch": "deepseek-7b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}),
            # H1 (beyond-paper): bf16 scores/probs halve the dominant
            # attention HBM traffic the paper's technique targets.
            ("bf16_scores", {"score_dtype": "bfloat16"}, {}),
            # H2: sequence-shard residuals -> smaller per-layer all-gathers.
            ("bf16_seqshard", {"score_dtype": "bfloat16"},
             {"seq_shard_activations": True}),
            # H3: larger KV blocks (512->1024): fewer block boundaries,
            # fewer q-tile re-reads per KV pass.
            ("bf16_seqshard_kv1024",
             {"score_dtype": "bfloat16", "q_block": 1024, "kv_block": 1024},
             {"seq_shard_activations": True}),
            # H4 (round 2): attribution — seqshard alone, f32 scores.
            ("seqshard_only", {}, {"seq_shard_activations": True}),
        ],
    },
    # round 2 bonus pair: flagship dense model, transfer the deepseek win
    "llama3_prefill": {
        "arch": "llama3-405b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}),
            ("seqshard", {}, {"seq_shard_activations": True}),
        ],
    },
    # round 3: the two worst remaining train cells
    "seamless_train": {
        "arch": "seamless-m4t-medium",
        "shape": "train_4k",
        "steps": [
            ("baseline", {}, {}),
            ("seqshard", {}, {"seq_shard_activations": True}),
        ],
    },
    "mixtral_train": {
        "arch": "mixtral-8x7b",
        "shape": "train_4k",
        "steps": [
            ("baseline", {}, {}),
            ("seqshard", {}, {"seq_shard_activations": True}),
        ],
    },
}

OUT = "artifacts/hillclimb"
AUTOTUNE_CACHE = os.path.join(OUT, "autotune_cache.jsonl")


def record_winner(kind: str, key: dict, winner: dict) -> None:
    """Append a sweep winner to the persistent autotune cache.

    One JSONL line per winner through the shared ``repro.obs.export`` sink
    (``schema_version`` stamped), keyed by (arch, seq bucket, capacity,
    backend) — the lookup key the serve engine's startup consultation
    (``repro.obs.autotune.load_autotune_cache``) resolves. The key passes
    through the same ``canonicalize_key`` normalization the reader dedups
    with, so writer and reader agree on what "same key" means. Append-only:
    later entries with the same key win (last-writer-wins on load).
    """
    from repro.obs.autotune import canonicalize_key
    from repro.obs.export import append_jsonl

    key = canonicalize_key(key)
    rec = append_jsonl(AUTOTUNE_CACHE, {"key": key, "winner": winner}, kind=kind)
    print(f"[autotune-cache] {kind} {key} -> {AUTOTUNE_CACHE} "
          f"(schema_version={rec['schema_version']})")


def _apply_cfg_overrides(arch, ov):
    """ssm sub-dataclass overrides need reconstruction."""
    from repro.configs import get_config
    import dataclasses

    ov = dict(ov)
    base = get_config(arch)
    if "ssm" in ov:
        ov["ssm"] = dataclasses.replace(base.ssm, **ov["ssm"])
    if "moe" in ov:
        ov["moe"] = dataclasses.replace(base.moe, **ov["moe"])
    return ov


def autotune_bwd(arch: str, *, seq: int, batch: int, impl: str, reps: int,
                 blocks=(128, 256, 512)):
    """Grid-search bwd_q_block × bwd_kv_block on a jitted train-microstep.

    The microstep is value_and_grad of sum(attention(q,k,v)^2) at the
    arch's head geometry — fwd + fused bwd of the kernel under tune, no
    model overhead diluting the signal. Forward blocks stay at the config
    values so only the backward tiles move.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.kernels import ops

    cfg = get_config(arch)
    hd = cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    q = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, hq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (batch, seq, hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (batch, seq, hkv, hd), jnp.float32)

    def microstep_time(bq, bk):
        def loss(q, k, v):
            out = ops.attention(
                q, k, v,
                order=cfg.attn_order,
                causal=True,
                window=cfg.window,
                q_block=cfg.q_block,
                kv_block=cfg.kv_block,
                impl=impl,
                score_dtype=cfg.score_dtype,
                bwd_q_block=bq,
                bwd_kv_block=bk,
            )
            return (out.astype(jnp.float32) ** 2).sum()

        fn = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))
        jax.block_until_ready(fn(q, k, v))  # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(q, k, v))
        return (time.perf_counter() - t0) / reps

    results = []
    for bq in blocks:
        for bk in blocks:
            s = microstep_time(bq, bk)
            results.append({"bwd_q_block": bq, "bwd_kv_block": bk, "step_s": s})
            print(f"[autotune-bwd {arch}] bq={bq} bk={bk} step_s={s:.4f}")
    best = min(results, key=lambda r: r["step_s"])

    def closest(val):  # the grid point standing in for "inherit fwd blocks"
        return min(blocks, key=lambda b: abs(b - val))

    base = next(
        r for r in results
        if r["bwd_q_block"] == closest(cfg.q_block)
        and r["bwd_kv_block"] == closest(cfg.kv_block)
    )
    rec = {
        "arch": arch,
        "seq": seq,
        "batch": batch,
        "impl": impl,
        "backend": jax.default_backend(),
        "fwd_blocks": [cfg.q_block, cfg.kv_block],
        "grid": results,
        "best": best,
        "speedup_vs_fwd_blocks": base["step_s"] / best["step_s"],
    }
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"bwd_autotune_{arch.replace('/', '_')}_s{seq}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[autotune-bwd {arch}] best bwd_q_block={best['bwd_q_block']} "
        f"bwd_kv_block={best['bwd_kv_block']} step_s={best['step_s']:.4f} "
        f"({rec['speedup_vs_fwd_blocks']:.3f}x vs fwd-block default) -> {path}"
    )
    record_winner(
        "bwd_autotune",
        key={"arch": arch, "seq_bucket": seq, "impl": impl,
             "backend": rec["backend"]},
        winner={"bwd_q_block": best["bwd_q_block"],
                "bwd_kv_block": best["bwd_kv_block"],
                "step_s": best["step_s"]},
    )
    return rec


def sweep_orders(arch: str, *, seq: int, batch: int, impl: str, reps: int,
                 blocks=(128, 256, 512), groups=(4, 8, 16, 32),
                 n_workers: int = 12, capacity_mib: float = 3.0,
                 measure_seq: int | None = None):
    """Joint (order, snake_group, blocks) sweep: modeled LLC bytes + wall time.

    The traversal order is free at the kernel level (the bodies are
    identical), so on CPU the discriminating signal is the *modeled* memory
    system: per (order, group, q_block/kv_block) candidate this replays the
    forward wavefront and the transposed dK/dV wavefront through the shared
    LRU (``fwd_llc_model``/``bwd_dkv_llc_model``) at a fixed modeled LLC
    capacity — absolute bytes, so block-size candidates compete on equal
    hardware — and ranks by total non-compulsory miss bytes. The jitted
    train-microstep (same objective as ``--autotune-bwd``) is then timed for
    the top candidates as a sanity check that the winning blocks are not
    compute-pathological. Writes artifacts/hillclimb/order_sweep_*.json
    with the winning ``(order, snake_group, q_block, kv_block)`` tuple.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.kernels import ops
    from repro.kernels.traffic import (
        FlashGridSpec, bwd_dkv_llc_model, fwd_llc_model,
    )

    cfg = get_config(arch)
    hd = cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    capacity_bytes = capacity_mib * 2**20
    measure_seq = measure_seq or min(seq, 1024)

    candidates = []
    for blk in blocks:
        if blk > seq:
            continue
        spec = FlashGridSpec(
            seq_q=seq, seq_kv=seq, n_groups=hq // hkv, head_dim=hd,
            q_block=blk, kv_block=blk, causal=True, window=cfg.window,
        )
        for order, group_list in (
            ("cyclic", [None]), ("sawtooth", [None]), ("block_snake", list(groups)),
        ):
            for g in group_list:
                if g is not None and g >= spec.nkv:
                    continue  # degenerate: == sawtooth at this block size
                fwd = fwd_llc_model(
                    spec, order, snake_group=g, n_workers=n_workers,
                    capacity_bytes=capacity_bytes,
                )
                bwd = bwd_dkv_llc_model(
                    spec, order, snake_group=g, n_workers=n_workers,
                    capacity_bytes=capacity_bytes,
                )
                miss = fwd.non_compulsory_misses + bwd.non_compulsory_misses
                candidates.append({
                    "order": order, "snake_group": g,
                    "q_block": blk, "kv_block": blk,
                    "fwd_noncomp_miss_bytes": fwd.non_compulsory_misses,
                    "bwd_noncomp_miss_bytes": bwd.non_compulsory_misses,
                    "total_noncomp_miss_bytes": miss,
                })
                print(f"[sweep-orders {arch}] {order}"
                      f"{'' if g is None else f'(g={g})'} blk={blk}: "
                      f"modeled miss {miss/2**20:.2f} MiB")
    if not candidates:
        raise SystemExit(
            f"sweep-orders: no block size in {blocks} fits --seq {seq}; "
            "pass a larger --seq or smaller blocks"
        )
    candidates.sort(key=lambda c: c["total_noncomp_miss_bytes"])

    # time the microstep for the best candidate per order family
    seen = set()
    for c in candidates:
        if c["order"] in seen:
            continue
        seen.add(c["order"])
        q = jax.random.normal(jax.random.PRNGKey(1), (batch, measure_seq, hq, hd), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(2), (batch, measure_seq, hkv, hd), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(3), (batch, measure_seq, hkv, hd), jnp.float32)

        def loss(q, k, v, c=c):
            out = ops.attention(
                q, k, v, order=c["order"], causal=True, window=cfg.window,
                q_block=c["q_block"], kv_block=c["kv_block"], impl=impl,
                score_dtype=cfg.score_dtype, snake_group=c["snake_group"],
            )
            return (out.astype(jnp.float32) ** 2).sum()

        fn = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))
        jax.block_until_ready(fn(q, k, v))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(q, k, v))
        c["microstep_s"] = (time.perf_counter() - t0) / reps
        print(f"[sweep-orders {arch}] timed {c['order']} "
              f"blk={c['q_block']}: {c['microstep_s']:.4f}s")

    winner = candidates[0]
    rec = {
        "arch": arch,
        "seq": seq,
        "measure_seq": measure_seq,
        "batch": batch,
        "impl": impl,
        "backend": jax.default_backend(),
        "n_workers": n_workers,
        "capacity_mib": capacity_mib,
        "winner": {
            "order": winner["order"],
            "snake_group": winner["snake_group"],
            "q_block": winner["q_block"],
            "kv_block": winner["kv_block"],
        },
        "candidates": candidates,
    }
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"order_sweep_{arch.replace('/', '_')}_s{seq}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    wg = "" if winner["snake_group"] is None else f"(g={winner['snake_group']})"
    print(
        f"[sweep-orders {arch}] winner: {winner['order']}{wg} "
        f"blocks=({winner['q_block']},{winner['kv_block']}) "
        f"modeled miss {winner['total_noncomp_miss_bytes']/2**20:.2f} MiB -> {path}"
    )
    record_winner(
        "order_sweep",
        key={"arch": arch, "seq_bucket": seq, "capacity_mib": capacity_mib,
             "n_workers": n_workers, "backend": rec["backend"]},
        winner=dict(rec["winner"],
                    modeled_miss_bytes=winner["total_noncomp_miss_bytes"]),
    )
    return rec


def sweep_draft_len(arch: str, *, draft_lens=(0, 2, 4, 7), reps: int = 3,
                    max_new: int = 96):
    """Speculative draft-length sweep: pick K for the serving engine.

    Runs the decode-heavy repetitive stream (the shape ``serve_bench
    --scenario speculative`` asserts on) through the continuous engine with
    the self-drafting n-gram drafter at each candidate ``K``, plus the
    ``K=0`` no-drafter baseline. Candidates are ranked by the
    *deterministic* mixed-step count (wall TPOT is recorded per candidate
    as a sanity check but CPU-CI noise never picks the winner); ties go to
    the smaller K — fewer wasted draft positions per verification chunk.
    The winner is persisted to the autotune cache
    (``kind="spec_draft_len"``) through the same JSONL schema the
    order-sweep winners use, so a serving launcher can consult it at
    startup.
    """
    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import NgramDrafter, Request, ServeEngine

    page, chunk, max_len = 8, 8, 256
    seeds = (5, 8)
    cfg = get_config(arch).reduced()
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    def make():
        reqs = []
        for i, s in enumerate(seeds):
            rng = np.random.default_rng(s)
            toks = np.tile(rng.integers(5, 20, size=4), 6).astype(np.int32)
            reqs.append(Request(tokens=toks, max_new_tokens=max_new, rid=i))
        return reqs

    rows = []
    for k in draft_lens:
        eng = ServeEngine(
            lm, params, batch_size=len(seeds), max_len=max_len,
            scheduler="continuous", page_size=page, prefill_chunk=chunk,
            drafter=NgramDrafter(ngram_max=4) if k > 0 else None,
            draft_len=max(k, 1),
        )
        eng.generate(make())  # warm-up: compile both widths
        best = None
        for _ in range(reps):
            t0 = time.time()
            res = eng.generate(make())
            best = min(best, time.time() - t0) if best else time.time() - t0
        st = eng.last_stats
        tokens = sum(r.steps for r in res)
        rows.append({
            "draft_len": k,
            "mixed_steps": st.mixed_steps,
            "seconds": round(best, 4),
            "tok_per_s": round(tokens / best, 2),
            "draft_tokens": st.draft_tokens,
            "accepted_tokens": st.accepted_tokens,
            "acceptance_rate": (
                round(st.acceptance_rate, 3) if st.draft_tokens else 0.0
            ),
        })
        print(f"[sweep-draft-len {arch}] K={k}: {st.mixed_steps} steps, "
              f"{rows[-1]['tok_per_s']} tok/s, "
              f"acceptance {rows[-1]['acceptance_rate']:.0%}")

    base = next(r for r in rows if r["draft_len"] == 0)
    winner = min(rows, key=lambda r: (r["mixed_steps"], r["draft_len"]))
    winner = dict(winner, steps_ratio=round(
        base["mixed_steps"] / max(winner["mixed_steps"], 1), 3))

    os.makedirs(OUT, exist_ok=True)
    rec = {
        "arch": arch,
        "backend": jax.default_backend(),
        "max_new": max_new,
        "prefill_chunk": chunk,
        "candidates": rows,
        "winner": winner,
    }
    path = os.path.join(OUT, f"spec_draft_len_{arch.replace('/', '_')}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[sweep-draft-len {arch}] winner: K={winner['draft_len']} "
          f"({winner['steps_ratio']}x steps vs K=0) -> {path}")
    record_winner(
        "spec_draft_len",
        key={"arch": arch, "max_new": max_new, "prefill_chunk": chunk,
             "drafter": "ngram", "backend": rec["backend"]},
        winner=winner,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(EXPERIMENTS))
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--autotune-bwd", default=None, metavar="ARCH",
                    help="grid-search backward block sizes on a jitted "
                    "train-microstep for ARCH, then exit")
    ap.add_argument("--sweep-orders", default=None, metavar="ARCH",
                    help="joint (order, snake_group, blocks) sweep: modeled "
                    "LLC miss bytes + microstep timing for ARCH, then exit")
    ap.add_argument("--sweep-draft-len", default=None, metavar="ARCH",
                    help="speculative draft-length sweep for ARCH: rank "
                    "K candidates by deterministic mixed-step count on the "
                    "decode-heavy stream, persist the winner to the "
                    "autotune cache, then exit")
    ap.add_argument("--draft-lens", default="0,2,4,7",
                    help="comma-separated K candidates for "
                    "--sweep-draft-len (0 = no-drafter baseline)")
    ap.add_argument("--capacity-mib", type=float, default=3.0,
                    help="modeled LLC capacity for --sweep-orders (MiB)")
    ap.add_argument("--llc-workers", type=int, default=12,
                    help="wavefront workers in the --sweep-orders LLC model")
    ap.add_argument("--sweep-blocks", default="128,256,512",
                    help="comma-separated block sizes for --sweep-orders")
    ap.add_argument("--sweep-groups", default="4,8,16,32",
                    help="comma-separated snake_group candidates for "
                    "--sweep-orders")
    ap.add_argument("--seq", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--impl", default="xla",
                    choices=["auto", "pallas", "pallas_interpret", "xla"])
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()

    if args.sweep_draft_len:
        sweep_draft_len(
            args.sweep_draft_len,
            draft_lens=tuple(int(x) for x in args.draft_lens.split(",")),
            reps=args.reps,
        )
        return

    if args.sweep_orders:
        sweep_orders(
            args.sweep_orders, seq=args.seq, batch=args.batch,
            impl=args.impl, reps=args.reps,
            blocks=tuple(int(x) for x in args.sweep_blocks.split(",")),
            groups=tuple(int(x) for x in args.sweep_groups.split(",")),
            n_workers=args.llc_workers, capacity_mib=args.capacity_mib,
        )
        return

    if args.autotune_bwd:
        # no dryrun import: keep the real device count (the 512-device flag
        # would shard the microstep and poison the timing)
        autotune_bwd(
            args.autotune_bwd, seq=args.seq, batch=args.batch,
            impl=args.impl, reps=args.reps,
        )
        return

    from repro.launch.dryrun import extrapolate_cell  # sets 512-dev flag
    from repro.launch.mesh import make_production_mesh

    os.makedirs(OUT, exist_ok=True)
    names = [args.only] if args.only else list(EXPERIMENTS)
    for name in names:
        exp = EXPERIMENTS[name]
        for tag, cfg_ov, par_ov in exp["steps"]:
            path = os.path.join(OUT, f"{name}__{tag}.json")
            if os.path.exists(path) and not args.no_resume:
                print(f"[cached] {name}/{tag}")
                continue
            mesh = make_production_mesh(multi_pod=False)
            try:
                rec = extrapolate_cell(
                    exp["arch"], exp["shape"], mesh, "single",
                    cfg_overrides=_apply_cfg_overrides(exp["arch"], cfg_ov),
                    par_overrides=dict(par_ov),
                )
                rec["experiment"] = name
                rec["step"] = tag
                r = rec["roofline"]
                print(
                    f"[{name}/{tag}] bneck={r['bottleneck']} "
                    f"Tc={r['compute_s']:.4f} Tm={r['memory_s']:.4f} "
                    f"Tx={r['collective_s']:.4f} step_s={r['step_s']:.4f} "
                    f"util={r['hw_flops_util']:.4f}"
                )
            except Exception as e:
                import traceback

                rec = {"experiment": name, "step": tag, "status": "error",
                       "error": str(e), "traceback": traceback.format_exc()[-3000:]}
                print(f"[{name}/{tag}] ERROR {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
