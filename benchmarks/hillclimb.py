"""§Perf hillclimbing driver.

Runs named configuration experiments against the three selected
(arch × shape) pairs and records trip-count-corrected roofline terms per
step into artifacts/hillclimb/. The hypothesis → napkin-math → measure →
validate narrative lives in EXPERIMENTS.md §Perf; this file is the
reproducible measurement harness for it.

Selected pairs (from the 33-cell baseline table):
  * mamba2-130m × train_4k   — worst roofline fraction (util 0.001)
  * olmoe-1b-7b × prefill_32k — most collective-bound (Tx/Tm = 2.4)
  * deepseek-7b × prefill_32k — most representative of the paper's
    technique (attention KV streaming dominates both terms)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--only PAIR]
(must run in its own process: imports repro.launch.dryrun which forces the
512-device XLA flag).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, "src")

EXPERIMENTS = {
    "mamba2_train": {
        "arch": "mamba2-130m",
        "shape": "train_4k",
        "steps": [
            # (tag, cfg_overrides, par_overrides)
            ("baseline", {}, {}),
            # H1: 130M params don't need TP/FSDP; model axis as extra DP
            # kills the vocab-gather remat + per-layer all-gathers and cuts
            # per-device activations 16x.
            ("pure_dp", {}, {
                "tensor_axis": "none",
                "fsdp_axes": (),
                "data_axes": ("data", "model"),
            }),
            # H2: SSD intra-chunk W matrix bytes are linear in chunk size;
            # chunk 128->64 halves the dominant f32 intermediate.
            ("pure_dp_chunk64", {"ssm": {"chunk": 64}}, {
                "tensor_axis": "none",
                "fsdp_axes": (),
                "data_axes": ("data", "model"),
            }),
            # H3: no-remat (memory is cheap for a 130M model at b=1/device;
            # full remat was re-reading every layer input twice).
            ("pure_dp_chunk64_noremat", {"ssm": {"chunk": 64}, "remat": "dots"}, {
                "tensor_axis": "none",
                "fsdp_axes": (),
                "data_axes": ("data", "model"),
            }),
        ],
    },
    "olmoe_prefill": {
        "arch": "olmoe-1b-7b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}),
            # H1: the dropless global argsort over 8.4M token-copies is the
            # collective driver; capacity-based dispatch shards statically.
            ("capacity_serve", {"moe_serve_dropless": False}, {}),
            # H2: + sequence-shard the residual/token stream so router and
            # dispatch work on (data x model)-sharded tokens.
            ("capacity_seqshard", {"moe_serve_dropless": False},
             {"seq_shard_activations": True}),
            # H3: + bf16 attention scores (memory term of the attn blocks).
            ("capacity_seqshard_bf16s",
             {"moe_serve_dropless": False, "score_dtype": "bfloat16"},
             {"seq_shard_activations": True}),
            # H4 (round 2): seqshard hurt (GSPMD replication, Tc x283) —
            # drop it; trim serve capacity factor instead (1.25 -> 1.0):
            # buffer + expert GEMM bytes scale with capacity.
            ("capacity_cf10", {"moe_serve_dropless": False,
                               "moe": {"capacity_factor": 1.0}}, {}),
        ],
    },
    "deepseek_prefill": {
        "arch": "deepseek-7b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}),
            # H1 (beyond-paper): bf16 scores/probs halve the dominant
            # attention HBM traffic the paper's technique targets.
            ("bf16_scores", {"score_dtype": "bfloat16"}, {}),
            # H2: sequence-shard residuals -> smaller per-layer all-gathers.
            ("bf16_seqshard", {"score_dtype": "bfloat16"},
             {"seq_shard_activations": True}),
            # H3: larger KV blocks (512->1024): fewer block boundaries,
            # fewer q-tile re-reads per KV pass.
            ("bf16_seqshard_kv1024",
             {"score_dtype": "bfloat16", "q_block": 1024, "kv_block": 1024},
             {"seq_shard_activations": True}),
            # H4 (round 2): attribution — seqshard alone, f32 scores.
            ("seqshard_only", {}, {"seq_shard_activations": True}),
        ],
    },
    # round 2 bonus pair: flagship dense model, transfer the deepseek win
    "llama3_prefill": {
        "arch": "llama3-405b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", {}, {}),
            ("seqshard", {}, {"seq_shard_activations": True}),
        ],
    },
    # round 3: the two worst remaining train cells
    "seamless_train": {
        "arch": "seamless-m4t-medium",
        "shape": "train_4k",
        "steps": [
            ("baseline", {}, {}),
            ("seqshard", {}, {"seq_shard_activations": True}),
        ],
    },
    "mixtral_train": {
        "arch": "mixtral-8x7b",
        "shape": "train_4k",
        "steps": [
            ("baseline", {}, {}),
            ("seqshard", {}, {"seq_shard_activations": True}),
        ],
    },
}

OUT = "artifacts/hillclimb"


def _apply_cfg_overrides(arch, ov):
    """ssm sub-dataclass overrides need reconstruction."""
    from repro.configs import get_config
    import dataclasses

    ov = dict(ov)
    base = get_config(arch)
    if "ssm" in ov:
        ov["ssm"] = dataclasses.replace(base.ssm, **ov["ssm"])
    if "moe" in ov:
        ov["moe"] = dataclasses.replace(base.moe, **ov["moe"])
    return ov


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(EXPERIMENTS))
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    from repro.launch.dryrun import extrapolate_cell  # sets 512-dev flag
    from repro.launch.mesh import make_production_mesh

    os.makedirs(OUT, exist_ok=True)
    names = [args.only] if args.only else list(EXPERIMENTS)
    for name in names:
        exp = EXPERIMENTS[name]
        for tag, cfg_ov, par_ov in exp["steps"]:
            path = os.path.join(OUT, f"{name}__{tag}.json")
            if os.path.exists(path) and not args.no_resume:
                print(f"[cached] {name}/{tag}")
                continue
            mesh = make_production_mesh(multi_pod=False)
            try:
                rec = extrapolate_cell(
                    exp["arch"], exp["shape"], mesh, "single",
                    cfg_overrides=_apply_cfg_overrides(exp["arch"], cfg_ov),
                    par_overrides=dict(par_ov),
                )
                rec["experiment"] = name
                rec["step"] = tag
                r = rec["roofline"]
                print(
                    f"[{name}/{tag}] bneck={r['bottleneck']} "
                    f"Tc={r['compute_s']:.4f} Tm={r['memory_s']:.4f} "
                    f"Tx={r['collective_s']:.4f} step_s={r['step_s']:.4f} "
                    f"util={r['hw_flops_util']:.4f}"
                )
            except Exception as e:
                import traceback

                rec = {"experiment": name, "step": tag, "status": "error",
                       "error": str(e), "traceback": traceback.format_exc()[-3000:]}
                print(f"[{name}/{tag}] ERROR {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
