"""Pallas TPU decode-attention kernel (one new token vs a long KV cache).

Used by ``serve_step`` for the decode_32k / long_500k shapes. The KV cache is
streamed chunk-by-chunk with online softmax; per-batch valid lengths and
sliding windows are carried by a precomputed (B, S_max) mask operand so the
kernel needs no scalar plumbing.

In the contiguous layout, sawtooth alternates the chunk-scan direction
across consecutive (batch·kv-head) grid rows. Unlike prefill there is no
*intrinsic* KV reuse between rows (different heads/batches read different
cache lines), so that toggle is exposed for symmetry and measurement, not
claimed as a win — see DESIGN.md §2 and kernels/traffic.py.

The *paged* layout (``paged_flash_decode_fwd``: shared page pools + per-row
block tables, scalar-prefetched visit order) restores a real reuse axis:
consecutive decode steps of one sequence re-walk the same pages, and
sawtooth parity keyed on the cache length re-touches the tail pages first
(DESIGN.md §8; reuse-distance deltas in core/cache_sim's page-trace mode).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.core.schedule import Order, Traversal, kv_index
from repro.kernels.flash_attention import MASK_VALUE, LANES, _pad_axis

__all__ = ["flash_decode_fwd", "paged_flash_decode_fwd"]


def _decode_step(q, k, v, ok, o_ref, m_scr, l_scr, acc_scr, *, c, n_chunks, scale):
    """One online-softmax chunk: q (Gp, D), k/v (ck, D), ok (ck,) bool."""

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )  # (Gp, ck)
    s = jnp.where(ok[None, :], s, MASK_VALUE)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(ok[None, :], jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(c == n_chunks - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _decode_kernel(
    q_ref,  # (1, Gp, D)
    k_ref,  # (1, ck, D)
    v_ref,
    mask_ref,  # (1, ck) f32 0/1
    o_ref,  # (1, Gp, D)
    m_scr,
    l_scr,
    acc_scr,
    *,
    n_chunks: int,
    scale: float,
):
    _decode_step(
        q_ref[0],
        k_ref[0],
        v_ref[0],
        mask_ref[0] > 0.0,
        o_ref,
        m_scr,
        l_scr,
        acc_scr,
        c=pl.program_id(1),
        n_chunks=n_chunks,
        scale=scale,
    )


def _paged_decode_kernel(
    visit_ref,  # scalar prefetch: (B, n_blocks) physical page ids (unused here —
    # consumed by the index maps; pallas passes it through to the body too)
    q_ref,  # (1, Gp, D)
    k_ref,  # (1, page, 1, D) one pool page, one kv head
    v_ref,
    mask_ref,  # (1, page) f32 0/1, already in visit order
    o_ref,  # (1, Gp, D)
    m_scr,
    l_scr,
    acc_scr,
    *,
    n_chunks: int,
    scale: float,
):
    _decode_step(
        q_ref[0],
        k_ref[0, :, 0, :],
        v_ref[0, :, 0, :],
        mask_ref[0] > 0.0,
        o_ref,
        m_scr,
        l_scr,
        acc_scr,
        c=pl.program_id(1),
        n_chunks=n_chunks,
        scale=scale,
    )


def flash_decode_fwd(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array | int,
    *,
    order: Order | str = Order.CYCLIC,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    chunk: int = 512,
    snake_group: Optional[int] = None,
    interpret: bool = False,
    block_table: Optional[jax.Array] = None,
) -> jax.Array:
    """q (B,1,Hq,D); caches (B,S_max,Hkv,D); cache_len scalar or (B,).

    With ``block_table`` (B, n_blocks), caches are shared page pools
    (n_pages, page, Hkv, D) and the kernel visits each row's pages through
    the block table in schedule order (see :func:`paged_flash_decode_fwd`).
    """
    if block_table is not None:
        return paged_flash_decode_fwd(
            q,
            k_cache,
            v_cache,
            cache_len,
            block_table,
            order=order,
            window=window,
            scale=scale,
            snake_group=snake_group,
            interpret=interpret,
        )
    return _flash_decode_contiguous(
        q,
        k_cache,
        v_cache,
        cache_len,
        order=Order.parse(order),
        window=window,
        scale=scale,
        chunk=chunk,
        snake_group=snake_group,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("order", "window", "scale", "chunk", "snake_group", "interpret"),
)
def _flash_decode_contiguous(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array | int,
    *,
    order: Order,
    window: Optional[int],
    scale: Optional[float],
    chunk: int,
    snake_group: Optional[int],
    interpret: bool,
) -> jax.Array:
    b, one, hq, d = q.shape
    assert one == 1, "decode kernel takes a single query position"
    _, s_max, hkv, _ = k_cache.shape
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)
    chunk = min(chunk, max(128, 1 << (s_max - 1).bit_length()))

    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    pos = jnp.arange(s_max, dtype=jnp.int32)[None, :]
    ok = pos < lens[:, None]
    if window is not None:
        ok &= pos > (lens[:, None] - 1 - window)
    mask = ok.astype(jnp.float32)  # (B, S_max)
    mask = _pad_axis(mask, 1, chunk)

    g_pad = max(8, g)
    qf = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    qf = _pad_axis(_pad_axis(qf, 1, g_pad), 2, LANES)
    kf = _pad_axis(
        _pad_axis(k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s_max, d), 1, chunk),
        2,
        LANES,
    )
    vf = _pad_axis(
        _pad_axis(v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s_max, d), 1, chunk),
        2,
        LANES,
    )
    dp = kf.shape[2]
    n_chunks = kf.shape[1] // chunk

    # The chunk walk derives from the same IR as every other consumer:
    # kv_index over n_chunks with the (batch*kv-head) grid row as the parity
    # driver (contiguous decode has no intrinsic cross-row reuse — DESIGN.md
    # §2 — so the toggle is for symmetry and measurement).
    def q_map(bh, c):
        return (bh, 0, 0)

    def kv_map(bh, c):
        return (bh, kv_index(order, bh, c, n_chunks, snake_group=snake_group), 0)

    def mask_map(bh, c):
        return (bh // hkv, kv_index(order, bh, c, n_chunks, snake_group=snake_group))

    kernel = functools.partial(_decode_kernel, n_chunks=n_chunks, scale=scale_)
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )

    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, n_chunks),
        in_specs=[
            pl.BlockSpec((1, g_pad, dp), q_map),
            pl.BlockSpec((1, chunk, dp), kv_map),
            pl.BlockSpec((1, chunk, dp), kv_map),
            pl.BlockSpec((1, chunk), mask_map),
        ],
        out_specs=pl.BlockSpec((1, g_pad, dp), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g_pad, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, LANES), jnp.float32),
            pltpu.VMEM((g_pad, LANES), jnp.float32),
            pltpu.VMEM((g_pad, dp), jnp.float32),
        ],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(qf, kf, vf, mask)

    out = out.reshape(b, hkv, g_pad, dp)[:, :, :g, :d]
    return out.reshape(b, 1, hq, d)


@functools.partial(
    jax.jit,
    static_argnames=("order", "window", "scale", "snake_group", "interpret"),
)
def paged_flash_decode_fwd(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    cache_len: jax.Array | int,
    block_table: jax.Array,
    *,
    order: Order | str = Order.CYCLIC,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    snake_group: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Paged decode: q (B,1,Hq,D); pools (n_pages, page, Hkv, D).

    The schedule is folded into the operands before the kernel launches:
    the compiled ``Traversal``'s ``visit_order`` lowering (sawtooth parity
    = cache_len, so consecutive decode steps reverse direction) gives each
    row's logical visit order, the block table maps it to physical pool
    pages, and that (B, n_blocks) physical id array is the scalar-prefetch
    operand the KV ``index_map`` reads — the classic TPU paged-attention
    pattern. The validity mask is pre-gathered into the same visit order so
    mask chunk c always matches KV chunk c.
    """
    order = Order.parse(order)
    b, one, hq, d = q.shape
    assert one == 1, "decode kernel takes a single query position"
    n_pages, page, hkv, _ = k_pool.shape
    n_blocks = block_table.shape[1]
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)

    tr = Traversal(
        order=order, n_q=1, n_kv=n_blocks, q_block=1, kv_block=page,
        snake_group=snake_group,
    )
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    visit = tr.visit_order(lens)  # (B, n_blocks) logical
    phys = jnp.take_along_axis(block_table.astype(jnp.int32), visit, axis=1)

    # Validity mask per logical position, gathered into visit order.
    pos = visit[:, :, None] * page + jnp.arange(page, dtype=jnp.int32)
    ok = pos < lens[:, None, None]
    if window is not None:
        ok &= pos > (lens[:, None, None] - 1 - window)
    mask = ok.reshape(b, n_blocks * page).astype(jnp.float32)

    g_pad = max(8, g)
    qf = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    qf = _pad_axis(_pad_axis(qf, 1, g_pad), 2, LANES)
    kf = _pad_axis(k_pool, 3, LANES)
    vf = _pad_axis(v_pool, 3, LANES)
    dp = kf.shape[3]

    def q_map(bh, c, visit_ref):
        return (bh, 0, 0)

    def kv_map(bh, c, visit_ref):
        return (visit_ref[bh // hkv, c], 0, bh % hkv, 0)

    def mask_map(bh, c, visit_ref):
        return (bh // hkv, c)

    kernel = functools.partial(
        _paged_decode_kernel, n_chunks=n_blocks, scale=scale_
    )
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, g_pad, dp), q_map),
            pl.BlockSpec((1, page, 1, dp), kv_map),
            pl.BlockSpec((1, page, 1, dp), kv_map),
            pl.BlockSpec((1, page), mask_map),
        ],
        out_specs=pl.BlockSpec((1, g_pad, dp), q_map),
        scratch_shapes=[
            pltpu.VMEM((g_pad, LANES), jnp.float32),
            pltpu.VMEM((g_pad, LANES), jnp.float32),
            pltpu.VMEM((g_pad, dp), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g_pad, dp), q.dtype),
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(phys, qf, kf, vf, mask)

    out = out.reshape(b, hkv, g_pad, dp)[:, :, :g, :d]
    return out.reshape(b, 1, hq, d)
