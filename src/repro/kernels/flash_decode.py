"""Pallas TPU decode-attention kernel (one new token vs a long KV cache).

Used by ``serve_step`` for the decode_32k / long_500k shapes. The KV cache is
streamed chunk-by-chunk with online softmax; per-batch valid lengths and
sliding windows are carried by a precomputed (B, S_max) mask operand so the
kernel needs no scalar plumbing.

In the contiguous layout, sawtooth alternates the chunk-scan direction
across consecutive (batch·kv-head) grid rows. Unlike prefill there is no
*intrinsic* KV reuse between rows (different heads/batches read different
cache lines), so that toggle is exposed for symmetry and measurement, not
claimed as a win — see DESIGN.md §2 and kernels/traffic.py.

The *paged* layout (``paged_flash_decode_fwd``: shared page pools + per-row
block tables, scalar-prefetched visit order) restores a real reuse axis:
consecutive decode steps of one sequence re-walk the same pages, and
sawtooth parity keyed on the cache length re-touches the tail pages first
(DESIGN.md §8; reuse-distance deltas in core/cache_sim's page-trace mode).
It is also *ragged*: q may carry C > 1 chunk positions per row with
per-row valid counts, causally masked inside the chunk — the serve
engine's unified mixed step (decode rows + chunked prefill rows) is one
launch of this kernel per layer.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.core.schedule import (
    Order,
    Traversal,
    kv_index,
    page_visit_order_dynamic,
)
from repro.kernels.flash_attention import MASK_VALUE, LANES, _pad_axis

__all__ = ["flash_decode_fwd", "paged_flash_decode_fwd"]


def _decode_step(q, k, v, ok, o_ref, m_scr, l_scr, acc_scr, *, c, n_chunks, scale):
    """One online-softmax chunk: q (Gp, D), k/v (ck, D), ok (1|Gp, ck) bool
    (broadcast against the (Gp, ck) score tile — per-query-row masks carry
    the ragged chunk's in-chunk causal structure)."""

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )  # (Gp, ck)
    s = jnp.where(ok, s, MASK_VALUE)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(c == n_chunks - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _decode_kernel(
    q_ref,  # (1, Gp, D)
    k_ref,  # (1, ck, D)
    v_ref,
    mask_ref,  # (1, ck) f32 0/1
    o_ref,  # (1, Gp, D)
    m_scr,
    l_scr,
    acc_scr,
    *,
    n_chunks: int,
    scale: float,
):
    _decode_step(
        q_ref[0],
        k_ref[0],
        v_ref[0],
        (mask_ref[0] > 0.0)[None, :],
        o_ref,
        m_scr,
        l_scr,
        acc_scr,
        c=pl.program_id(1),
        n_chunks=n_chunks,
        scale=scale,
    )


def _paged_decode_kernel(
    phys_ref,     # scalar prefetch: (B, n_blocks) physical page ids (index maps)
    logical_ref,  # scalar prefetch: (B, n_blocks) visit-ordered logical page ids
    meta_ref,     # scalar prefetch: (B, 2) per-row [cache_len, q_len]
    q_ref,  # (1, CGp, D) — C chunk rows × G GQA rows, query-major
    k_ref,  # (1, page, 1, D) one pool page, one kv head
    v_ref,
    o_ref,  # (1, CGp, D)
    m_scr,
    l_scr,
    acc_scr,
    *,
    n_chunks: int,
    scale: float,
    page: int,
    g: int,
    hkv: int,
    window: Optional[int],
):
    """Ragged paged chunk: the whole mask is derived in-kernel from the
    scalar-prefetched (cache_len, q_len) row metadata and the visit-ordered
    logical page id — no O(B·n_blocks·C·page) mask operand ever exists.
    Query row r of the folded tile is chunk position ``r // g`` at absolute
    position ``cache_len - q_len + r // g``; rows past ``q_len`` (padding /
    inactive slots) are fully masked and finalize to exact zeros."""
    c = pl.program_id(1)
    b = pl.program_id(0) // hkv
    logical = logical_ref[b, c]
    length = meta_ref[b, 0]
    q_len = meta_ref[b, 1]
    rows = q_ref.shape[1]
    row_t = jax.lax.broadcasted_iota(jnp.int32, (rows, page), 0) // g
    col = logical * page + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1)
    q_pos = (length - q_len) + row_t
    ok = (col <= q_pos) & (col < length) & (row_t < q_len)
    if window is not None:
        ok &= col > q_pos - window
    _decode_step(
        q_ref[0],
        k_ref[0, :, 0, :],
        v_ref[0, :, 0, :],
        ok,
        o_ref,
        m_scr,
        l_scr,
        acc_scr,
        c=c,
        n_chunks=n_chunks,
        scale=scale,
    )


def flash_decode_fwd(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array | int,
    *,
    order: Order | str = Order.CYCLIC,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    chunk: int = 512,
    snake_group: Optional[int] = None,
    interpret: bool = False,
    block_table: Optional[jax.Array] = None,
    q_lens: Optional[jax.Array] = None,
    order_group: Optional[jax.Array] = None,
) -> jax.Array:
    """q (B,1,Hq,D); caches (B,S_max,Hkv,D); cache_len scalar or (B,).

    With ``block_table`` (B, n_blocks), caches are shared page pools
    (n_pages, page, Hkv, D) and the kernel visits each row's pages through
    the block table in schedule order; q may then carry C > 1 ragged chunk
    positions per row with per-row ``q_lens`` (see
    :func:`paged_flash_decode_fwd`). ``order_group`` (paged only) replaces
    the static order with a traced effective reversal-group operand so the
    visit order can change per step without retracing.
    """
    if block_table is not None:
        return paged_flash_decode_fwd(
            q,
            k_cache,
            v_cache,
            cache_len,
            block_table,
            q_lens=q_lens,
            order=order,
            window=window,
            scale=scale,
            snake_group=snake_group,
            interpret=interpret,
            order_group=order_group,
        )
    assert q_lens is None, "q_lens requires the paged layout (block_table)"
    assert order_group is None, "order_group requires the paged layout"
    return _flash_decode_contiguous(
        q,
        k_cache,
        v_cache,
        cache_len,
        order=Order.parse(order),
        window=window,
        scale=scale,
        chunk=chunk,
        snake_group=snake_group,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("order", "window", "scale", "chunk", "snake_group", "interpret"),
)
def _flash_decode_contiguous(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array | int,
    *,
    order: Order,
    window: Optional[int],
    scale: Optional[float],
    chunk: int,
    snake_group: Optional[int],
    interpret: bool,
) -> jax.Array:
    b, one, hq, d = q.shape
    assert one == 1, "decode kernel takes a single query position"
    _, s_max, hkv, _ = k_cache.shape
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)
    chunk = min(chunk, max(128, 1 << (s_max - 1).bit_length()))

    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    pos = jnp.arange(s_max, dtype=jnp.int32)[None, :]
    ok = pos < lens[:, None]
    if window is not None:
        ok &= pos > (lens[:, None] - 1 - window)
    mask = ok.astype(jnp.float32)  # (B, S_max)
    mask = _pad_axis(mask, 1, chunk)

    g_pad = max(8, g)
    qf = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    qf = _pad_axis(_pad_axis(qf, 1, g_pad), 2, LANES)
    kf = _pad_axis(
        _pad_axis(k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s_max, d), 1, chunk),
        2,
        LANES,
    )
    vf = _pad_axis(
        _pad_axis(v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s_max, d), 1, chunk),
        2,
        LANES,
    )
    dp = kf.shape[2]
    n_chunks = kf.shape[1] // chunk

    # The chunk walk derives from the same IR as every other consumer:
    # kv_index over n_chunks with the (batch*kv-head) grid row as the parity
    # driver (contiguous decode has no intrinsic cross-row reuse — DESIGN.md
    # §2 — so the toggle is for symmetry and measurement).
    def q_map(bh, c):
        return (bh, 0, 0)

    def kv_map(bh, c):
        return (bh, kv_index(order, bh, c, n_chunks, snake_group=snake_group), 0)

    def mask_map(bh, c):
        return (bh // hkv, kv_index(order, bh, c, n_chunks, snake_group=snake_group))

    kernel = functools.partial(_decode_kernel, n_chunks=n_chunks, scale=scale_)
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )

    out = pl.pallas_call(
        kernel,
        grid=(b * hkv, n_chunks),
        in_specs=[
            pl.BlockSpec((1, g_pad, dp), q_map),
            pl.BlockSpec((1, chunk, dp), kv_map),
            pl.BlockSpec((1, chunk, dp), kv_map),
            pl.BlockSpec((1, chunk), mask_map),
        ],
        out_specs=pl.BlockSpec((1, g_pad, dp), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g_pad, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, LANES), jnp.float32),
            pltpu.VMEM((g_pad, LANES), jnp.float32),
            pltpu.VMEM((g_pad, dp), jnp.float32),
        ],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(qf, kf, vf, mask)

    out = out.reshape(b, hkv, g_pad, dp)[:, :, :g, :d]
    return out.reshape(b, 1, hq, d)


@functools.partial(
    jax.jit,
    static_argnames=("order", "window", "scale", "snake_group", "interpret"),
)
def paged_flash_decode_fwd(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    cache_len: jax.Array | int,
    block_table: jax.Array,
    *,
    q_lens: Optional[jax.Array] = None,
    order: Order | str = Order.CYCLIC,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    snake_group: Optional[int] = None,
    interpret: bool = False,
    order_group: Optional[jax.Array] = None,
) -> jax.Array:
    """Ragged paged attention: q (B,C,Hq,D); pools (n_pages, page, Hkv, D).

    C = 1 is plain decode; C > 1 is a chunked-prefill / mixed serve step,
    with per-row ``q_lens`` valid chunk rows and causal masking *inside*
    the chunk (query t of row b sits at position ``cache_len - q_len + t``).

    The schedule is folded into the operands before the kernel launches:
    the compiled ``Traversal``'s ``visit_order`` lowering (sawtooth parity
    = cache_len per row, so consecutive steps reverse direction) gives each
    row's logical visit order, the block table maps it to physical pool
    pages, and that (B, n_blocks) physical id array is the scalar-prefetch
    operand the KV ``index_map`` reads — the classic TPU paged-attention
    pattern. Validity/causality is computed *in-kernel* from two more
    scalar-prefetch operands (the visit-ordered logical ids and per-row
    (cache_len, q_len)), so no O(B·n_blocks·C·page) mask operand exists.
    """
    order = Order.parse(order)
    b, c, hq, d = q.shape
    n_pages, page, hkv, _ = k_pool.shape
    n_blocks = block_table.shape[1]
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)

    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    qls = (
        jnp.full((b,), c, jnp.int32)
        if q_lens is None
        else jnp.broadcast_to(jnp.asarray(q_lens, jnp.int32), (b,))
    )
    if order_group is not None:
        # Runtime-switchable order: the schedule is already folded into the
        # scalar-prefetch operands outside the kernel, so rebinding the
        # visit order is pure data — the effective reversal group arrives
        # as a traced scalar (schedule.resolve_order_group) and the static
        # ``order``/``snake_group`` arguments are ignored. The kernel body
        # is untouched; no recompile happens across order switches.
        visit = page_visit_order_dynamic(lens, n_blocks, order_group)
    else:
        tr = Traversal(
            order=order, n_q=1, n_kv=n_blocks, q_block=1, kv_block=page,
            snake_group=snake_group,
        )
        visit = tr.visit_order(lens)  # (B, n_blocks) logical
    phys = jnp.take_along_axis(block_table.astype(jnp.int32), visit, axis=1)
    meta = jnp.stack([lens, qls], axis=1)  # (B, 2)

    # Fold (chunk, GQA group) into one query-major row axis: row = t*g + gg.
    cg = c * g
    cg_pad = max(8, -(-cg // 8) * 8)
    qf = (
        q.reshape(b, c, hkv, g, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(b * hkv, cg, d)
    )
    qf = _pad_axis(_pad_axis(qf, 1, cg_pad), 2, LANES)
    kf = _pad_axis(k_pool, 3, LANES)
    vf = _pad_axis(v_pool, 3, LANES)
    dp = kf.shape[3]

    def q_map(bh, j, phys_ref, logical_ref, meta_ref):
        return (bh, 0, 0)

    def kv_map(bh, j, phys_ref, logical_ref, meta_ref):
        return (phys_ref[bh // hkv, j], 0, bh % hkv, 0)

    kernel = functools.partial(
        _paged_decode_kernel,
        n_chunks=n_blocks,
        scale=scale_,
        page=page,
        g=g,
        hkv=hkv,
        window=window,
    )
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b * hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, cg_pad, dp), q_map),
            pl.BlockSpec((1, page, 1, dp), kv_map),
            pl.BlockSpec((1, page, 1, dp), kv_map),
        ],
        out_specs=pl.BlockSpec((1, cg_pad, dp), q_map),
        scratch_shapes=[
            pltpu.VMEM((cg_pad, LANES), jnp.float32),
            pltpu.VMEM((cg_pad, LANES), jnp.float32),
            pltpu.VMEM((cg_pad, dp), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, cg_pad, dp), q.dtype),
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(phys, visit, meta, qf, kf, vf)

    out = out[:, :cg, :d].reshape(b, hkv, c, g, d)
    return out.transpose(0, 2, 1, 3, 4).reshape(b, c, hq, d)
