"""HBM->VMEM traffic models for the Pallas flash kernels (TPU analogue of §3.2).

The Pallas TPU pipeline elides the copy for an operand whose block index is
unchanged between consecutive grid steps ("revisiting"). This module hosts
two model families, both lowered from the same compiled
``repro.core.schedule.Traversal`` the kernels consume:

* the **pipeline replays** (``pipeline_traffic``/``bwd_dq_traffic``/
  ``bwd_dkv_traffic``) walk ``fwd_grid_steps``/``stream_grid_steps`` — the
  exact index_map arithmetic, *global-row* parity included — so these byte
  counts cannot drift from the kernels; they are the TPU-native equivalent
  of the paper's L2 sector-access model, and the quantity sawtooth reduces
  structurally (the pass-boundary block is always elided);
* the **LLC wavefront models** (``fwd_llc_model``/``bwd_dkv_llc_model``)
  replay ``Traversal.wavefront`` — the paper's persistent-worker execution
  model (Alg. 2 round-robin, §3.4 lock-step, Alg. 4 *worker-local* parity)
  — through a finite shared LRU. Note the deliberate parity difference:
  the Pallas index_maps key direction on the global row id (a proxy that
  matches the worker-local counter only when worker count and row parity
  align), while these models keep the paper's per-worker counter; they
  model the GB10-style shared-LLC wavefront, not the TPU DMA stream.

Backward grids: the dQ kernel reuses the forward grid (KV streamed), so its
traffic is the forward replay with the extra dO/lse/delta reads and the dQ
write. The dK/dV kernel runs the *transposed* grid — each KV tile resident,
the Q-side operands streamed — so the cyclic reuse pathology moves to the
Q/dO stream (``bwd_dkv_traffic``); ``bwd_dkv_llc_model`` additionally plays
the transposed wavefront (``core.schedule.BwdKVSchedule``) through the LRU
simulator with a finite shared buffer (CMEM on v4, or "what if TPUs had a
GB10-style LLC"), which is where the paper-style ~50% non-compulsory miss
reduction shows up and what the ≥30% acceptance test asserts.

``fwd_llc_model`` is the per-order forward-grid counterpart and the place
``block_snake`` earns its keep: causal trimming gives the round-robin
workers different pass lengths, so the lock-step wavefront *desynchronizes*
— under sawtooth, desynchronized workers sweep the full KV range in
opposite directions and the shared buffer thrashes, while block_snake keeps
every worker's reversal inside a ``snake_group``-tile window, bounding the
concurrent footprint so it can be sized to the modeled LLC capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.schedule import Order, Traversal

__all__ = [
    "FlashGridSpec",
    "pipeline_traffic",
    "TrafficReport",
    "BwdTrafficReport",
    "bwd_dq_traffic",
    "bwd_dkv_traffic",
    "bwd_dkv_llc_model",
    "fwd_llc_model",
    "shared_prefix_llc_model",
]


@dataclasses.dataclass(frozen=True)
class FlashGridSpec:
    """Static description of one flash_attention_fwd launch (one bh slice)."""

    seq_q: int
    seq_kv: int
    n_groups: int = 1          # GQA G (q tiles folded per kv head)
    head_dim: int = 128
    q_block: int = 256
    kv_block: int = 256
    elem_bytes: int = 2
    causal: bool = False
    window: Optional[int] = None

    @property
    def nq(self) -> int:
        return -(-self.seq_q // self.q_block)

    @property
    def nkv(self) -> int:
        return -(-self.seq_kv // self.kv_block)

    def traversal(
        self, order: Order | str, snake_group: Optional[int] = None
    ) -> Traversal:
        """Compile the Traversal this launch's kernels would consume."""
        return Traversal(
            order=Order.parse(order),
            n_q=self.nq,
            n_kv=self.nkv,
            causal=self.causal,
            window=self.window,
            q_block=self.q_block,
            kv_block=self.kv_block,
            n_groups=self.n_groups,
            snake_group=snake_group,
        )


@dataclasses.dataclass
class TrafficReport:
    q_bytes: int = 0
    kv_bytes: int = 0
    out_bytes: int = 0
    elided_kv_fetches: int = 0
    total_kv_fetches: int = 0

    @property
    def total_bytes(self) -> int:
        return self.q_bytes + self.kv_bytes + self.out_bytes


def pipeline_traffic(
    spec: FlashGridSpec,
    order: Order | str,
    *,
    snake_group: Optional[int] = None,
) -> TrafficReport:
    """Count HBM bytes fetched under Pallas consecutive-revisit elision."""
    tr = spec.traversal(order, snake_group)
    rep = TrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes  # K and V
    last_q = None
    last_kv = None
    for i, jj, _valid in tr.fwd_grid_steps():
        if last_q != i:
            rep.q_bytes += q_tile_bytes
            rep.out_bytes += q_tile_bytes  # O written once per tile
            last_q = i
        rep.total_kv_fetches += 1
        if last_kv == jj:
            rep.elided_kv_fetches += 1
        else:
            rep.kv_bytes += kv_tile_bytes
            last_kv = jj
    return rep


# --------------------------------------------------------------------------
# backward grids
# --------------------------------------------------------------------------

# lse and delta are f32 per-row vectors, but the kernels stream them
# lane-replicated as (q_block, 128) f32 tiles (the upstream JAX TPU
# flash-bwd residual layout — Mosaic has no cheap lane->sublane broadcast),
# so the model counts the replicated bytes actually DMA'd.
LSE_BYTES = 4
RESIDUAL_LANES = 128


@dataclasses.dataclass
class BwdTrafficReport:
    """Byte counts for one backward grid (roles named, not Q/KV-fixed)."""

    resident_bytes: int = 0    # operands fetched once per resident tile
    stream_bytes: int = 0      # the streamed operand bundle (non-elided)
    write_bytes: int = 0       # gradient tiles written
    elided_stream_fetches: int = 0
    total_stream_fetches: int = 0

    @property
    def total_bytes(self) -> int:
        return self.resident_bytes + self.stream_bytes + self.write_bytes


def _row_vec_bytes(spec: FlashGridSpec) -> int:
    return spec.q_block * RESIDUAL_LANES * LSE_BYTES


def bwd_dq_traffic(
    spec: FlashGridSpec,
    order: Order | str,
    *,
    snake_group: Optional[int] = None,
) -> BwdTrafficReport:
    """dQ kernel traffic: the forward grid (Q-side resident, K/V streamed).

    Per resident row: q + do + lse + delta fetched once, dq written once;
    K/V tiles stream with the same schedule/elision as the forward.
    """
    tr = spec.traversal(order, snake_group)
    rep = BwdTrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes
    last_q = None
    last_kv = None
    for i, jj, _valid in tr.fwd_grid_steps():
        if last_q != i:
            rep.resident_bytes += 2 * q_tile_bytes + 2 * _row_vec_bytes(spec)
            rep.write_bytes += q_tile_bytes
            last_q = i
        rep.total_stream_fetches += 1
        if last_kv == jj:
            rep.elided_stream_fetches += 1
        else:
            rep.stream_bytes += kv_tile_bytes
            last_kv = jj
    return rep


def bwd_dkv_traffic(
    spec: FlashGridSpec,
    order: Order | str,
    *,
    snake_group: Optional[int] = None,
) -> BwdTrafficReport:
    """dK/dV kernel traffic: the transposed grid (KV resident, Q streamed).

    Each resident KV tile streams one linearized sweep — all GQA groups
    over the trimmed Q range — of q + do + lse + delta bundles; K/V are
    fetched and dK/dV written once per KV tile. Sawtooth reverses the whole
    sweep on odd resident counters (``Traversal.stream_block_index``), so
    the sweep-boundary bundle is elided at every KV-tile transition, GQA
    included; block_snake reverses within ``snake_group``-sized windows of
    the sweep instead.
    """
    tr = spec.traversal(order, snake_group)
    rep = BwdTrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes
    stream_bytes = 2 * q_tile_bytes + 2 * _row_vec_bytes(spec)  # q+do+lse+delta
    last_resident = None
    last_stream = None
    for jkv, gg, qi, _valid in tr.stream_grid_steps():
        if last_resident != jkv:
            rep.resident_bytes += kv_tile_bytes
            rep.write_bytes += kv_tile_bytes
            last_resident = jkv
        key = (gg, qi)
        rep.total_stream_fetches += 1
        if last_stream == key:
            rep.elided_stream_fetches += 1
        else:
            rep.stream_bytes += stream_bytes
            last_stream = key
    return rep


def bwd_dkv_llc_model(
    spec: FlashGridSpec,
    order: Order | str,
    *,
    snake_group: Optional[int] = None,
    n_workers: int = 4,
    capacity_frac: float = 0.5,
    capacity_bytes: Optional[float] = None,
):
    """LRU shared-buffer model of the dK/dV wavefront (paper §3.3/§4.2 shape).

    Plays the transposed wavefront trace through an LRU whose capacity is
    ``capacity_frac`` of the distinct streamed Q-side bytes (or the absolute
    ``capacity_bytes`` when given — the fixed-hardware view a joint
    order/block sweep needs) — the regime where cyclic traversal thrashes
    (reuse distance = the whole Q stream) and sawtooth halves the
    non-compulsory misses. Returns a ``cache_sim.SimResult`` in bytes.
    """
    from repro.core.cache_sim import simulate_trace  # lazy: avoid import cycle

    tr = spec.traversal(order, snake_group)
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = spec.kv_block * spec.head_dim * spec.elem_bytes
    weights = {
        "Q": q_tile_bytes,
        "dO": q_tile_bytes,
        "K": kv_tile_bytes,
        "V": kv_tile_bytes,
    }
    if capacity_bytes is None:
        # frac of the distinct streamed Q-side bytes (all GQA groups)
        capacity_bytes = capacity_frac * 2 * spec.n_groups * spec.nq * q_tile_bytes
    # dK/dV are streaming stores (written once, never re-read) — they bypass
    # the buffer, like the paper's L2 *read* sector model.
    trace = (
        ((tensor, key), weights[tensor])
        for _, tensor, key in tr.wavefront(n_workers, transposed=True)
        if tensor in weights
    )
    return simulate_trace(trace, capacity_bytes)


def fwd_llc_model(
    spec: FlashGridSpec,
    order: Order | str,
    *,
    snake_group: Optional[int] = None,
    n_workers: int = 8,
    capacity_frac: float = 0.75,
    capacity_bytes: Optional[float] = None,
):
    """LRU shared-buffer model of the *forward* wavefront, per order.

    Plays the forward persistent-worker wavefront (round-robin Q tiles,
    lock-step progress — ``KVSchedule.wavefront_trace``) through an LRU
    whose capacity is ``capacity_frac`` of the distinct K+V stream bytes.
    Q tiles are read through the buffer too; O tiles are streaming stores
    and bypass it. Returns a ``cache_sim.SimResult`` in bytes.

    This is the capacity-bound regime the ``block_snake`` order targets:
    with causal trimming the workers' pass lengths differ, the wavefront
    desynchronizes, and sawtooth's full-range opposite-direction sweeps
    spread concurrent accesses across the whole KV range — misses despite
    a buffer large enough to hold most of it. Bounding the reversal to
    ``snake_group`` tiles keeps co-resident accesses within ~one group of
    each other, so a group sized below the buffer capacity turns those
    spread accesses back into hits (asserted in tests/test_traversal.py;
    sweep the knob with ``benchmarks/hillclimb.py --sweep-orders``).
    """
    from repro.core.cache_sim import simulate_trace  # lazy: avoid import cycle

    tr = spec.traversal(order, snake_group)
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = spec.kv_block * spec.head_dim * spec.elem_bytes
    weights = {"Q": q_tile_bytes, "K": kv_tile_bytes, "V": kv_tile_bytes}
    if capacity_bytes is None:
        capacity_bytes = capacity_frac * 2 * spec.nkv * kv_tile_bytes  # K+V bytes
    trace = (
        ((tensor, key), weights[tensor])
        for _, tensor, key in tr.wavefront(n_workers)
        if tensor in weights
    )
    return simulate_trace(trace, capacity_bytes)


def shared_prefix_llc_model(
    order: Order | str,
    *,
    n_rows: int = 8,
    prefix_pages: int = 8,
    own_tokens: int = 16,
    n_steps: int = 16,
    page: int = 16,
    n_kv_heads: int = 2,
    head_dim: int = 128,
    elem_bytes: int = 2,
    shared: bool = True,
    capacity_frac: float = 0.5,
    capacity_bytes: Optional[float] = None,
    snake_group: Optional[int] = None,
):
    """LRU shared-buffer model of a shared-prefix ragged serve step stream.

    Plays ``core.cache_sim.shared_prefix_decode_trace`` — n_rows sequences
    with a common ``prefix_pages``-page prompt prefix, interleaved in the
    step-level lock-step visit order (``schedule.step_page_visits``), each
    row's walk in its own sawtooth/block_snake parity — through an LRU of
    ``capacity_frac`` × the *unshared* distinct K+V page bytes. Returns a
    ``cache_sim.SimResult`` in bytes.

    With ``shared=True`` the prefix pages are single physical copies (the
    ``serve.kv_pool`` hash-dedup layout): every row past the first hits
    them both in the LLC *and* as deduplicated cold misses, so both the
    compulsory floor and the capacity misses drop versus the private-copy
    layout — the serving-side locality axis the paper's traversal orders
    act on once continuous batching shares pages across rows.
    """
    from repro.core.cache_sim import shared_prefix_decode_trace, simulate_trace

    page_bytes = page * n_kv_heads * head_dim * elem_bytes
    if capacity_bytes is None:
        distinct = n_rows * (prefix_pages + -(-(own_tokens + n_steps) // page))
        capacity_bytes = capacity_frac * 2 * distinct * page_bytes  # K+V
    trace = (
        (key, page_bytes)
        for key in shared_prefix_decode_trace(
            order,
            n_rows,
            prefix_pages,
            [own_tokens] * n_rows,
            n_steps,
            page,
            shared=shared,
            snake_group=snake_group,
        )
    )
    return simulate_trace(trace, capacity_bytes)
