"""HBM->VMEM traffic model for the Pallas flash kernels (TPU analogue of §3.2).

The Pallas TPU pipeline elides the copy for an operand whose block index is
unchanged between consecutive grid steps ("revisiting"). This module replays
the kernel grid host-side with the exact index_map arithmetic and counts
fetched bytes per operand — the TPU-native equivalent of the paper's L2
sector-access model, and the quantity sawtooth reduces structurally (the
pass-boundary block is always elided).

It also models a hypothetical shared buffer of configurable size between the
DMA engine and HBM (CMEM on v4, or simply "what if TPUs had a GB10-style
LLC") via the LRU simulator, so the paper's GB10 findings and the TPU
structural gain are reported side by side in benchmarks/kernel_bench.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.schedule import Order

__all__ = ["FlashGridSpec", "pipeline_traffic", "TrafficReport"]


@dataclasses.dataclass(frozen=True)
class FlashGridSpec:
    """Static description of one flash_attention_fwd launch (one bh slice)."""

    seq_q: int
    seq_kv: int
    n_groups: int = 1          # GQA G (q tiles folded per kv head)
    head_dim: int = 128
    q_block: int = 256
    kv_block: int = 256
    elem_bytes: int = 2
    causal: bool = False
    window: Optional[int] = None

    @property
    def nq(self) -> int:
        return -(-self.seq_q // self.q_block)

    @property
    def nkv(self) -> int:
        return -(-self.seq_kv // self.kv_block)


@dataclasses.dataclass
class TrafficReport:
    q_bytes: int = 0
    kv_bytes: int = 0
    out_bytes: int = 0
    elided_kv_fetches: int = 0
    total_kv_fetches: int = 0

    @property
    def total_bytes(self) -> int:
        return self.q_bytes + self.kv_bytes + self.out_bytes


def _kv_bounds_host(spec: FlashGridSpec, i: int) -> tuple[int, int]:
    q_tile = i % spec.nq
    if spec.causal:
        last_row = q_tile * spec.q_block + (spec.q_block - 1)
        hi = min(spec.nkv - 1, last_row // spec.kv_block)
    else:
        hi = spec.nkv - 1
    if spec.window is not None:
        lo = max(q_tile * spec.q_block - (spec.window - 1), 0) // spec.kv_block
    else:
        lo = 0
    return lo, hi


def _kv_block_host(spec: FlashGridSpec, order: Order, i: int, j: int) -> int:
    lo, hi = _kv_bounds_host(spec, i)
    jc = min(j, hi - lo)
    return (lo + jc) if (order is Order.CYCLIC or i % 2 == 0) else (hi - jc)


def pipeline_traffic(spec: FlashGridSpec, order: Order | str) -> TrafficReport:
    """Count HBM bytes fetched under Pallas consecutive-revisit elision."""
    order = Order.parse(order)
    rep = TrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes  # K and V
    last_q = None
    last_kv = None
    n_rows = spec.n_groups * spec.nq
    for i in range(n_rows):
        if last_q != i:
            rep.q_bytes += q_tile_bytes
            rep.out_bytes += q_tile_bytes  # O written once per tile
            last_q = i
        for j in range(spec.nkv):
            jj = _kv_block_host(spec, order, i, j)
            rep.total_kv_fetches += 1
            if last_kv == jj:
                rep.elided_kv_fetches += 1
            else:
                rep.kv_bytes += kv_tile_bytes
                last_kv = jj
    return rep
