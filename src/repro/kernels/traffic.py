"""HBM->VMEM traffic model for the Pallas flash kernels (TPU analogue of §3.2).

The Pallas TPU pipeline elides the copy for an operand whose block index is
unchanged between consecutive grid steps ("revisiting"). This module replays
the kernel grids host-side with the exact index_map arithmetic and counts
fetched bytes per operand — the TPU-native equivalent of the paper's L2
sector-access model, and the quantity sawtooth reduces structurally (the
pass-boundary block is always elided).

Backward grids: the dQ kernel reuses the forward grid (KV streamed), so its
traffic is the forward replay with the extra dO/lse/delta reads and the dQ
write. The dK/dV kernel runs the *transposed* grid — each KV tile resident,
the Q-side operands streamed — so the cyclic reuse pathology moves to the
Q/dO stream (``bwd_dkv_traffic``); ``bwd_dkv_llc_model`` additionally plays
the transposed wavefront (``core.schedule.BwdKVSchedule``) through the LRU
simulator with a finite shared buffer (CMEM on v4, or "what if TPUs had a
GB10-style LLC"), which is where the paper-style ~50% non-compulsory miss
reduction shows up and what the ≥30% acceptance test asserts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.schedule import Order, bwd_kv_schedule, q_tile_bounds_for

__all__ = [
    "FlashGridSpec",
    "pipeline_traffic",
    "TrafficReport",
    "BwdTrafficReport",
    "bwd_dq_traffic",
    "bwd_dkv_traffic",
    "bwd_dkv_llc_model",
]


@dataclasses.dataclass(frozen=True)
class FlashGridSpec:
    """Static description of one flash_attention_fwd launch (one bh slice)."""

    seq_q: int
    seq_kv: int
    n_groups: int = 1          # GQA G (q tiles folded per kv head)
    head_dim: int = 128
    q_block: int = 256
    kv_block: int = 256
    elem_bytes: int = 2
    causal: bool = False
    window: Optional[int] = None

    @property
    def nq(self) -> int:
        return -(-self.seq_q // self.q_block)

    @property
    def nkv(self) -> int:
        return -(-self.seq_kv // self.kv_block)


@dataclasses.dataclass
class TrafficReport:
    q_bytes: int = 0
    kv_bytes: int = 0
    out_bytes: int = 0
    elided_kv_fetches: int = 0
    total_kv_fetches: int = 0

    @property
    def total_bytes(self) -> int:
        return self.q_bytes + self.kv_bytes + self.out_bytes


def _kv_bounds_host(spec: FlashGridSpec, i: int) -> tuple[int, int]:
    q_tile = i % spec.nq
    if spec.causal:
        last_row = q_tile * spec.q_block + (spec.q_block - 1)
        hi = min(spec.nkv - 1, last_row // spec.kv_block)
    else:
        hi = spec.nkv - 1
    if spec.window is not None:
        lo = max(q_tile * spec.q_block - (spec.window - 1), 0) // spec.kv_block
    else:
        lo = 0
    return lo, hi


def _kv_block_host(spec: FlashGridSpec, order: Order, i: int, j: int) -> int:
    lo, hi = _kv_bounds_host(spec, i)
    jc = min(j, hi - lo)
    return (lo + jc) if (order is Order.CYCLIC or i % 2 == 0) else (hi - jc)


def pipeline_traffic(spec: FlashGridSpec, order: Order | str) -> TrafficReport:
    """Count HBM bytes fetched under Pallas consecutive-revisit elision."""
    order = Order.parse(order)
    rep = TrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes  # K and V
    last_q = None
    last_kv = None
    n_rows = spec.n_groups * spec.nq
    for i in range(n_rows):
        if last_q != i:
            rep.q_bytes += q_tile_bytes
            rep.out_bytes += q_tile_bytes  # O written once per tile
            last_q = i
        for j in range(spec.nkv):
            jj = _kv_block_host(spec, order, i, j)
            rep.total_kv_fetches += 1
            if last_kv == jj:
                rep.elided_kv_fetches += 1
            else:
                rep.kv_bytes += kv_tile_bytes
                last_kv = jj
    return rep


# --------------------------------------------------------------------------
# backward grids
# --------------------------------------------------------------------------

# lse and delta are f32 per-row vectors, but the kernels stream them
# lane-replicated as (q_block, 128) f32 tiles (the upstream JAX TPU
# flash-bwd residual layout — Mosaic has no cheap lane->sublane broadcast),
# so the model counts the replicated bytes actually DMA'd.
LSE_BYTES = 4
RESIDUAL_LANES = 128


@dataclasses.dataclass
class BwdTrafficReport:
    """Byte counts for one backward grid (roles named, not Q/KV-fixed)."""

    resident_bytes: int = 0    # operands fetched once per resident tile
    stream_bytes: int = 0      # the streamed operand bundle (non-elided)
    write_bytes: int = 0       # gradient tiles written
    elided_stream_fetches: int = 0
    total_stream_fetches: int = 0

    @property
    def total_bytes(self) -> int:
        return self.resident_bytes + self.stream_bytes + self.write_bytes


def _row_vec_bytes(spec: FlashGridSpec) -> int:
    return spec.q_block * RESIDUAL_LANES * LSE_BYTES


def bwd_dq_traffic(spec: FlashGridSpec, order: Order | str) -> BwdTrafficReport:
    """dQ kernel traffic: the forward grid (Q-side resident, K/V streamed).

    Per resident row: q + do + lse + delta fetched once, dq written once;
    K/V tiles stream with the same schedule/elision as the forward.
    """
    order = Order.parse(order)
    rep = BwdTrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes
    last_kv = None
    for i in range(spec.n_groups * spec.nq):
        rep.resident_bytes += 2 * q_tile_bytes + 2 * _row_vec_bytes(spec)
        rep.write_bytes += q_tile_bytes
        for j in range(spec.nkv):
            jj = _kv_block_host(spec, order, i, j)
            rep.total_stream_fetches += 1
            if last_kv == jj:
                rep.elided_stream_fetches += 1
            else:
                rep.stream_bytes += kv_tile_bytes
                last_kv = jj
    return rep


def bwd_dkv_traffic(spec: FlashGridSpec, order: Order | str) -> BwdTrafficReport:
    """dK/dV kernel traffic: the transposed grid (KV resident, Q streamed).

    Each resident KV tile streams one linearized sweep — all GQA groups
    over the trimmed Q range — of q + do + lse + delta bundles; K/V are
    fetched and dK/dV written once per KV tile. Sawtooth reverses the whole
    sweep on odd resident counters (``_stream_index`` in
    kernels/flash_attention.py), so the sweep-boundary bundle is elided at
    every KV-tile transition, GQA included.
    """
    order = Order.parse(order)
    rep = BwdTrafficReport()
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = 2 * spec.kv_block * spec.head_dim * spec.elem_bytes
    stream_bytes = 2 * q_tile_bytes + 2 * _row_vec_bytes(spec)  # q+do+lse+delta
    nq = spec.nq
    g = spec.n_groups
    last_stream = None
    for jkv in range(spec.nkv):
        rep.resident_bytes += kv_tile_bytes
        rep.write_bytes += kv_tile_bytes
        lo, hi = q_tile_bounds_for(
            jkv, nq,
            causal=spec.causal, window=spec.window,
            q_block=spec.q_block, kv_block=spec.kv_block,
        )
        n = hi - lo + 1
        total = g * n
        for u in range(total):
            uu = (total - 1) - u if (order is Order.SAWTOOTH and jkv % 2 == 1) else u
            key = (uu // n, lo + uu % n)  # (group, q tile)
            rep.total_stream_fetches += 1
            if last_stream == key:
                rep.elided_stream_fetches += 1
            else:
                rep.stream_bytes += stream_bytes
                last_stream = key
    return rep


def bwd_dkv_llc_model(
    spec: FlashGridSpec,
    order: Order | str,
    *,
    n_workers: int = 4,
    capacity_frac: float = 0.5,
):
    """LRU shared-buffer model of the dK/dV wavefront (paper §3.3/§4.2 shape).

    Plays the transposed wavefront trace through an LRU whose capacity is
    ``capacity_frac`` of the distinct streamed Q-side bytes — the regime
    where cyclic traversal thrashes (reuse distance = the whole Q stream)
    and sawtooth halves the non-compulsory misses. Returns a
    ``cache_sim.SimResult`` in bytes.
    """
    from repro.core.cache_sim import simulate_trace  # lazy: avoid import cycle

    sched = bwd_kv_schedule(
        order, spec.nq, spec.nkv,
        causal=spec.causal, window=spec.window,
        q_block=spec.q_block, kv_block=spec.kv_block,
    )
    q_tile_bytes = spec.q_block * spec.head_dim * spec.elem_bytes
    kv_tile_bytes = spec.kv_block * spec.head_dim * spec.elem_bytes
    weights = {
        "Q": q_tile_bytes,
        "dO": q_tile_bytes,
        "K": kv_tile_bytes,
        "V": kv_tile_bytes,
    }
    capacity = capacity_frac * 2 * spec.nq * q_tile_bytes  # frac of Q+dO stream
    # dK/dV are streaming stores (written once, never re-read) — they bypass
    # the buffer, like the paper's L2 *read* sector model.
    trace = (
        ((tensor, tile), weights[tensor])
        for tensor, tile in sched.flat_trace(n_workers)
        if tensor in weights
    )
    return simulate_trace(trace, capacity)
