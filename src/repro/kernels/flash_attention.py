"""Pallas TPU flash-attention forward kernel with schedulable KV traversal.

The paper's Sawtooth Wavefront Reordering (Alg. 4) is expressed *entirely in
the BlockSpec index_map*: the kernel body is identical for cyclic and
sawtooth. On TPU the schedule controls the HBM->VMEM DMA stream of the
Pallas software pipeline; consecutive grid steps that map to the same block
elide the copy, so the sawtooth boundary block (last block of pass i ==
first block of pass i+1) is fetched once instead of twice, and the mean HBM
reuse distance of the KV stream halves (see kernels/traffic.py for the
counting model and DESIGN.md §2 for the GB10->TPU adaptation).

Dataflow is the paper's split-Q (Alg. 1): the Q tile is resident (one per
grid row), K/V tiles stream. Causal and sliding-window ranges are *clamped
in the index_map* so out-of-range steps re-map to a boundary block (elided
fetch) with compute skipped — the TPU analogue of causal grid trimming.

Layout: q (B, Sq, Hq, D), k/v (B, Skv, Hkv, D), GQA folded by stacking the
``G = Hq // Hkv`` query groups along the row axis per KV head.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # jax >= 0.7 name, with fallback for older spellings
    from jax.experimental.pallas import tpu as pltpu

    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.core.schedule import Order

__all__ = ["flash_attention_fwd", "MASK_VALUE"]

MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)
LANES = 128


def _kv_bounds(i, *, nq, nkv, q_block, kv_block, causal, window):
    """Inclusive [lo, hi] KV-block range visible to q-tile row ``i``.

    ``i`` indexes the G-folded q tiles; the sequence tile is ``i % nq``.
    Returns traced int32 scalars.
    """
    q_tile = jax.lax.rem(i, nq)
    if causal:
        last_row = q_tile * q_block + (q_block - 1)
        hi = jnp.minimum(nkv - 1, last_row // kv_block)
    else:
        hi = jnp.int32(nkv - 1)
    if window is not None:
        first_visible = jnp.maximum(q_tile * q_block - (window - 1), 0)
        lo = first_visible // kv_block
    else:
        lo = jnp.int32(0)
    return lo, hi


def _kv_block_index(order: Order, i, j, *, nq, nkv, q_block, kv_block, causal, window):
    """KV block fetched at grid step (i, j) plus the compute-valid predicate."""
    lo, hi = _kv_bounds(
        i, nq=nq, nkv=nkv, q_block=q_block, kv_block=kv_block, causal=causal, window=window
    )
    steps = hi - lo + 1
    jc = jnp.minimum(j, steps - 1)  # clamp out-of-range steps to boundary
    fwd = lo + jc
    if order is Order.SAWTOOTH:
        bwd = hi - jc
        jj = jax.lax.select(jax.lax.rem(i, 2) == 0, fwd, bwd)
    else:
        jj = fwd
    valid = j < steps
    return jj, valid


def _fwd_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    order: Order,
    nq: int,
    nkv: int,
    q_block: int,
    kv_block: int,
    causal: bool,
    window: Optional[int],
    kv_len: int,
    scale: float,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    jj, valid = _kv_block_index(
        order,
        i,
        j,
        nq=nq,
        nkv=nkv,
        q_block=q_block,
        kv_block=kv_block,
        causal=causal,
        window=window,
    )

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(valid)
    def _compute():
        q = q_ref[0]  # (qb, D)
        k = k_ref[0]  # (kb, D)
        v = v_ref[0]
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (qb, kb)

        q_tile = jax.lax.rem(i, nq)
        rows = (
            jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
            + q_tile * q_block
        )
        cols = (
            jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1) + jj * kv_block
        )
        ok = cols < kv_len
        if causal:
            ok &= cols <= rows
        if window is not None:
            ok &= cols > rows - window
        s = jnp.where(ok, s, MASK_VALUE)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # Explicit mask on p: with sawtooth-causal the *diagonal* block is
        # visited first on odd passes, where early rows have no valid columns
        # yet — exp(mask - mask) would poison l without this.
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)  # (qb, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == nkv - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked (padding) rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=(
        "order",
        "causal",
        "window",
        "scale",
        "q_block",
        "kv_block",
        "interpret",
    ),
)
def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    order: Order | str = Order.SAWTOOTH,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 256,
    kv_block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Forward flash attention via pl.pallas_call. See module docstring."""
    order = Order.parse(order)
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}")
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)

    q_block = min(q_block, max(8, 1 << (sq - 1).bit_length()))
    kv_block = min(kv_block, max(128, 1 << (skv - 1).bit_length()))

    # --- fold GQA: (B, Sq, Hkv, G, D) -> rows grouped per kv head -----------
    qf = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Sq,D)
    qf = _pad_axis(qf, 3, q_block)
    sq_p = qf.shape[3]
    nq = sq_p // q_block
    qf = qf.reshape(b * hkv, g * sq_p, d)
    qf = _pad_axis(qf, 2, LANES)

    kf = _pad_axis(k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), 1, kv_block)
    vf = _pad_axis(v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), 1, kv_block)
    kf = _pad_axis(kf, 2, LANES)
    vf = _pad_axis(vf, 2, LANES)
    skv_p = kf.shape[1]
    nkv = skv_p // kv_block
    dp = kf.shape[2]

    kv_map_kwargs = dict(
        nq=nq, nkv=nkv, q_block=q_block, kv_block=kv_block, causal=causal, window=window
    )

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        jj, _ = _kv_block_index(order, i, j, **kv_map_kwargs)
        return (bh, jj, 0)

    kernel = functools.partial(
        _fwd_kernel,
        order=order,
        kv_len=skv,
        scale=scale_,
        **kv_map_kwargs,
    )

    grid = (b * hkv, g * nq, nkv)
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, dp), q_map),
            pl.BlockSpec((1, kv_block, dp), kv_map),
            pl.BlockSpec((1, kv_block, dp), kv_map),
        ],
        out_specs=pl.BlockSpec((1, q_block, dp), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g * sq_p, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, LANES), jnp.float32),
            pltpu.VMEM((q_block, LANES), jnp.float32),
            pltpu.VMEM((q_block, dp), jnp.float32),
        ],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(qf, kf, vf)

    out = out.reshape(b, hkv, g, sq_p, dp)[:, :, :, :sq, :d]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
