"""Pallas TPU flash-attention kernels with schedulable KV traversal.

The paper's Sawtooth Wavefront Reordering (Alg. 4) is expressed *entirely in
the BlockSpec index_map*: the kernel bodies are identical for every
traversal order. The index arithmetic itself is not owned here — each
launch compiles a ``repro.core.schedule.Traversal`` and consumes its traced
lowerings (``kv_block_index`` for the forward/dQ grid,
``stream_block_index`` for the transposed dK/dV grid), so the kernels, the
blockwise XLA path, the traffic models, and the cache simulator all share
one source of truth for the order (``block_snake`` included). On TPU the
traversal controls the HBM->VMEM DMA stream of the Pallas software
pipeline; consecutive grid steps that map to the same block elide the copy,
so the sawtooth boundary block (last block of pass i == first block of pass
i+1) is fetched once instead of twice (see kernels/traffic.py for the
counting model and DESIGN.md §2/§3 for the GB10->TPU adaptation and the IR).

Forward dataflow is the paper's split-Q (Alg. 1): the Q tile is resident
(one per grid row), K/V tiles stream. Causal and sliding-window ranges are
*clamped in the index_map* so out-of-range steps re-map to a boundary block
(elided fetch) with compute skipped — the TPU analogue of causal grid
trimming.

The fused backward (FlashAttention-2 style, cf. the CUTLASS Hopper case
study) is three kernels consuming the forward's saved ``(o, lse)``:

  * ``_delta_kernel``      — delta = rowsum(dO * O), per-row preprocess;
  * ``_dq_kernel``         — the forward grid (Q resident, KV streamed);
  * ``_dkv_kernel``        — the *transposed* grid: each KV tile is
    resident (accumulating dK/dV) and the Q-side operands (Q, dO, lse,
    delta) stream — exactly the cyclic-traversal reuse pathology the
    reordering targets, now on the Q stream. The whole per-resident stream
    (all GQA groups over the trimmed Q range) is one sweep, reordered as
    one range with parity keyed on the resident KV-tile counter.
    ``core.schedule.BwdKVSchedule`` is the host-side (G=1) model.

Layout: q (B, Sq, Hq, D), k/v (B, Skv, Hkv, D), GQA folded by stacking the
``G = Hq // Hkv`` query groups along the row axis per KV head.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # jax >= 0.7 name, with fallback for older spellings
    from jax.experimental.pallas import tpu as pltpu

    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

from repro.core.schedule import Order, Traversal

__all__ = ["flash_attention_fwd", "flash_attention_bwd", "MASK_VALUE"]

MASK_VALUE = -0.7 * float(np.finfo(np.float32).max)
LANES = 128


def _tile_mask(q_tile, jj, *, q_block, kv_block, causal, window, kv_len):
    """(q_block, kv_block) visibility mask for tile pair (q_tile, jj)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0) + q_tile * q_block
    cols = jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1) + jj * kv_block
    ok = cols < kv_len
    if causal:
        ok &= cols <= rows
    if window is not None:
        ok &= cols > rows - window
    return ok


def _tr_mask_kwargs(tr: Traversal, kv_len: int) -> dict:
    return dict(
        q_block=tr.q_block,
        kv_block=tr.kv_block,
        causal=tr.causal,
        window=tr.window,
        kv_len=kv_len,
    )


# --------------------------------------------------------------------------
# layout folding (GQA groups stacked along the row axis per KV head)
# --------------------------------------------------------------------------


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _fold_q(x: jax.Array, hkv: int, g: int, q_block: int):
    """(B, Sq, Hq, D) -> ((B*Hkv, G*Sq_p, Dp), Sq_p)."""
    b, sq, _, d = x.shape
    xf = x.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Sq,D)
    xf = _pad_axis(xf, 3, q_block)
    sq_p = xf.shape[3]
    xf = xf.reshape(b * hkv, g * sq_p, d)
    return _pad_axis(xf, 2, LANES), sq_p


def _fold_kv(x: jax.Array, kv_block: int) -> jax.Array:
    """(B, Skv, Hkv, D) -> (B*Hkv, Skv_p, Dp)."""
    b, skv, hkv, d = x.shape
    xf = _pad_axis(x.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d), 1, kv_block)
    return _pad_axis(xf, 2, LANES)


def _fold_rows(x: jax.Array, hkv: int, g: int, q_block: int) -> jax.Array:
    """Per-row vector (B, Sq, Hq) -> (B*Hkv, G*Sq_p), zero-padded."""
    b, sq, _ = x.shape
    xf = x.reshape(b, sq, hkv, g).transpose(0, 2, 3, 1)  # (B,Hkv,G,Sq)
    xf = _pad_axis(xf, 3, q_block)
    sq_p = xf.shape[3]
    return xf.reshape(b * hkv, g * sq_p)


def _clamp_blocks(q_block: int, kv_block: int, sq: int, skv: int):
    q_block = min(q_block, max(8, 1 << (sq - 1).bit_length()))
    kv_block = min(kv_block, max(128, 1 << (skv - 1).bit_length()))
    return q_block, kv_block


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _fwd_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *rest,
    tr: Traversal,
    kv_len: int,
    scale: float,
    emit_lse: bool,
):
    lse_ref = rest[0] if emit_lse else None
    m_scr, l_scr, acc_scr = rest[-3:]
    i = pl.program_id(1)
    j = pl.program_id(2)
    jj, valid = tr.kv_block_index(i, j)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(valid)
    def _compute():
        q = q_ref[0]  # (qb, D)
        k = k_ref[0]  # (kb, D)
        v = v_ref[0]
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (qb, kb)

        q_tile = jax.lax.rem(i, tr.n_q)
        ok = _tile_mask(q_tile, jj, **_tr_mask_kwargs(tr, kv_len))
        s = jnp.where(ok, s, MASK_VALUE)

        m_prev = m_scr[:, :1]
        l_prev = l_scr[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # Explicit mask on p: with a reversed-causal traversal the *diagonal*
        # block can be visited first on odd passes, where early rows have no
        # valid columns yet — exp(mask - mask) would poison l without this.
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)  # (qb, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype),
            v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == tr.n_kv - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked (padding) rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)
        if emit_lse:
            lse = m_scr[:, :1] + jnp.log(l)
            lse_ref[0] = jnp.broadcast_to(lse, lse_ref.shape[1:])


@functools.partial(
    jax.jit,
    static_argnames=(
        "order",
        "causal",
        "window",
        "scale",
        "q_block",
        "kv_block",
        "snake_group",
        "interpret",
        "return_lse",
    ),
)
def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    order: Order | str = Order.SAWTOOTH,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 256,
    kv_block: int = 256,
    snake_group: Optional[int] = None,
    interpret: bool = False,
    return_lse: bool = False,
) -> jax.Array:
    """Forward flash attention via pl.pallas_call. See module docstring.

    With ``return_lse=True`` returns ``(o, lse)``; lse is the per-row
    log-sum-exp of the scaled scores, shape (B, Sq, Hq) f32 — the residual
    the fused backward consumes instead of recomputing the forward.
    ``snake_group`` sizes the ``block_snake`` reversal window (KV tiles).
    """
    order = Order.parse(order)
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}")
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)
    q_block, kv_block = _clamp_blocks(q_block, kv_block, sq, skv)

    qf, sq_p = _fold_q(q, hkv, g, q_block)
    nq = sq_p // q_block
    kf = _fold_kv(k, kv_block)
    vf = _fold_kv(v, kv_block)
    skv_p = kf.shape[1]
    nkv = skv_p // kv_block
    dp = kf.shape[2]

    tr = Traversal(
        order=order,
        n_q=nq,
        n_kv=nkv,
        causal=causal,
        window=window,
        q_block=q_block,
        kv_block=kv_block,
        n_groups=g,
        snake_group=snake_group,
    )

    def q_map(bh, i, j):
        return (bh, i, 0)

    def kv_map(bh, i, j):
        jj, _ = tr.kv_block_index(i, j)
        return (bh, jj, 0)

    kernel = functools.partial(
        _fwd_kernel, tr=tr, kv_len=skv, scale=scale_, emit_lse=return_lse
    )

    grid = (b * hkv, g * nq, nkv)
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        )

    out_shape = [jax.ShapeDtypeStruct((b * hkv, g * sq_p, dp), q.dtype)]
    out_specs = [pl.BlockSpec((1, q_block, dp), q_map)]
    if return_lse:
        out_shape.append(jax.ShapeDtypeStruct((b * hkv, g * sq_p, LANES), jnp.float32))
        out_specs.append(pl.BlockSpec((1, q_block, LANES), q_map))

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, dp), q_map),
            pl.BlockSpec((1, kv_block, dp), kv_map),
            pl.BlockSpec((1, kv_block, dp), kv_map),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((q_block, LANES), jnp.float32),
            pltpu.VMEM((q_block, LANES), jnp.float32),
            pltpu.VMEM((q_block, dp), jnp.float32),
        ],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(qf, kf, vf)

    out = outs[0].reshape(b, hkv, g, sq_p, dp)[:, :, :, :sq, :d]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    if not return_lse:
        return out
    lse = outs[1][:, :, 0].reshape(b, hkv, g, sq_p)[:, :, :, :sq]
    lse = lse.transpose(0, 3, 1, 2).reshape(b, sq, hq)
    return out, lse


# --------------------------------------------------------------------------
# backward: delta preprocess
# --------------------------------------------------------------------------


def _delta_kernel(o_ref, do_ref, delta_ref):
    """delta = rowsum(dO * O): the softmax-grad dot the dQ/dKV kernels reuse."""
    prod = o_ref[0].astype(jnp.float32) * do_ref[0].astype(jnp.float32)
    delta_ref[0] = jnp.broadcast_to(
        jnp.sum(prod, axis=-1, keepdims=True), delta_ref.shape[1:]
    )


# --------------------------------------------------------------------------
# backward: dQ (forward grid — Q resident, KV streamed)
# --------------------------------------------------------------------------


def _dq_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dq_ref,
    dq_scr,
    *,
    tr: Traversal,
    kv_len: int,
    scale: float,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    jj, valid = tr.kv_block_index(i, j)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(valid)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        lse_row = lse_ref[0][:, :1]  # (qb, 1)
        delta_row = delta_ref[0][:, :1]
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )
        q_tile = jax.lax.rem(i, tr.n_q)
        ok = _tile_mask(q_tile, jj, **_tr_mask_kwargs(tr, kv_len))
        # exp(s - lse) is the *normalized* P (lse = m + log l) — masked
        # explicitly so padded/fully-masked rows can't poison the grads.
        p = jnp.where(ok, jnp.exp(s - lse_row), 0.0)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta_row) * scale
        dq_scr[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == tr.n_kv - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


# --------------------------------------------------------------------------
# backward: dK/dV (transposed grid — KV resident, Q/dO streamed)
# --------------------------------------------------------------------------


def _dkv_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dk_ref,
    dv_ref,
    dk_scr,
    dv_scr,
    *,
    tr: Traversal,
    kv_len: int,
    scale: float,
):
    jkv = pl.program_id(1)
    u = pl.program_id(2)
    _, qi, valid = tr.stream_block_index(jkv, u)

    @pl.when(u == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(valid)
    def _compute():
        q = q_ref[0]  # (qb, D)
        k = k_ref[0]  # (kb, D)
        v = v_ref[0]
        do = do_ref[0]
        lse_row = lse_ref[0][:, :1]
        delta_row = delta_ref[0][:, :1]
        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )
            * scale
        )  # (qb, kb)
        ok = _tile_mask(qi, jkv, **_tr_mask_kwargs(tr, kv_len))
        p = jnp.where(ok, jnp.exp(s - lse_row), 0.0)
        # dV += P^T @ dO  (contract the q rows)
        dv_scr[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta_row) * scale
        # dK += dS^T @ Q
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(u == tr.grid_rows - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "order",
        "causal",
        "window",
        "scale",
        "q_block",
        "kv_block",
        "snake_group",
        "interpret",
    ),
)
def flash_attention_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    *,
    order: Order | str = Order.SAWTOOTH,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 256,
    kv_block: int = 256,
    snake_group: Optional[int] = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Pallas flash backward from saved ``(o, lse)`` residuals.

    Launches the delta preprocess, the dQ kernel (forward grid) and the
    dK/dV kernel (transposed grid), all traversed per the compiled
    ``Traversal``. No forward recompute: the normalized probabilities are
    recovered as ``exp(s - lse)``. Block sizes may differ from the
    forward's (they are autotuned separately — benchmarks/hillclimb.py).
    """
    order = Order.parse(order)
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}")
    g = hq // hkv
    scale_ = float(d**-0.5 if scale is None else scale)
    q_block, kv_block = _clamp_blocks(q_block, kv_block, sq, skv)

    qf, sq_p = _fold_q(q, hkv, g, q_block)
    dof, _ = _fold_q(do.astype(q.dtype), hkv, g, q_block)
    of, _ = _fold_q(o, hkv, g, q_block)
    kf = _fold_kv(k, kv_block)
    vf = _fold_kv(v, kv_block)
    nq = sq_p // q_block
    skv_p = kf.shape[1]
    nkv = skv_p // kv_block
    dp = kf.shape[2]

    tr = Traversal(
        order=order,
        n_q=nq,
        n_kv=nkv,
        causal=causal,
        window=window,
        q_block=q_block,
        kv_block=kv_block,
        n_groups=g,
        snake_group=snake_group,
    )

    # lse/delta stream lane-replicated as (q_block, LANES) f32 tiles — the
    # upstream JAX TPU flash-bwd residual layout: Mosaic has no cheap
    # lane->sublane broadcast, so replicating at materialization beats an
    # in-kernel transpose. kernels/traffic.py counts the replicated bytes.
    lse_f = _fold_rows(lse.astype(jnp.float32), hkv, g, q_block)
    lse_f = jnp.broadcast_to(lse_f[:, :, None], (b * hkv, g * sq_p, LANES))

    def row_map(bh, i):
        return (bh, i, 0)

    interp = {"interpret": interpret}
    if _CompilerParams is not None and not interpret:
        compiler3 = {
            "compiler_params": _CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")
            )
        }
    else:
        compiler3 = {}

    # ---- delta = rowsum(dO * O) ---------------------------------------------
    delta_f = pl.pallas_call(
        _delta_kernel,
        grid=(b * hkv, g * nq),
        in_specs=[
            pl.BlockSpec((1, q_block, dp), row_map),
            pl.BlockSpec((1, q_block, dp), row_map),
        ],
        out_specs=pl.BlockSpec((1, q_block, LANES), row_map),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g * sq_p, LANES), jnp.float32),
        **interp,
    )(of, dof)

    # ---- dQ: forward grid ----------------------------------------------------
    def q_map3(bh, i, j):
        return (bh, i, 0)

    def kv_map3(bh, i, j):
        jj, _ = tr.kv_block_index(i, j)
        return (bh, jj, 0)

    dqf = pl.pallas_call(
        functools.partial(_dq_kernel, tr=tr, kv_len=skv, scale=scale_),
        grid=(b * hkv, g * nq, nkv),
        in_specs=[
            pl.BlockSpec((1, q_block, dp), q_map3),
            pl.BlockSpec((1, kv_block, dp), kv_map3),
            pl.BlockSpec((1, kv_block, dp), kv_map3),
            pl.BlockSpec((1, q_block, dp), q_map3),
            pl.BlockSpec((1, q_block, LANES), q_map3),
            pl.BlockSpec((1, q_block, LANES), q_map3),
        ],
        out_specs=pl.BlockSpec((1, q_block, dp), q_map3),
        out_shape=jax.ShapeDtypeStruct((b * hkv, g * sq_p, dp), q.dtype),
        scratch_shapes=[pltpu.VMEM((q_block, dp), jnp.float32)],
        **interp,
        **compiler3,
    )(qf, kf, vf, dof, lse_f, delta_f)

    # ---- dK/dV: transposed grid ---------------------------------------------
    def stream_map(bh, jkv, u):
        gg, qi, _ = tr.stream_block_index(jkv, u)
        return (bh, gg * nq + qi, 0)

    def resident_map(bh, jkv, u):
        return (bh, jkv, 0)

    dkf, dvf = pl.pallas_call(
        functools.partial(_dkv_kernel, tr=tr, kv_len=skv, scale=scale_),
        grid=(b * hkv, nkv, g * nq),
        in_specs=[
            pl.BlockSpec((1, q_block, dp), stream_map),
            pl.BlockSpec((1, kv_block, dp), resident_map),
            pl.BlockSpec((1, kv_block, dp), resident_map),
            pl.BlockSpec((1, q_block, dp), stream_map),
            pl.BlockSpec((1, q_block, LANES), stream_map),
            pl.BlockSpec((1, q_block, LANES), stream_map),
        ],
        out_specs=[
            pl.BlockSpec((1, kv_block, dp), resident_map),
            pl.BlockSpec((1, kv_block, dp), resident_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * hkv, skv_p, dp), k.dtype),
            jax.ShapeDtypeStruct((b * hkv, skv_p, dp), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((kv_block, dp), jnp.float32),
            pltpu.VMEM((kv_block, dp), jnp.float32),
        ],
        **interp,
        **compiler3,
    )(qf, kf, vf, dof, lse_f, delta_f)

    dq = dqf.reshape(b, hkv, g, sq_p, dp)[:, :, :, :sq, :d]
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    dk = dkf.reshape(b, hkv, skv_p, dp)[:, :, :skv, :d].transpose(0, 2, 1, 3)
    dv = dvf.reshape(b, hkv, skv_p, dp)[:, :, :skv, :d].transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
