"""Public, differentiable, platform-dispatched kernel ops.

``attention`` / ``attention_decode`` are what the model layers call. Each op:

  * dispatches to the Pallas TPU kernel on TPU backends, the blockwise pure
    JAX path elsewhere (CPU dry-run / tests), or an explicit impl override
    ('pallas' | 'pallas_interpret' | 'xla' | 'jnp' | 'reference'),
  * carries the KV schedule (cyclic / sawtooth) through to whichever path,
  * is differentiable with a *fused* flash backward (DESIGN.md §7.5): the
    forward saves ``(o, lse)`` residuals and the backward dispatches to the
    Pallas backward kernels ('pallas' / 'pallas_interpret') or the fused
    blockwise JAX backward ('xla') — no forward recompute. ``impl='jnp'``
    keeps the old recompute-VJP path (differentiate through the blockwise
    forward) as the fallback; 'reference' recomputes through the
    full-materialization oracle (tiny shapes only).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import attention as core_attn
from repro.core.schedule import Order
from repro.kernels import ref as kref
from repro.kernels import flash_attention as kflash
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode_fwd
from repro.kernels.ssd import ssd_fwd

__all__ = ["attention", "attention_decode", "ssd", "default_impl"]

Impl = str  # 'auto' | 'pallas' | 'pallas_interpret' | 'xla' | 'jnp' | 'reference'

# Impls whose backward consumes (o, lse) residuals instead of recomputing.
_FUSED_BWD_IMPLS = ("pallas", "pallas_interpret", "xla")


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve(impl: Impl) -> str:
    return default_impl() if impl == "auto" else impl


def _fwd_dispatch(
    q, k, v, *, impl, order, causal, window, scale, q_block, kv_block, score_dtype,
    snake_group, return_lse=False,
):
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        return flash_attention_fwd(
            q,
            k,
            v,
            order=order,
            causal=causal,
            window=window,
            scale=scale,
            q_block=q_block,
            kv_block=kv_block,
            snake_group=snake_group,
            interpret=(impl == "pallas_interpret"),
            return_lse=return_lse,
        )
    if impl in ("xla", "jnp"):
        return core_attn.flash_attention(
            q,
            k,
            v,
            order=order,
            causal=causal,
            window=window,
            scale=scale,
            q_block=q_block,
            kv_block=kv_block,
            score_dtype=score_dtype,
            snake_group=snake_group,
            return_lse=return_lse,
        )
    if impl == "reference":
        out = kref.flash_attention_ref(
            q, k, v, causal=causal, window=window, scale=scale
        )
        assert not return_lse, "reference impl has no fused backward"
        return out
    raise ValueError(f"unknown attention impl: {impl!r}")


@functools.lru_cache(maxsize=None)
def _make_attention(
    impl, order, causal, window, scale, q_block, kv_block, score_dtype,
    bwd_q_block, bwd_kv_block, snake_group,
):
    """Build a custom_vjp attention fn for one static configuration."""

    cfg = dict(
        impl=impl,
        order=order,
        causal=causal,
        window=window,
        scale=scale,
        q_block=q_block,
        kv_block=kv_block,
        score_dtype=score_dtype,
        snake_group=snake_group,
    )
    bqb = bwd_q_block or q_block
    bkb = bwd_kv_block or kv_block

    def _recompute_fn(q, k, v):
        # The recompute fallback differentiates the blockwise JAX path
        # (order kept: the schedule is math-preserving, so grads match any
        # forward impl) — one extra attention pass per backward.
        return core_attn.flash_attention(
            q,
            k,
            v,
            order=order,
            causal=causal,
            window=window,
            scale=scale,
            q_block=q_block,
            kv_block=kv_block,
            score_dtype=score_dtype,
            snake_group=snake_group,
        )

    @jax.custom_vjp
    def attn(q, k, v):
        return _fwd_dispatch(q, k, v, **cfg)

    def fwd(q, k, v):
        r = _resolve(impl)
        if r in _FUSED_BWD_IMPLS:
            o, lse = _fwd_dispatch(q, k, v, **{**cfg, "impl": r}, return_lse=True)
            return o, (q, k, v, o, lse)
        return attn(q, k, v), (q, k, v, None, None)

    def bwd(res, g):
        q, k, v, o, lse = res
        r = _resolve(impl)
        if r in ("pallas", "pallas_interpret"):
            return kflash.flash_attention_bwd(
                q, k, v, o, lse, g,
                order=order,
                causal=causal,
                window=window,
                scale=scale,
                q_block=bqb,
                kv_block=bkb,
                snake_group=snake_group,
                interpret=(r == "pallas_interpret"),
            )
        if r == "xla":
            return core_attn.flash_attention_bwd(
                q, k, v, o, lse, g,
                order=order,
                causal=causal,
                window=window,
                scale=scale,
                q_block=bqb,
                kv_block=bkb,
                score_dtype=score_dtype,
                snake_group=snake_group,
            )
        if r == "reference":
            _, vjp = jax.vjp(
                lambda q_, k_, v_: kref.flash_attention_ref(
                    q_, k_, v_, causal=causal, window=window, scale=scale
                ),
                q, k, v,
            )
            return vjp(g)
        # 'jnp': memory-safe flash-style recompute (the pre-fused design).
        _, vjp = jax.vjp(_recompute_fn, q, k, v)
        return vjp(g)

    attn.defvjp(fwd, bwd)
    return attn


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    order: Order | str = Order.SAWTOOTH,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 256,
    kv_block: int = 256,
    impl: Impl = "auto",
    score_dtype: str = "float32",
    bwd_q_block: Optional[int] = None,
    bwd_kv_block: Optional[int] = None,
    snake_group: Optional[int] = None,
) -> jax.Array:
    """Flash attention, layout (B, S, H, D); GQA via Hq > Hkv.

    ``bwd_q_block`` / ``bwd_kv_block`` size the fused backward kernels'
    tiles (default: the forward blocks) — the backward's working set is
    larger (Q, dO, lse, delta stream against a resident dK/dV accumulator),
    so its optimum is usually smaller; benchmarks/hillclimb.py autotunes
    them separately. ``snake_group`` sizes the ``block_snake`` order's
    reversal window (KV tiles); ignored by the other orders.
    """
    order = Order.parse(order)
    fn = _make_attention(
        impl, order, causal, window, scale, q_block, kv_block, score_dtype,
        bwd_q_block, bwd_kv_block, snake_group,
    )
    return fn(q, k, v)


def attention_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len,
    *,
    order: Order | str = Order.CYCLIC,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    chunk: int = 512,
    impl: Impl = "auto",
    block_table: Optional[jax.Array] = None,
    q_lens: Optional[jax.Array] = None,
    snake_group: Optional[int] = None,
    order_group: Optional[jax.Array] = None,
) -> jax.Array:
    """Decode / ragged-chunk attention vs a KV cache. Not differentiated.

    ``block_table`` switches both backends to the paged layout: caches are
    shared (n_pages, page, Hkv, D) pools and pages are visited in schedule
    order through the table (sawtooth parity keyed per row on
    ``cache_len``). The paged layout is ragged: q may carry C > 1 chunk
    positions per row with per-row ``q_lens`` valid rows and causal masking
    inside the chunk — the serve engine's unified mixed step (decode rows
    at q_len 1 + chunked prefill rows) runs through exactly this call.
    ``order_group`` (paged only) overrides the static ``order`` with a
    traced effective reversal-group scalar
    (``core.schedule.resolve_order_group``) — both backends then compute
    the visit order from that operand, so the serve engine's online order
    adaptation switches traversal orders with zero recompiles.
    """
    order = Order.parse(order)
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        return flash_decode_fwd(
            q,
            k_cache,
            v_cache,
            cache_len,
            order=order,
            window=window,
            scale=scale,
            chunk=chunk,
            snake_group=snake_group,
            interpret=(impl == "pallas_interpret"),
            block_table=block_table,
            q_lens=q_lens,
            order_group=order_group,
        )
    if impl in ("xla", "reference"):
        return core_attn.decode_attention(
            q,
            k_cache,
            v_cache,
            cache_len,
            window=window,
            scale=scale,
            block_table=block_table,
            q_lens=q_lens,
            order=order,
            snake_group=snake_group,
            order_group=order_group,
        )
    raise ValueError(f"unknown decode impl: {impl!r}")


# --------------------------------------------------------------------------
# Mamba-2 SSD op (Pallas on TPU, chunked jnp elsewhere; bwd via jnp recompute)
# --------------------------------------------------------------------------


def _ssd_jnp(x, dt, a, b, c, init_state, chunk):
    from repro.models.ssm import ssd_chunked  # lazy: avoids import cycle

    return ssd_chunked(x, dt, a, b, c, chunk=chunk, init_state=init_state)


@functools.lru_cache(maxsize=None)
def _make_ssd(impl, chunk):
    def _dispatch(x, dt, a, b, c, init_state):
        r = _resolve(impl)
        if r in ("pallas", "pallas_interpret"):
            return ssd_fwd(
                x, dt, a, b, c, init_state=init_state, chunk=chunk,
                interpret=(r == "pallas_interpret"),
            )
        return _ssd_jnp(x, dt, a, b, c, init_state, chunk)

    @jax.custom_vjp
    def op(x, dt, a, b, c, init_state):
        return _dispatch(x, dt, a, b, c, init_state)

    def fwd(x, dt, a, b, c, init_state):
        return op(x, dt, a, b, c, init_state), (x, dt, a, b, c, init_state)

    def bwd(res, g):
        x, dt, a, b, c, init_state = res
        _, vjp = jax.vjp(
            lambda *args: _ssd_jnp(*args, chunk), x, dt, a, b, c, init_state
        )
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


def ssd(x, dt, a, b, c, *, init_state=None, chunk: int = 128, impl: Impl = "auto"):
    """Mamba-2 SSD scan: (y, final_state). Layouts as kernels.ref.ssd_ref."""
    if init_state is None:
        bsz, _, h, p = x.shape
        init_state = jnp.zeros((bsz, h, p, b.shape[-1]), jnp.float32)
    return _make_ssd(impl, chunk)(x, dt, a, b, c, init_state)
