"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Addresses the §Roofline finding that SSM train/prefill cells are
memory-bound on f32 chunk intermediates: the (c×c) decay/score matrices and
per-chunk states live in VMEM scratch and never touch HBM; only x/dt/B/C
chunks stream in and y streams out.

Grid: (B·H, n_chunks), chunk axis sequential — the running state is carried
in VMEM scratch across chunk steps (reset at chunk 0, emitted at the last).
B/C projections are shared across heads (Mamba-2 G=1), so their BlockSpec
index_map repeats the same (batch, chunk) block for all H heads of a batch —
consecutive grid steps then elide the fetch in the Pallas pipeline, the same
revisiting mechanism the sawtooth schedule exploits for attention
(DESIGN.md §2). Grid order (h outer would break this) is (b, h) flattened
with h fastest, giving H−1 elided B/C fetches per (batch, chunk).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
except ImportError:  # pragma: no cover
    pltpu = None
    _CompilerParams = None

__all__ = ["ssd_fwd"]


def _ssd_kernel(
    x_ref,      # (1, c, P)
    da_ref,     # (1, c)      dt * a  (<= 0)
    dt_ref,     # (1, c)
    b_ref,      # (1, c, N)
    c_ref,      # (1, c, N)
    init_ref,   # (1, P, N)
    y_ref,      # (1, c, P)  out
    s_out_ref,  # (1, P, N)  out (final state)
    state_scr,  # (P, N) f32
    *,
    n_chunks: int,
    chunk: int,
):
    z = pl.program_id(1)

    @pl.when(z == 0)
    def _init():
        state_scr[...] = init_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)        # (c, P)
    da = da_ref[0].astype(jnp.float32)      # (c,)
    dt = dt_ref[0].astype(jnp.float32)
    bm = b_ref[0].astype(jnp.float32)       # (c, N)
    cm = c_ref[0].astype(jnp.float32)

    cum = jnp.cumsum(da)                    # (c,)
    # intra-chunk: W[i,j] = (c_i . b_j) * exp(cum_i - cum_j) * dt_j,  j <= i
    diff = cum[:, None] - cum[None, :]
    tril = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    decay = jnp.where(tril, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, c)
    w = cb * decay * dt[None, :]
    y_intra = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, P)

    # inter-chunk: y_i += c_i . (exp(cum_i) * S_in)
    state = state_scr[...]
    c_scaled = cm * jnp.exp(cum)[:, None]   # (c, N)
    y_inter = jax.lax.dot_general(
        c_scaled, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (c, P)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S_out = exp(cum_last) S_in + sum_j dt_j e^{cum_last-cum_j} x_j b_j^T
    cum_last = cum[chunk - 1]
    coeff = (dt * jnp.exp(cum_last - cum))[:, None] * x  # (c, P)
    s_new = jnp.exp(cum_last) * state + jax.lax.dot_general(
        coeff, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (P, N)
    state_scr[...] = s_new

    @pl.when(z == n_chunks - 1)
    def _emit():
        s_out_ref[0] = s_new.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_fwd(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)   post-softplus
    a: jax.Array,    # (H,)        negative decay rates
    b: jax.Array,    # (B, S, N)
    c: jax.Array,    # (B, S, N)
    *,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Pallas SSD forward. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, max(8, 1 << (s - 1).bit_length()))
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nz = sp // chunk

    da = dt * a[None, None, :]                                  # (B, Sp, H)
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, sp, p)        # (BH, Sp, P)
    daf = da.transpose(0, 2, 1).reshape(bsz * h, sp)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, sp)
    init = (
        jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None else init_state
    ).reshape(bsz * h, p, n)

    kernel = functools.partial(_ssd_kernel, n_chunks=nz, chunk=chunk)
    compiler_params = None
    if _CompilerParams is not None and not interpret:
        compiler_params = _CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )

    def bh_map(bh, z):
        return (bh, z, 0)

    def seq_map(bh, z):
        return (bh, z)

    def bc_map(bh, z):
        return (bh // h, z, 0)  # B/C shared across heads: repeated -> elided

    def state_map(bh, z):
        return (bh, 0, 0)

    y, s_out = pl.pallas_call(
        kernel,
        grid=(bsz * h, nz),
        in_specs=[
            pl.BlockSpec((1, chunk, p), bh_map),
            pl.BlockSpec((1, chunk), seq_map),
            pl.BlockSpec((1, chunk), seq_map),
            pl.BlockSpec((1, chunk, n), bc_map),
            pl.BlockSpec((1, chunk, n), bc_map),
            pl.BlockSpec((1, p, n), state_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), bh_map),
            pl.BlockSpec((1, p, n), state_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, sp, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(xf, daf, dtf, b, c, init)

    y = y.reshape(bsz, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
    return y, s_out.reshape(bsz, h, p, n)
