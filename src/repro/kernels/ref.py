"""Pure-jnp oracles for every Pallas kernel in this package.

These are *independent* implementations (full materialization, no blocking)
used by the shape/dtype-sweep tests; the blockwise ``repro.core.attention``
path is itself validated against them.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.attention import decode_attention as _decode_full
from repro.core.attention import mha_reference

__all__ = ["flash_attention_ref", "decode_attention_ref", "ssd_ref"]


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Oracle for kernels.flash_attention. Layout (B, S, H, D)."""
    return mha_reference(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len,
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Oracle for kernels.flash_decode. q (B,1,Hq,D), caches (B,S,Hkv,D)."""
    return _decode_full(q, k_cache, v_cache, cache_len, window=window, scale=scale)


def ssd_ref(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    *,
    chunk: int = 64,  # unused; oracle is sequential
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the Mamba-2 SSD kernel: sequential selective-state recurrence.

    Shapes (SSD / Mamba-2, arXiv:2405.21060):
      x:  (B, S, H, P)   inputs (P = head dim)
      dt: (B, S, H)      per-head step sizes (post-softplus, >= 0)
      a:  (H,)           negative state decay rates (A = -exp(a_log) <= 0)
      b:  (B, S, N)      input projections  (shared across heads, G=1)
      c:  (B, S, N)      output projections
    Returns (y, final_state) with y (B,S,H,P), state (B,H,P,N).

    Recurrence per head h:  S_t = exp(dt_t * a_h) * S_{t-1} + dt_t * x_t b_t^T
                            y_t = S_t c_t
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(state, t):
        x_t, dt_t, b_t, c_t = t
        decay = jnp.exp(dt_t[..., None, None] * af[None, :, None, None])
        upd = (dt_t[..., None] * x_t)[..., :, None] * b_t[:, None, None, :]
        state = decay * state + upd  # (B,H,P,N)
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    state, ys = jax.lax.scan(step, init, xs)
    y = jnp.moveaxis(ys, 0, 1).transpose(0, 1, 2, 3)  # (B,S,H,P)
    return y.astype(x.dtype), state
