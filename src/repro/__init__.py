"""repro: Sawtooth Wavefront Reordering as a first-class feature of a
JAX/TPU training+serving framework. See DESIGN.md."""

__version__ = "1.0.0"
