"""repro: Sawtooth Wavefront Reordering as a first-class feature of a
JAX/TPU training+serving framework. See DESIGN.md."""

# Install jax forward-compat shims (no-ops on modern jax) before any
# submodule — or test code — touches the newer API surface.
from repro import _compat as _compat

_compat.install()

__version__ = "1.0.0"
