"""Trace-driven LRU cache simulator (tile granularity).

This is the measurement instrument standing in for `ncu` on hardware we do
not have: it replays the exact access stream a persistent-CTA flash-attention
kernel issues (paper Alg. 1+2+4) against an LRU cache of the GB10 L2's size
and reports hit/miss sector counts.

Granularity: one entry per (tensor, batch·head, tile) — all sectors of a tile
are touched together by the tiled kernel, so tile-granularity LRU is exact
for this workload up to boundary tiles. Sector weights preserve the paper's
counter units (`lts__t_sectors.sum`).

Validated against the paper:
  * cold-miss floor 16S            (§3.3, Fig 5)
  * divergence at KV ≈ cache size  (§3.3)
  * hit rate ≈ 1 − 1/N_SM          (§3.4, Fig 6)
  * sawtooth ≈ 50 % fewer non-compulsory misses (§4.2, Fig 8)
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Iterable, Iterator, Sequence

from repro.core import cache_model
from repro.core.cache_model import AttentionWorkload, HWConfig
from repro.core.schedule import (
    Order,
    kv_index_host,
    num_kv_tiles_for,
    step_page_visits,
)

__all__ = [
    "SimResult",
    "LRUCache",
    "simulate_trace",
    "attention_trace",
    "simulate_attention",
    "reuse_distances",
    "reuse_distance_stats",
    "reuse_distance_percentile",
    "slot_reuse_stats",
    "decode_page_trace",
    "simulate_paged_decode",
    "shared_prefix_decode_trace",
    "simulate_shared_prefix_decode",
]


@dataclasses.dataclass
class SimResult:
    accesses: float = 0.0      # sectors requested
    misses: float = 0.0        # sectors missed
    cold_misses: float = 0.0   # first-touch sectors (compulsory)

    @property
    def hits(self) -> float:
        return self.accesses - self.misses

    @property
    def hit_rate(self) -> float:
        return 0.0 if self.accesses == 0 else self.hits / self.accesses

    @property
    def non_compulsory_misses(self) -> float:
        return self.misses - self.cold_misses


class LRUCache:
    """Weighted-entry LRU. Entries carry a sector size; capacity in sectors."""

    def __init__(self, capacity_sectors: float):
        self.capacity = capacity_sectors
        self._entries: OrderedDict[tuple, float] = OrderedDict()
        self._used = 0.0
        self._seen: set[tuple] = set()

    def access(self, key: tuple, sectors: float, result: SimResult) -> bool:
        """Touch ``key``; returns True on hit. Updates ``result`` in place."""
        result.accesses += sectors
        entries = self._entries
        if key in entries:
            entries.move_to_end(key)
            return True
        result.misses += sectors
        if key not in self._seen:
            self._seen.add(key)
            result.cold_misses += sectors
        if sectors > self.capacity:
            return False  # un-cacheable entry: bypass
        entries[key] = sectors
        self._used += sectors
        while self._used > self.capacity:
            _, sz = entries.popitem(last=False)
            self._used -= sz
        return False


def simulate_trace(
    trace: Iterable[tuple[tuple, float]], capacity_sectors: float
) -> SimResult:
    """Replay (key, sectors) accesses through an LRU cache."""
    cache = LRUCache(capacity_sectors)
    result = SimResult()
    access = cache.access
    for key, sectors in trace:
        access(key, sectors, result)
    return result


def attention_trace(
    w: AttentionWorkload,
    hw: HWConfig,
    order: Order | str,
    n_workers: int,
    *,
    snake_group: int | None = None,
) -> Iterator[tuple[tuple, float]]:
    """Wavefront access trace for the full (batch × heads × tiles) problem.

    Work distribution follows paper Alg. 2: the global list of Q tiles (over
    batch·head·tile-index, batch/head-major as in the paper's linearised
    ``(Batch, Head, TileIndex)`` decoding) is claimed round-robin by
    ``n_workers`` persistent workers that progress in lock-step (§3.4's
    wavefront observation). Sawtooth parity is the *worker-local* iteration
    counter, exactly Alg. 4.

    Keys: ("Q"|"K"|"V"|"O", bh, tile).  K/V of one (b,h) are distinct tensors.
    """
    order = Order.parse(order)
    n_tiles = w.n_tiles
    spt = cache_model.sectors_per_tile(w, hw)
    bh_count = w.batch * w.heads
    total_q = bh_count * n_tiles

    # Worker w gets global q indices w, w+G, w+2G, ...
    n_workers = max(1, min(n_workers, total_q))
    positions = [0] * n_workers           # index into worker's assignment
    inner = [0] * n_workers               # inner kv step
    started = [False] * n_workers

    def q_of(worker: int, pos: int) -> int:
        return worker + pos * n_workers

    active = [q_of(wk, 0) < total_q for wk in range(n_workers)]
    while any(active):
        for wk in range(n_workers):
            if not active[wk]:
                continue
            gq = q_of(wk, positions[wk])
            bh, q_tile = divmod(gq, n_tiles)
            n_kv = num_kv_tiles_for(
                q_tile, n_tiles, causal=w.causal, q_block=w.tile, kv_block=w.tile
            )
            if not started[wk]:
                yield (("Q", bh, q_tile), spt)
                started[wk] = True
            j = inner[wk]
            kv = kv_index_host(order, positions[wk], j, n_kv, snake_group=snake_group)
            yield (("K", bh, kv), spt)
            yield (("V", bh, kv), spt)
            inner[wk] += 1
            if inner[wk] >= n_kv:
                yield (("O", bh, q_tile), spt)
                inner[wk] = 0
                started[wk] = False
                positions[wk] += 1
                if q_of(wk, positions[wk]) >= total_q:
                    active[wk] = False


def reuse_distances(keys: Iterable[tuple]) -> list[int]:
    """LRU stack distances of an access stream.

    For each access, the number of *distinct* keys touched since the
    previous access to the same key (0 = immediate re-touch). First-touch
    (compulsory) accesses carry no distance and are skipped. A stream's
    mean stack distance is the canonical locality figure: an LRU cache of
    capacity C hits exactly the accesses with distance < C.
    """
    stack: list[tuple] = []  # most-recent-first
    out: list[int] = []
    for key in keys:
        try:
            i = stack.index(key)
        except ValueError:
            stack.insert(0, key)
            continue
        out.append(i)
        del stack[i]
        stack.insert(0, key)
    return out


def reuse_distance_percentile(dists: Sequence[int], p: float) -> float:
    """Nearest-rank percentile of an LRU stack-distance list (0 if empty).

    ``p`` in [0, 100]. The p-th percentile distance is the smallest cache
    capacity (in entries) at which an LRU cache hits at least ``p`` percent
    of the stream's non-compulsory accesses — the operational reading that
    makes these percentiles an eviction-ranking signal."""
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile {p} outside [0, 100]")
    xs = sorted(dists)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, max(0, round(p / 100 * (len(xs) - 1))))
    return float(xs[i])


def reuse_distance_stats(dists: Sequence[int]) -> dict:
    """Summary statistics of a :func:`reuse_distances` output.

    Returns ``{"n", "mean", "p50", "p90", "max"}`` (zeros for an empty
    list). The mean stack distance is the canonical locality figure; the
    percentiles bound it from both sides (p50 <= mean is the skew check,
    p90/max expose the tail that a capacity-sized LRU actually misses).
    The tiered serve engine ranks spill victims by these stats instead of
    plain last-touch LRU: a slot whose page stream carries the largest
    reuse distances is the one whose pages an LLC-sized device tier was
    going to miss anyway, so it is the cheapest resident set to lose.
    """
    xs = list(dists)
    if not xs:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p90": 0.0, "max": 0}
    return {
        "n": len(xs),
        "mean": sum(xs) / len(xs),
        "p50": reuse_distance_percentile(xs, 50),
        "p90": reuse_distance_percentile(xs, 90),
        "max": max(xs),
    }


def slot_reuse_stats(
    order: Order | str,
    lens: Sequence[int],
    page: int,
    *,
    n_steps: int = 2,
    snake_group: int | None = None,
) -> list[dict]:
    """Per-slot :func:`reuse_distance_stats` over the interleaved decode
    page trace of all slots stepping together.

    Replays ``n_steps`` lock-step decode steps of rows with cache lengths
    ``lens`` (:func:`decode_page_trace`), splits the stream's stack
    distances by the slot that issued each access, and summarizes each
    slot's share. This is the tiered pool's spill-ranking signal: the trace
    is the measurement twin of the serve hot path, so a slot whose accesses
    land at the largest stack distances is the slot contributing least
    locality to the device tier — evicting (spilling) it first sacrifices
    the fewest would-have-hit residencies. Two steps are enough to expose
    every cross-step reuse pair; more steps only repeat the pattern.
    """
    trace = list(
        decode_page_trace(order, lens, n_steps, page, snake_group=snake_group)
    )
    # reuse_distances skips first touches; recompute with slot attribution.
    stack: list[tuple] = []
    per_slot: list[list[int]] = [[] for _ in lens]
    for key in trace:
        slot = key[1]
        try:
            i = stack.index(key)
        except ValueError:
            stack.insert(0, key)
            continue
        per_slot[slot].append(i)
        del stack[i]
        stack.insert(0, key)
    return [reuse_distance_stats(d) for d in per_slot]


def decode_page_trace(
    order: Order | str,
    lens: Sequence[int],
    n_steps: int,
    page: int,
    *,
    snake_group: int | None = None,
) -> Iterator[tuple]:
    """Page-granular access trace of a paged continuous-batching decode.

    Each decode step, every sequence streams all pages holding its current
    KV (K and V of page p are distinct pool entries), visiting them in
    schedule order with the *cache length* as the sawtooth parity driver —
    exactly what ``paged_decode_attention`` / ``paged_flash_decode_fwd``
    execute, so this trace is the measurement twin of the serving hot path.
    Sawtooth makes consecutive steps reverse direction: the tail pages of
    step t are re-touched first at t+1, halving the mean reuse distance vs
    a cyclic traversal that always restarts at page 0.

    Keys: ("K"|"V", seq, logical_page). Lengths grow by one per step.
    """
    order = Order.parse(order)
    cur = [int(l) for l in lens]
    for _ in range(n_steps):
        for s, length in enumerate(cur):
            n = max(1, -(-(length + 1) // page))  # incl. the token written now
            for j in range(n):
                # Parity matches the hot path exactly: the decode kernels are
                # called with cache_len = length + 1 (the just-written token
                # included), so that is the sawtooth driver here too.
                p = kv_index_host(order, length + 1, j, n, snake_group=snake_group)
                yield ("K", s, p)
                yield ("V", s, p)
            cur[s] = length + 1


def simulate_paged_decode(
    order: Order | str,
    lens: Sequence[int],
    n_steps: int,
    page: int,
    *,
    capacity_pages: float | None = None,
    snake_group: int | None = None,
) -> dict:
    """Replay a paged decode's page trace; report locality + LRU stats.

    Returns mean/max reuse (stack) distance over the page stream and, when
    ``capacity_pages`` is given, the LRU hit rate of a cache holding that
    many page entries. The reuse-distance delta between cyclic and sawtooth
    here is the serving-side analogue of the paper's prefill Fig. 8.
    """
    trace = list(decode_page_trace(order, lens, n_steps, page, snake_group=snake_group))
    dists = reuse_distances(trace)
    stats = {
        "accesses": len(trace),
        "mean_reuse_distance": (sum(dists) / len(dists)) if dists else 0.0,
        "max_reuse_distance": max(dists, default=0),
    }
    if capacity_pages is not None:
        res = simulate_trace(((k, 1.0) for k in trace), capacity_pages)
        stats["hit_rate"] = res.hit_rate
        stats["misses"] = res.misses
        stats["cold_misses"] = res.cold_misses
    return stats


def shared_prefix_decode_trace(
    order: Order | str,
    n_rows: int,
    prefix_pages: int,
    own_lens: Sequence[int],
    n_steps: int,
    page: int,
    *,
    shared: bool = True,
    snake_group: int | None = None,
) -> Iterator[tuple]:
    """Physical-page access trace of a mixed decode step stream whose rows
    share a prompt prefix.

    ``n_rows`` sequences each hold ``prefix_pages`` prompt pages plus their
    own suffix of ``own_lens[b]`` tokens (growing one per step). With
    ``shared=True`` the prefix pages are the *same physical pages* for
    every row (the ``serve.kv_pool`` hash-dedup layout); with False every
    row owns a private copy (the pre-sharing layout). Page walks follow the
    per-row ``Traversal`` (sawtooth parity keyed per row on the visited
    length) and rows interleave in lock-step via
    ``schedule.step_page_visits`` — the step-level shared-page visit order.

    Keys: ("K"|"V", physical_page). The reuse-distance delta between
    shared and unshared is the serving-side locality win of prefix dedup:
    a shared page is re-touched within ~2·n_rows accesses instead of once
    per row's full private walk.
    """
    order = Order.parse(order)
    if len(own_lens) != n_rows:
        raise ValueError(f"{n_rows} rows vs {len(own_lens)} own_lens")
    cur = [int(l) for l in own_lens]
    # Physical page ids: shared prefix pages 0..prefix_pages-1 (or a private
    # copy per row), then per-row suffix pages.
    def phys(row: int, logical: int) -> int:
        if logical < prefix_pages:
            return logical if shared else row * 10_000 + logical
        return 1_000_000 + row * 10_000 + logical
    for _ in range(n_steps):
        row_pages = []
        parities = []
        for b in range(n_rows):
            length = prefix_pages * page + cur[b] + 1  # incl. token written now
            n = max(1, -(-length // page))
            row_pages.append([phys(b, j) for j in range(n)])
            parities.append(length)
        for b, pid in step_page_visits(
            order, row_pages, parities, snake_group=snake_group
        ):
            yield ("K", pid)
            yield ("V", pid)
        cur = [l + 1 for l in cur]


def simulate_shared_prefix_decode(
    order: Order | str,
    n_rows: int,
    prefix_pages: int,
    own_lens: Sequence[int],
    n_steps: int,
    page: int,
    *,
    shared: bool = True,
    capacity_pages: float | None = None,
    snake_group: int | None = None,
) -> dict:
    """Replay a shared-prefix mixed decode stream; report locality + LRU
    stats (same schema as :func:`simulate_paged_decode`). Comparing
    ``shared=True`` vs ``False`` quantifies the cross-row LLC reuse that
    copy-on-write page dedup creates; comparing orders shows the paper's
    sawtooth/block_snake deltas surviving into the shared layout."""
    trace = list(
        shared_prefix_decode_trace(
            order, n_rows, prefix_pages, own_lens, n_steps, page,
            shared=shared, snake_group=snake_group,
        )
    )
    dists = reuse_distances(trace)
    stats = {
        "accesses": len(trace),
        "mean_reuse_distance": (sum(dists) / len(dists)) if dists else 0.0,
        "max_reuse_distance": max(dists, default=0),
    }
    if capacity_pages is not None:
        res = simulate_trace(((k, 1.0) for k in trace), capacity_pages)
        stats["hit_rate"] = res.hit_rate
        stats["misses"] = res.misses
        stats["cold_misses"] = res.cold_misses
    return stats


def simulate_attention(
    w: AttentionWorkload,
    hw: HWConfig,
    order: Order | str = Order.CYCLIC,
    n_workers: int | None = None,
    *,
    snake_group: int | None = None,
) -> SimResult:
    """End-to-end: build the wavefront trace and run it through the LRU L2."""
    n_workers = hw.n_workers if n_workers is None else n_workers
    capacity_sectors = hw.cache_bytes / hw.sector_bytes
    return simulate_trace(
        attention_trace(w, hw, order, n_workers, snake_group=snake_group),
        capacity_sectors,
    )
