"""Trace-driven LRU cache simulator (tile granularity).

This is the measurement instrument standing in for `ncu` on hardware we do
not have: it replays the exact access stream a persistent-CTA flash-attention
kernel issues (paper Alg. 1+2+4) against an LRU cache of the GB10 L2's size
and reports hit/miss sector counts.

Granularity: one entry per (tensor, batch·head, tile) — all sectors of a tile
are touched together by the tiled kernel, so tile-granularity LRU is exact
for this workload up to boundary tiles. Sector weights preserve the paper's
counter units (`lts__t_sectors.sum`).

Validated against the paper:
  * cold-miss floor 16S            (§3.3, Fig 5)
  * divergence at KV ≈ cache size  (§3.3)
  * hit rate ≈ 1 − 1/N_SM          (§3.4, Fig 6)
  * sawtooth ≈ 50 % fewer non-compulsory misses (§4.2, Fig 8)
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Iterable, Iterator

from repro.core import cache_model
from repro.core.cache_model import AttentionWorkload, HWConfig
from repro.core.schedule import Order, kv_index_host, num_kv_tiles_for

__all__ = ["SimResult", "LRUCache", "simulate_trace", "attention_trace", "simulate_attention"]


@dataclasses.dataclass
class SimResult:
    accesses: float = 0.0      # sectors requested
    misses: float = 0.0        # sectors missed
    cold_misses: float = 0.0   # first-touch sectors (compulsory)

    @property
    def hits(self) -> float:
        return self.accesses - self.misses

    @property
    def hit_rate(self) -> float:
        return 0.0 if self.accesses == 0 else self.hits / self.accesses

    @property
    def non_compulsory_misses(self) -> float:
        return self.misses - self.cold_misses


class LRUCache:
    """Weighted-entry LRU. Entries carry a sector size; capacity in sectors."""

    def __init__(self, capacity_sectors: float):
        self.capacity = capacity_sectors
        self._entries: OrderedDict[tuple, float] = OrderedDict()
        self._used = 0.0
        self._seen: set[tuple] = set()

    def access(self, key: tuple, sectors: float, result: SimResult) -> bool:
        """Touch ``key``; returns True on hit. Updates ``result`` in place."""
        result.accesses += sectors
        entries = self._entries
        if key in entries:
            entries.move_to_end(key)
            return True
        result.misses += sectors
        if key not in self._seen:
            self._seen.add(key)
            result.cold_misses += sectors
        if sectors > self.capacity:
            return False  # un-cacheable entry: bypass
        entries[key] = sectors
        self._used += sectors
        while self._used > self.capacity:
            _, sz = entries.popitem(last=False)
            self._used -= sz
        return False


def simulate_trace(
    trace: Iterable[tuple[tuple, float]], capacity_sectors: float
) -> SimResult:
    """Replay (key, sectors) accesses through an LRU cache."""
    cache = LRUCache(capacity_sectors)
    result = SimResult()
    access = cache.access
    for key, sectors in trace:
        access(key, sectors, result)
    return result


def attention_trace(
    w: AttentionWorkload,
    hw: HWConfig,
    order: Order | str,
    n_workers: int,
) -> Iterator[tuple[tuple, float]]:
    """Wavefront access trace for the full (batch × heads × tiles) problem.

    Work distribution follows paper Alg. 2: the global list of Q tiles (over
    batch·head·tile-index, batch/head-major as in the paper's linearised
    ``(Batch, Head, TileIndex)`` decoding) is claimed round-robin by
    ``n_workers`` persistent workers that progress in lock-step (§3.4's
    wavefront observation). Sawtooth parity is the *worker-local* iteration
    counter, exactly Alg. 4.

    Keys: ("Q"|"K"|"V"|"O", bh, tile).  K/V of one (b,h) are distinct tensors.
    """
    order = Order.parse(order)
    n_tiles = w.n_tiles
    spt = cache_model.sectors_per_tile(w, hw)
    bh_count = w.batch * w.heads
    total_q = bh_count * n_tiles

    # Worker w gets global q indices w, w+G, w+2G, ...
    n_workers = max(1, min(n_workers, total_q))
    positions = [0] * n_workers           # index into worker's assignment
    inner = [0] * n_workers               # inner kv step
    started = [False] * n_workers

    def q_of(worker: int, pos: int) -> int:
        return worker + pos * n_workers

    active = [q_of(wk, 0) < total_q for wk in range(n_workers)]
    while any(active):
        for wk in range(n_workers):
            if not active[wk]:
                continue
            gq = q_of(wk, positions[wk])
            bh, q_tile = divmod(gq, n_tiles)
            n_kv = num_kv_tiles_for(
                q_tile, n_tiles, causal=w.causal, q_block=w.tile, kv_block=w.tile
            )
            if not started[wk]:
                yield (("Q", bh, q_tile), spt)
                started[wk] = True
            j = inner[wk]
            kv = kv_index_host(order, positions[wk], j, n_kv)
            yield (("K", bh, kv), spt)
            yield (("V", bh, kv), spt)
            inner[wk] += 1
            if inner[wk] >= n_kv:
                yield (("O", bh, q_tile), spt)
                inner[wk] = 0
                started[wk] = False
                positions[wk] += 1
                if q_of(wk, positions[wk]) >= total_q:
                    active[wk] = False


def simulate_attention(
    w: AttentionWorkload,
    hw: HWConfig,
    order: Order | str = Order.CYCLIC,
    n_workers: int | None = None,
) -> SimResult:
    """End-to-end: build the wavefront trace and run it through the LRU L2."""
    n_workers = hw.n_workers if n_workers is None else n_workers
    capacity_sectors = hw.cache_bytes / hw.sector_bytes
    return simulate_trace(attention_trace(w, hw, order, n_workers), capacity_sectors)
