"""Analytic cache-traffic models from the paper (§3.2–§3.4).

All formulas keep (S, D, E, C, T) symbolic so the same code serves

  * the faithful GB10 reproduction  (C=32 B sectors, E=2 fp16, D=64, T=80/64),
  * the TPU adaptation              (C=512 B DMA granule, bf16, TPU block sizes).

The model counts *accesses* (demand traffic into the shared cache level) and
*cold (compulsory) misses*; the LRU simulator (``cache_sim``) provides the
non-compulsory miss counts that depend on traversal order.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "HWConfig",
    "GB10",
    "TPU_V5E_DMA",
    "AttentionWorkload",
    "sectors_per_tile",
    "l2_sector_accesses",
    "l2_sector_accesses_simple",
    "cold_miss_sectors",
    "kv_bytes",
    "l2_hit_rate_wavefront",
    "attention_flops",
    "gb10_throughput_model",
    "calibrate_miss_service",
]


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """The cache/memory level the model targets."""

    name: str
    sector_bytes: int          # C — granularity of the cache/DMA level
    cache_bytes: int           # capacity of the shared level (L2 on GB10)
    mem_bandwidth: float       # bytes/s behind the cache (LPDDR / HBM)
    peak_flops: float          # per-device peak (fp16/bf16 MACs*2)
    n_workers: int             # SMs on GB10 / concurrent cores on TPU


# GB10: 48 SMs, 24 MiB L2, ~600 GB/s aggregate LPDDR5X (paper §2.1).
# Peak fp16 tensor throughput for GB10 is not published precisely; the paper's
# CUDA kernel reaches 2.4 TFLOPS and the CuTile one 69 TFLOPS. We use 100e12
# as a nominal dense fp16 peak for the bottleneck model; only *ratios* between
# cyclic/sawtooth matter for the reproduction.
GB10 = HWConfig(
    name="gb10",
    sector_bytes=32,
    cache_bytes=24 * 2**20,
    mem_bandwidth=600e9,
    peak_flops=100e12,
    n_workers=48,
)

# TPU v5e seen through the same lens: the "shared level" for a single core is
# the HBM<->VMEM DMA engine; granule 512B. cache_bytes models VMEM available
# for KV staging (half of 128 MiB VMEM as double-buffered pipeline space).
TPU_V5E_DMA = HWConfig(
    name="tpu_v5e",
    sector_bytes=512,
    cache_bytes=64 * 2**20,
    mem_bandwidth=819e9,
    peak_flops=197e12,
    n_workers=1,
)


@dataclasses.dataclass(frozen=True)
class AttentionWorkload:
    """One flash-attention forward problem (single head unless stated)."""

    seq_len: int               # S
    head_dim: int = 64         # D
    elem_bytes: int = 2        # E (fp16/bf16)
    tile: int = 80             # T (square tiling, B_r == B_c, paper §2.2)
    batch: int = 1
    heads: int = 1
    causal: bool = False

    @property
    def n_tiles(self) -> int:
        return self.seq_len // self.tile  # paper uses floor(S/T)

    def scale(self) -> int:
        """batch*heads scales the problem linearly (paper §3.2)."""
        return self.batch * self.heads


def sectors_per_tile(w: AttentionWorkload, hw: HWConfig) -> float:
    """T*D*E/C — sectors in one (T × D) tile."""
    return w.tile * w.head_dim * w.elem_bytes / hw.sector_bytes


def l2_sector_accesses(w: AttentionWorkload, hw: HWConfig) -> float:
    """Exact tiled count of demand sectors into the shared level.

    Q and O tiles are touched once each; K and V tiles once per Q tile
    (non-causal) or only up to the diagonal (causal). Matches paper §3.2
    including the floor-division tile count.
    """
    spt = sectors_per_tile(w, hw)
    n = w.n_tiles
    qo = 2.0 * spt * n
    if w.causal:
        # sum_{i=1..n} i  = n(n+1)/2 KV tile visits; the paper's closed form
        # uses S(S-1)/(2T) ~ n^2/2 — we keep the exact tiled sum here.
        kv_visits = n * (n + 1) / 2.0
    else:
        kv_visits = float(n) * n
    kv = 2.0 * spt * kv_visits
    return w.scale() * (qo + kv)


def l2_sector_accesses_simple(w: AttentionWorkload, hw: HWConfig) -> float:
    """Paper's closed forms (direct-division approximations).

    non-causal: M = 2(S·D·E/C + S²·D·E/(T·C))
    causal:     M = 2(S·D·E/C + S(S−1)·D·E/(2·T·C))
                  ≈ 8S(S/2T + 1/2) for C=32,E=2,D=64
    """
    s, d, e, c, t = w.seq_len, w.head_dim, w.elem_bytes, hw.sector_bytes, w.tile
    if w.causal:
        m = 2.0 * (s * d * e / c + s * (s - 1) * d * e / (2.0 * t * c))
    else:
        m = 2.0 * (s * d * e / c + s * s * d * e / (t * c))
    return w.scale() * m


def cold_miss_sectors(w: AttentionWorkload, hw: HWConfig) -> float:
    """Compulsory misses: each of Q,K,V,O is loaded at least once.

    4·S·D·E/C — "16S with our configuration" (paper §3.3).
    """
    return w.scale() * 4.0 * w.seq_len * w.head_dim * w.elem_bytes / hw.sector_bytes


def kv_bytes(w: AttentionWorkload) -> int:
    """Size of the streamed KV working set (drives the §3.3 threshold)."""
    return w.scale() * 2 * w.seq_len * w.head_dim * w.elem_bytes


def l2_hit_rate_wavefront(n_workers: int) -> float:
    """Paper §3.4: synchronized wavefronts give hit rate ≈ 1 − 1/N_SM."""
    if n_workers <= 0:
        raise ValueError("n_workers must be positive")
    return 1.0 - 1.0 / n_workers


def attention_flops(w: AttentionWorkload) -> float:
    """Matmul FLOPs of the fused forward: 2 GEMMs of 2·S·S·D each.

    Causal halves the score region. Softmax FLOPs are O(S²) and ignored,
    consistent with how the paper reports TFLOPS.
    """
    full = 4.0 * w.seq_len * w.seq_len * w.head_dim
    if w.causal:
        full *= 0.5
    return w.scale() * full


def gb10_throughput_model(
    w: AttentionWorkload,
    hw: HWConfig,
    miss_sectors: float,
    *,
    miss_service_s: float,
    kernel_peak: float | None = None,
) -> float:
    """Additive stall model used to reproduce Fig 7/10/12.

        t = t_compute + misses · miss_service_s,   throughput = FLOPs / t

    Rationale (napkin math in EXPERIMENTS.md §Paper-validation): at the
    paper's CUDA operating point, pure DRAM *bandwidth* for the measured
    miss traffic would cost ~0.2 s while the observed time is ~27 s — the
    kernel is miss-*latency* (stall) bound, so time scales ~linearly in the
    miss count, which is exactly why halving misses nearly doubles
    throughput (1.3→2.4 TFLOPS). The CuTile kernel runs near its compute
    ceiling, so the same model with its calibrated (much smaller) exposed
    miss-service time yields the paper's ~13% non-causal gain.

    ``miss_service_s`` is calibrated once on the *cyclic baseline* via
    :func:`calibrate_miss_service`; sawtooth numbers are then predictions.
    """
    flops = attention_flops(w)
    t_compute = flops / (kernel_peak or hw.peak_flops)
    t = t_compute + miss_sectors * miss_service_s
    return flops / t


def calibrate_miss_service(
    w: AttentionWorkload,
    hw: HWConfig,
    *,
    observed_flops: float,
    miss_sectors: float,
    kernel_peak: float | None = None,
) -> float:
    """Solve the additive model for the exposed per-miss service time given
    one observed (baseline) throughput."""
    flops = attention_flops(w)
    t_total = flops / observed_flops
    t_compute = flops / (kernel_peak or hw.peak_flops)
    return max(t_total - t_compute, 0.0) / max(miss_sectors, 1.0)


def divergence_seq_len(hw: HWConfig, w: AttentionWorkload) -> int:
    """Sequence length where KV working set reaches cache capacity (§3.3).

    Paper: divergence at S ≈ 80K on GB10 (KV = 20 MiB vs 24 MiB L2).
    """
    per_token = w.scale() * 2 * w.head_dim * w.elem_bytes
    return int(hw.cache_bytes // per_token)
