"""Blockwise (flash) attention in pure JAX, parameterized by KV schedule.

This is the framework's reference execution path: it is the oracle for the
Pallas kernels, the implementation used on CPU (and in the multi-pod
dry-run, where Pallas-TPU cannot lower), and the place where the paper's
sawtooth schedule is demonstrably *math-preserving* — online softmax is
traversal-order invariant, so cyclic and sawtooth produce identical outputs
up to floating-point reassociation (property-tested).

Layout convention: q:(B, Sq, Hq, D), k/v:(B, Skv, Hkv, D) with Hq % Hkv == 0
(GQA). Output (B, Sq, Hq, D), accumulation in f32, output in q.dtype.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import (
    KVSchedule,
    Order,
    Traversal,
    page_visit_order_dynamic,
)

__all__ = [
    "mha_reference",
    "flash_attention",
    "flash_attention_bwd",
    "decode_attention",
    "paged_decode_attention",
]

NEG_INF = float(np.finfo(np.float32).min)


def _valid_mask(
    rows: jax.Array,
    cols: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    kv_len: int,
) -> jax.Array:
    """Boolean (len(rows), len(cols)) visibility mask for global indices."""
    m = cols < kv_len  # mask out kv padding
    if causal:
        m &= cols[None, :] <= rows[:, None]
    if window is not None:
        m &= cols[None, :] > rows[:, None] - window
    if not causal and window is None:
        m = jnp.broadcast_to(m[None, :], (rows.shape[0], cols.shape[0]))
    return m


def _mask_bias(
    rows: jax.Array,
    cols: jax.Array,
    *,
    causal: bool,
    window: Optional[int],
    kv_len: int,
) -> jax.Array:
    """Additive mask bias (0 or -inf) for global row/col index grids."""
    m = _valid_mask(rows, cols, causal=causal, window=window, kv_len=kv_len)
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Full-materialization attention. Small shapes / testing only."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    rows = jnp.arange(sq)
    cols = jnp.arange(skv)
    s = s + _mask_bias(rows, cols, causal=causal, window=window, kv_len=skv)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(
    jax.jit,
    static_argnames=(
        "order",
        "causal",
        "window",
        "q_block",
        "kv_block",
        "scale",
        "score_dtype",
        "snake_group",
        "return_lse",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    order: Order | str = Order.CYCLIC,
    causal: bool = False,
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
    scale: Optional[float] = None,
    score_dtype: str = "float32",
    snake_group: Optional[int] = None,
    return_lse: bool = False,
) -> jax.Array:
    """Blockwise online-softmax attention, KV traversed in schedule order.

    Structure mirrors paper Alg. 1 (split-Q: Q tile resident, KV streamed)
    with the KV visit order given by Alg. 4 when ``order == 'sawtooth'``.
    Q blocks are independent (vmapped — the 'parallel for' of Alg. 1); the
    KV stream is a ``lax.scan`` so the lowered HLO stays small at any S.

    ``return_lse=True`` additionally returns the per-row log-sum-exp of the
    *scaled* scores, shape (B, Sq, Hq) f32 — the residual the fused flash
    backward (:func:`flash_attention_bwd`) consumes instead of recomputing
    the forward. Fully-masked (padding) rows report ``NEG_INF``-scale lse.
    """
    order = Order.parse(order)
    sdt = jnp.dtype(score_dtype)
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale_ = d ** -0.5 if scale is None else scale

    q_block = min(q_block, max(sq, 1))
    kv_block = min(kv_block, max(skv, 1))

    qp = _pad_to(q, 1, q_block)
    kp = _pad_to(k, 1, kv_block)
    vp = _pad_to(v, 1, kv_block)
    sq_p, skv_p = qp.shape[1], kp.shape[1]
    nq, nkv = sq_p // q_block, skv_p // kv_block

    # (B, Hkv, G, nq, qb, D) queries; (B, Hkv, nkv, kb, D) keys/values.
    # The compiled traversal: the XLA path masks instead of trimming, so it
    # walks the full tile range in IR order (``kv_step``).
    tr = Traversal(
        order=order, n_q=nq, n_kv=nkv, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, n_groups=g, snake_group=snake_group,
    )

    qb_ = (
        qp.reshape(b, nq, q_block, hkv, g, d)
        .transpose(0, 3, 4, 1, 2, 5)
        .astype(sdt)
        * jnp.asarray(scale_, sdt)
    )
    kb_ = kp.reshape(b, nkv, kv_block, hkv, d).transpose(0, 3, 1, 2, 4)
    vb_ = vp.reshape(b, nkv, kv_block, hkv, d).transpose(0, 3, 1, 2, 4)

    rows = jnp.arange(q_block)
    cols = jnp.arange(kv_block)

    def one_q_block(i, q_tile):
        # q_tile: (B, Hkv, G, qb, D)
        def body(carry, j):
            m, l, acc = carry
            kv_j = tr.kv_step(i, j)
            k_j = jax.lax.dynamic_index_in_dim(kb_, kv_j, axis=2, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb_, kv_j, axis=2, keepdims=False)
            # scores/probs in score_dtype (bf16 halves the dominant HBM
            # traffic term — EXPERIMENTS.md §Perf); softmax stats stay f32.
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk",
                q_tile,
                k_j.astype(sdt),
                precision=jax.lax.Precision.DEFAULT,
                preferred_element_type=sdt,
            )
            bias = _mask_bias(
                rows + i * q_block,
                cols + kv_j * kv_block,
                causal=causal,
                window=window,
                kv_len=skv,
            ).astype(sdt)
            s = s + bias
            m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
            p = jnp.exp(s - m_new.astype(sdt)[..., None])  # stays in sdt
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1).astype(jnp.float32)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_j.astype(sdt),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, hkv, g, q_block), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, q_block), jnp.float32),
            jnp.zeros((b, hkv, g, q_block, d), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nkv))
        lse = m + jnp.log(jnp.where(l == 0.0, 1.0, l))
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (padding)
        return acc / l[..., None], lse

    out, lse = jax.vmap(one_q_block, in_axes=(0, 3), out_axes=(3, 3))(
        jnp.arange(nq), qb_
    )  # (B, Hkv, G, nq, qb, D), (B, Hkv, G, nq, qb)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(b, sq_p, hq, d)
    out = out[:, :sq].astype(q.dtype)
    if not return_lse:
        return out
    lse = lse.transpose(0, 3, 4, 1, 2).reshape(b, sq_p, hq)[:, :sq]
    return out, lse


@functools.partial(
    jax.jit,
    static_argnames=(
        "order",
        "causal",
        "window",
        "q_block",
        "kv_block",
        "scale",
        "score_dtype",
        "snake_group",
    ),
)
def flash_attention_bwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    *,
    order: Order | str = Order.CYCLIC,
    causal: bool = False,
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
    scale: Optional[float] = None,
    score_dtype: str = "float32",
    snake_group: Optional[int] = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused blockwise flash backward from saved ``(o, lse)`` residuals.

    The FlashAttention-2 two-pass structure, without re-running the forward:

      delta = rowsum(dO * O)                      (per-row, f32)
      dQ pass: Q tile resident, KV tiles streamed in schedule order
               (the forward grid), accumulating dQ += scale * dS @ K
      dK/dV pass: KV tile resident, Q/dO tiles streamed in the *transposed*
               schedule order (parity keyed on the KV-tile counter — see
               ``core.schedule.BwdKVSchedule``), accumulating
               dV += P^T @ dO and dK += scale * dS^T @ Q

    with P = exp(S - lse) recovered from the saved log-sum-exp (already
    normalized — no second softmax reduction) and dS = P * (dP - delta).
    Out-of-range tiles contribute exact zeros through the mask, so both
    passes scan the full tile range (the Pallas kernels trim instead).
    ``score_dtype`` drops the two score-shaped einsums to bf16 like the
    forward; softmax recovery and accumulation stay f32.
    """
    order = Order.parse(order)
    sdt = jnp.dtype(score_dtype)
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale_ = d ** -0.5 if scale is None else scale

    q_block = min(q_block, max(sq, 1))
    kv_block = min(kv_block, max(skv, 1))

    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)  # (B,Sq,Hq)

    qp = _pad_to(q, 1, q_block)
    dop = _pad_to(do, 1, q_block)
    lsep = _pad_to(lse.astype(jnp.float32), 1, q_block)
    deltap = _pad_to(delta, 1, q_block)
    kp = _pad_to(k, 1, kv_block)
    vp = _pad_to(v, 1, kv_block)
    sq_p, skv_p = qp.shape[1], kp.shape[1]
    nq, nkv = sq_p // q_block, skv_p // kv_block

    tr = Traversal(
        order=order, n_q=nq, n_kv=nkv, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, n_groups=g, snake_group=snake_group,
    )
    # The transposed (dK/dV) pass streams Q tiles with parity on the resident
    # KV-tile counter: the same IR with the roles of the axes swapped.
    tr_t = Traversal(
        order=order, n_q=nkv, n_kv=nq, q_block=kv_block, kv_block=q_block,
        snake_group=snake_group,
    )

    def fold_q(x):  # (B, Sq, Hq[, D]) -> (B, Hkv, G, nq, qb[, D])
        tail = x.shape[3:]
        x = x.reshape((b, nq, q_block, hkv, g) + tail)
        perm = (0, 3, 4, 1, 2) + tuple(range(5, x.ndim))
        return x.transpose(perm)

    qb_ = fold_q(qp.astype(jnp.float32))
    dob_ = fold_q(dop.astype(jnp.float32))
    lseb = fold_q(lsep)
    deltab = fold_q(deltap)
    kb_ = kp.astype(jnp.float32).reshape(b, nkv, kv_block, hkv, d).transpose(0, 3, 1, 2, 4)
    vb_ = vp.astype(jnp.float32).reshape(b, nkv, kv_block, hkv, d).transpose(0, 3, 1, 2, 4)

    rows = jnp.arange(q_block)
    cols = jnp.arange(kv_block)

    def _p_ds(q_t, do_t, lse_t, delta_t, k_j, v_j, ok):
        """Shared tile math: normalized probs P and score grad dS."""
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", q_t.astype(sdt), k_j.astype(sdt),
            preferred_element_type=sdt,
        ).astype(jnp.float32) * scale_
        p = jnp.where(ok, jnp.exp(s - lse_t[..., None]), 0.0)
        dp = jnp.einsum(
            "bhgqd,bhkd->bhgqk", do_t.astype(sdt), v_j.astype(sdt),
            preferred_element_type=sdt,
        ).astype(jnp.float32)
        ds = p * (dp - delta_t[..., None])
        return p, ds

    # ---- dQ pass: forward grid (Q resident, KV streamed) ---------------------
    def dq_block(i, q_t, do_t, lse_t, delta_t):
        def body(acc, j):
            kv_j = tr.kv_step(i, j)
            k_j = jax.lax.dynamic_index_in_dim(kb_, kv_j, axis=2, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb_, kv_j, axis=2, keepdims=False)
            ok = _valid_mask(
                rows + i * q_block, cols + kv_j * kv_block,
                causal=causal, window=window, kv_len=skv,
            )
            _, ds = _p_ds(q_t, do_t, lse_t, delta_t, k_j, v_j, ok)
            acc = acc + scale_ * jnp.einsum("bhgqk,bhkd->bhgqd", ds, k_j)
            return acc, None

        init = jnp.zeros((b, hkv, g, q_block, d), jnp.float32)
        acc, _ = jax.lax.scan(body, init, jnp.arange(nkv))
        return acc

    dq = jax.vmap(dq_block, in_axes=(0, 3, 3, 3, 3), out_axes=3)(
        jnp.arange(nq), qb_, dob_, lseb, deltab
    )
    dq = dq.transpose(0, 3, 4, 1, 2, 5).reshape(b, sq_p, hq, d)[:, :sq]

    # ---- dK/dV pass: transposed grid (KV resident, Q/dO streamed) ------------
    def dkv_block(jt, k_t, v_t):
        def body(carry, jq):
            dk_acc, dv_acc = carry
            q_i = tr_t.kv_step(jt, jq)  # transposed: parity on KV tile
            q_t = jax.lax.dynamic_index_in_dim(qb_, q_i, axis=3, keepdims=False)
            do_t = jax.lax.dynamic_index_in_dim(dob_, q_i, axis=3, keepdims=False)
            lse_t = jax.lax.dynamic_index_in_dim(lseb, q_i, axis=3, keepdims=False)
            delta_t = jax.lax.dynamic_index_in_dim(deltab, q_i, axis=3, keepdims=False)
            ok = _valid_mask(
                rows + q_i * q_block, cols + jt * kv_block,
                causal=causal, window=window, kv_len=skv,
            )
            p, ds = _p_ds(q_t, do_t, lse_t, delta_t, k_t, v_t, ok)
            dv_acc = dv_acc + jnp.einsum("bhgqk,bhgqd->bhkd", p, do_t)
            dk_acc = dk_acc + scale_ * jnp.einsum("bhgqk,bhgqd->bhkd", ds, q_t)
            return (dk_acc, dv_acc), None

        init = (
            jnp.zeros((b, hkv, kv_block, d), jnp.float32),
            jnp.zeros((b, hkv, kv_block, d), jnp.float32),
        )
        (dk_acc, dv_acc), _ = jax.lax.scan(body, init, jnp.arange(nq))
        return dk_acc, dv_acc

    dk, dv = jax.vmap(dkv_block, in_axes=(0, 2, 2), out_axes=2)(
        jnp.arange(nkv), kb_, vb_
    )  # (B, Hkv, nkv, kb, D)
    dk = dk.transpose(0, 2, 3, 1, 4).reshape(b, skv_p, hkv, d)[:, :skv]
    dv = dv.transpose(0, 2, 3, 1, 4).reshape(b, skv_p, hkv, d)[:, :skv]

    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array | int,
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_table: Optional[jax.Array] = None,
    q_lens: Optional[jax.Array] = None,
    order: Order | str = Order.CYCLIC,
    snake_group: Optional[int] = None,
    order_group: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-position decode attention against a (possibly padded) KV cache.

    Contiguous layout: q (B, 1, Hq, D); caches (B, S_max, Hkv, D);
    cache_len: valid prefix length (scalar or (B,)). Linear in S_max — used
    for decode_32k/long_500k serve steps. Window applies Mistral-style SWA
    over absolute positions.

    Paged layout (``block_table`` given): caches are shared page pools
    (n_pages, page, Hkv, D); ``block_table`` (B, n_blocks) maps each row's
    logical page j to a physical pool page, and pages are visited in
    ``KVSchedule`` order (``order='sawtooth'`` alternates direction per
    decode step, parity keyed on ``cache_len``). The paged path is ragged:
    q may carry C > 1 chunk positions per row with per-row ``q_lens``
    (chunked prefill / mixed serve steps) — see
    :func:`paged_decode_attention`. ``order_group`` (paged only) overrides
    the static order with a traced effective reversal-group scalar
    (``schedule.resolve_order_group``) so the order can change per step
    without retracing.
    """
    if block_table is not None:
        return paged_decode_attention(
            q,
            k_cache,
            v_cache,
            cache_len,
            block_table,
            q_lens=q_lens,
            window=window,
            scale=scale,
            order=order,
            snake_group=snake_group,
            order_group=order_group,
        )
    assert q_lens is None, "q_lens requires the paged layout (block_table)"
    assert order_group is None, "order_group requires the paged layout"
    b, one, hq, d = q.shape
    assert one == 1
    _, s_max, hkv, _ = k_cache.shape
    g = hq // hkv
    scale_ = d ** -0.5 if scale is None else scale
    lens = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32)) * scale_
    pos = jnp.arange(s_max)[None, :]  # (1, S)
    valid = pos < lens[:, None]
    if window is not None:
        valid &= pos > (lens[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    cache_len: jax.Array | int,
    block_table: jax.Array,
    *,
    q_lens: Optional[jax.Array] = None,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    order: Order | str = Order.CYCLIC,
    snake_group: Optional[int] = None,
    order_group: Optional[jax.Array] = None,
) -> jax.Array:
    """Blockwise ragged attention over a paged KV pool, schedule-ordered.

    q: (B, C, Hq, D) — a ragged chunk of C query positions per row (C=1 is
    plain decode; C>1 is a chunked-prefill / mixed serve step).
    k_pool/v_pool: (n_pages, page, Hkv, D) — one shared pool across the
    batch. block_table: (B, n_blocks) int32, logical page j of row b lives
    in pool page ``block_table[b, j]``. cache_len: (B,) or scalar valid KV
    lengths *including* this chunk's writes. q_lens: (B,) number of valid
    query rows in each row's chunk (default: all C); query t of row b sits
    at absolute position ``cache_len - q_len + t`` and attends causally to
    positions ``<=`` its own — causal masking *inside* the chunk, so one
    ragged step serves decode rows (q_len 1) and prefill chunks (q_len up
    to C) together.

    Pages are streamed through online softmax in the order given by a
    :class:`KVSchedule` over the gathered pages; sawtooth parity is driven
    per row by ``cache_len`` (the visited length) so consecutive steps of
    one sequence reverse direction (the tail pages of step t are the head
    pages of step t+1 — the decode analogue of the paper's prefill
    reordering). The result is traversal-order invariant, matching the
    contiguous oracle.

    Fully-masked rows (q_len 0 / len 0 — e.g. a free slot in a
    continuous-batching pool) return exact zeros rather than NaN.
    """
    b, c, hq, d = q.shape
    n_pages, page, hkv, _ = k_pool.shape
    n_blocks = block_table.shape[1]
    g = hq // hkv
    scale_ = d ** -0.5 if scale is None else scale
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    qls = (
        jnp.full((b,), c, jnp.int32)
        if q_lens is None
        else jnp.broadcast_to(jnp.asarray(q_lens, jnp.int32), (b,))
    )
    # Absolute position of each query row; invalid rows (t >= q_len) get a
    # fully-masked position so they contribute exact zeros.
    tq = jnp.arange(c, dtype=jnp.int32)[None, :]
    q_pos = (lens - qls)[:, None] + tq          # (B, C)
    q_valid = tq < qls[:, None]

    if order_group is not None:
        # Runtime-switchable order: the effective reversal group arrives as
        # a traced scalar operand (schedule.resolve_order_group), so a serve
        # engine can flip cyclic/sawtooth/block_snake between steps inside
        # one compiled step — the static ``order`` argument is ignored.
        visit = page_visit_order_dynamic(lens, n_blocks, order_group)
    else:
        sched = KVSchedule(
            order, n_q=1, n_kv=n_blocks, causal=False, q_block=1,
            kv_block=page, snake_group=snake_group,
        )
        visit = sched.page_order(lens)  # (B, n_blocks) logical page ids
    phys = jnp.take_along_axis(block_table.astype(jnp.int32), visit, axis=1)

    qf = q.astype(jnp.float32).reshape(b, c, hkv, g, d).transpose(0, 2, 3, 1, 4)
    qf = qf * scale_                            # (B, Hkv, G, C, D)
    offs = jnp.arange(page, dtype=jnp.int32)[None, :]

    def body(carry, j):
        m, l, acc = carry
        logical = jax.lax.dynamic_index_in_dim(visit, j, axis=1, keepdims=False)
        pid = jax.lax.dynamic_index_in_dim(phys, j, axis=1, keepdims=False)
        k_j = k_pool[pid].astype(jnp.float32)  # (B, page, Hkv, D)
        v_j = v_pool[pid].astype(jnp.float32)
        pos = logical[:, None] * page + offs   # (B, page) absolute positions
        # (B, C, page): kv visible to query row t iff within [0, len),
        # causally at-or-before the query's own position, and the query
        # row itself is valid; a window trims the low end per query row.
        valid = (pos[:, None, :] <= q_pos[:, :, None]) & q_valid[:, :, None]
        valid &= pos[:, None, :] < lens[:, None, None]
        if window is not None:
            valid &= pos[:, None, :] > (q_pos[:, :, None] - window)
        ok = valid[:, None, None, :, :]        # (B, 1, 1, C, page)
        s = jnp.einsum("bhgcd,bkhd->bhgck", qf, k_j)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhgck,bkhd->bhgcd", p, v_j)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, hkv, g, c), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, c), jnp.float32),
        jnp.zeros((b, hkv, g, c, d), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (free slots)
    o = acc / l[..., None]           # (B, Hkv, G, C, D)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, c, hq, d).astype(q.dtype)
