"""KV traversal schedules — the paper's core contribution as a composable object.

The paper ("Sawtooth Wavefront Reordering", §4) changes the order in which a
flash-attention worker streams KV tiles for consecutive Q tiles:

  cyclic   : every Q tile scans KV tiles 0..n-1           (reuse distance = |KV|)
  sawtooth : even local iterations scan 0..n-1, odd scan n-1..0
             (mean reuse distance halves; the tail of each pass always hits)

A schedule here is pure data + index arithmetic, shared by

  * the pure-JAX blockwise attention (``repro.core.attention``), which scans
    KV blocks in schedule order,
  * the Pallas TPU kernels (``repro.kernels.flash_attention``), where the
    schedule becomes the BlockSpec ``index_map``,
  * the cache simulator (``repro.core.cache_sim``), which consumes the access
    trace the schedule induces.

Everything is traceable (``lax`` ops on scalar ints) so the same function
works inside ``index_map`` and inside ``lax.scan`` bodies.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Order",
    "KVSchedule",
    "BwdKVSchedule",
    "bwd_kv_schedule",
    "kv_index",
    "kv_index_host",
    "page_visit_order",
    "tile_ids",
    "num_kv_tiles_for",
    "q_tile_bounds_for",
]


class Order(str, enum.Enum):
    """Traversal order of the KV inner loop."""

    CYCLIC = "cyclic"
    SAWTOOTH = "sawtooth"

    @classmethod
    def parse(cls, v: "Order | str") -> "Order":
        if isinstance(v, Order):
            return v
        return cls(str(v).lower())


def kv_index(order: Order | str, i, j, n_kv: int):
    """Traced KV tile index for Q-tile ``i``, inner step ``j``.

    Works on python ints and on traced scalars (usable in Pallas index_maps).
    ``i`` is the *local* iteration number of the worker (paper Alg. 4 uses the
    per-SM local counter, not the global tile id — with round-robin assignment
    both have the same parity per worker, so we use the q-tile counter).
    """
    order = Order.parse(order)
    if order is Order.CYCLIC:
        return j
    rev = (n_kv - 1) - j
    if isinstance(i, (int, np.integer)) and isinstance(j, (int, np.integer)):
        return int(j if i % 2 == 0 else rev)
    return jax.lax.select(jnp.asarray(i) % 2 == 0, jnp.asarray(j), jnp.asarray(rev))


def kv_index_host(order: Order | str, i: int, j: int, n_kv: int) -> int:
    """Host-side (python int) version of :func:`kv_index`."""
    order = Order.parse(order)
    if order is Order.CYCLIC:
        return j
    return j if i % 2 == 0 else (n_kv - 1) - j


def page_visit_order(order: Order | str, parity, n_kv: int) -> jax.Array:
    """Vectorized :func:`kv_index`: full visit-order rows for a batch.

    ``parity`` is a (B,)-shaped (or scalar) per-row parity driver — during
    decode the natural driver is the current cache length, so consecutive
    decode steps of one sequence alternate direction and the tail pages of
    step ``t`` are revisited first at ``t+1`` (the decode analogue of the
    paper's sawtooth win). Returns (B, n_kv) logical KV page indices in
    visit order; traced inputs are fine.
    """
    order = Order.parse(order)
    j = jnp.arange(n_kv, dtype=jnp.int32)[None, :]
    p = jnp.atleast_1d(jnp.asarray(parity, jnp.int32))[:, None]
    if order is Order.CYCLIC:
        return jnp.broadcast_to(j, (p.shape[0], n_kv))
    return jnp.where(p % 2 == 0, j, (n_kv - 1) - j)


def num_kv_tiles_for(
    q_tile: int, n_kv: int, *, causal: bool, q_block: int, kv_block: int
) -> int:
    """Number of KV tiles a given Q tile actually touches (causal trimming).

    For causal masking, Q tile ``i`` covering rows [i*q_block, (i+1)*q_block)
    needs KV tiles up to and including the one containing its last row.
    """
    if not causal:
        return n_kv
    last_row = (q_tile + 1) * q_block - 1
    return min(n_kv, last_row // kv_block + 1)


def q_tile_bounds_for(
    kv_tile: int,
    n_q: int,
    *,
    causal: bool,
    window: Optional[int],
    q_block: int,
    kv_block: int,
) -> tuple[int, int]:
    """Inclusive [lo, hi] Q-tile range that touches ``kv_tile`` (transposed
    trimming, for the dK/dV backward grid).

    The transpose of :func:`num_kv_tiles_for`: causal masking means KV tile
    ``j`` (cols [j*kb, (j+1)*kb)) is only visible to Q tiles whose last row
    reaches its first column, so ``lo`` rises with ``j``; a sliding window
    caps ``hi`` because rows beyond ``col + window - 1`` no longer see it.
    """
    lo = (kv_tile * kv_block) // q_block if causal else 0
    if window is not None:
        last_row = (kv_tile + 1) * kv_block + window - 2
        hi = min(n_q - 1, last_row // q_block)
    else:
        hi = n_q - 1
    return lo, hi


@dataclasses.dataclass(frozen=True)
class KVSchedule:
    """A full traversal schedule for one attention problem instance.

    Attributes:
      order: cyclic or sawtooth.
      n_q: number of Q tiles.
      n_kv: number of KV tiles.
      causal: whether causal masking trims the KV range per Q tile.
      q_block / kv_block: tile sizes (rows) — only used for causal trimming
        and for the cache-trace sector weighting.
    """

    order: Order
    n_q: int
    n_kv: int
    causal: bool = False
    q_block: int = 128
    kv_block: int = 128

    def __post_init__(self):
        object.__setattr__(self, "order", Order.parse(self.order))
        if self.n_q <= 0 or self.n_kv <= 0:
            raise ValueError(f"empty schedule: n_q={self.n_q} n_kv={self.n_kv}")

    # ---- per-worker iteration ------------------------------------------------

    def kv_range(self, q_tile: int) -> int:
        return num_kv_tiles_for(
            q_tile,
            self.n_kv,
            causal=self.causal,
            q_block=self.q_block,
            kv_block=self.kv_block,
        )

    def kv_order(self, q_tile: int, local_iter: int | None = None) -> list[int]:
        """The sequence of KV tile ids visited for ``q_tile``.

        ``local_iter`` is the worker-local iteration parity driver; defaults to
        the q_tile id itself (single-worker view / round-robin with G workers
        keeps parity consistent per worker).
        """
        li = q_tile if local_iter is None else local_iter
        n = self.kv_range(q_tile)
        idx = [kv_index_host(self.order, li, j, n) for j in range(n)]
        return idx

    def page_order(self, parity) -> jax.Array:
        """Visit order over this schedule's KV tiles for per-row ``parity``.

        The paged-decode entry point: ``decode_attention`` builds a
        ``KVSchedule`` over the gathered pages of a block table and walks
        them in this order (sawtooth alternates per decode step, keyed on
        the cache length). Traced ``parity`` is fine; returns (B, n_kv).
        """
        return page_visit_order(self.order, parity, self.n_kv)

    # ---- global traces (cache simulation) ------------------------------------

    def worker_assignments(self, n_workers: int) -> list[list[int]]:
        """Round-robin (grid-stride) Q-tile assignment, paper Alg. 2."""
        if n_workers <= 0:
            raise ValueError("n_workers must be positive")
        return [list(range(w, self.n_q, n_workers)) for w in range(n_workers)]

    def wavefront_trace(self, n_workers: int) -> Iterator[tuple[int, str, int]]:
        """Lock-step wavefront access trace: yields (worker, tensor, tile).

        Models the paper's observation (§3.4) that persistent CTAs progress in
        a largely synchronized manner: at each global step every still-active
        worker issues the access for its current (q_tile, j) position, in
        worker order. Tensors: 'Q' (once per q tile), 'K','V' per inner step,
        'O' at tile end.  Tile ids for K/V are KV tile ids; Q/O tiles use the
        q-tile id (distinct tensor namespaces — the simulator keys on
        (tensor, tile)).
        """
        assignments = self.worker_assignments(n_workers)
        # Per-worker iterator state: (assignment position, inner position).
        pos = [0] * len(assignments)
        inner = [0] * len(assignments)
        active = [len(a) > 0 for a in assignments]
        emitted_q = [False] * len(assignments)
        while any(active):
            for w, assign in enumerate(assignments):
                if not active[w]:
                    continue
                q_tile = assign[pos[w]]
                local_iter = pos[w]
                n = self.kv_range(q_tile)
                if not emitted_q[w]:
                    yield (w, "Q", q_tile)
                    emitted_q[w] = True
                j = inner[w]
                kv = kv_index_host(self.order, local_iter, j, n)
                yield (w, "K", kv)
                yield (w, "V", kv)
                inner[w] += 1
                if inner[w] >= n:
                    yield (w, "O", q_tile)
                    inner[w] = 0
                    emitted_q[w] = False
                    pos[w] += 1
                    if pos[w] >= len(assign):
                        active[w] = False

    def flat_trace(self, n_workers: int = 1) -> list[tuple[str, int]]:
        """Trace without worker ids (cache sees the interleaved stream)."""
        return [(t, tile) for (_, t, tile) in self.wavefront_trace(n_workers)]

    def bwd(self, window: Optional[int] = None) -> "BwdKVSchedule":
        """The transposed (dK/dV) schedule over the same tile geometry."""
        return BwdKVSchedule(
            order=self.order,
            n_q=self.n_q,
            n_kv=self.n_kv,
            causal=self.causal,
            window=window,
            q_block=self.q_block,
            kv_block=self.kv_block,
        )


@dataclasses.dataclass(frozen=True)
class BwdKVSchedule:
    """Transposed traversal schedule for the dK/dV backward grid.

    In the flash backward's dK/dV pass the roles flip: each worker parks on
    one *KV* tile (accumulating dK/dV) and streams the *Q*-side operands
    (Q, dO, plus the per-row LSE/delta vectors). The cyclic-traversal L2
    pathology the paper targets therefore reappears on the Q stream —
    every KV tile revisits the full sweep of Q tiles — and the same
    sawtooth reordering applies, with parity keyed on the worker-local
    resident (KV-tile) counter. Causal masking trims the *low* end of the
    Q range per KV tile (the transpose of the forward's high-end trim);
    a sliding window trims the high end.
    """

    order: Order
    n_q: int
    n_kv: int
    causal: bool = False
    window: Optional[int] = None
    q_block: int = 128
    kv_block: int = 128

    def __post_init__(self):
        object.__setattr__(self, "order", Order.parse(self.order))
        if self.n_q <= 0 or self.n_kv <= 0:
            raise ValueError(f"empty schedule: n_q={self.n_q} n_kv={self.n_kv}")

    # ---- per-worker iteration ------------------------------------------------

    def q_bounds(self, kv_tile: int) -> tuple[int, int]:
        return q_tile_bounds_for(
            kv_tile,
            self.n_q,
            causal=self.causal,
            window=self.window,
            q_block=self.q_block,
            kv_block=self.kv_block,
        )

    def q_range(self, kv_tile: int) -> int:
        lo, hi = self.q_bounds(kv_tile)
        return hi - lo + 1

    def q_order(self, kv_tile: int, local_iter: int | None = None) -> list[int]:
        """The sequence of Q tile ids streamed while parked on ``kv_tile``."""
        li = kv_tile if local_iter is None else local_iter
        lo, hi = self.q_bounds(kv_tile)
        n = hi - lo + 1
        return [lo + kv_index_host(self.order, li, j, n) for j in range(n)]

    # ---- global traces (cache simulation) ------------------------------------

    def worker_assignments(self, n_workers: int) -> list[list[int]]:
        """Round-robin KV-tile assignment (the resident tile of this grid)."""
        if n_workers <= 0:
            raise ValueError("n_workers must be positive")
        return [list(range(w, self.n_kv, n_workers)) for w in range(n_workers)]

    def wavefront_trace(self, n_workers: int) -> Iterator[tuple[int, str, int]]:
        """Lock-step wavefront trace of the dK/dV grid.

        Tensors: 'K','V' once per resident KV tile, 'Q','dO' per inner
        step (Q-stream tile ids), 'dK','dV' written at tile end. Sawtooth
        parity is the worker-local resident counter, mirroring
        :meth:`KVSchedule.wavefront_trace`.
        """
        assignments = self.worker_assignments(n_workers)
        pos = [0] * len(assignments)
        inner = [0] * len(assignments)
        active = [len(a) > 0 for a in assignments]
        emitted_kv = [False] * len(assignments)
        while any(active):
            for w, assign in enumerate(assignments):
                if not active[w]:
                    continue
                kv_tile = assign[pos[w]]
                local_iter = pos[w]
                lo, hi = self.q_bounds(kv_tile)
                n = hi - lo + 1
                if not emitted_kv[w]:
                    yield (w, "K", kv_tile)
                    yield (w, "V", kv_tile)
                    emitted_kv[w] = True
                qt = lo + kv_index_host(self.order, local_iter, inner[w], n)
                yield (w, "Q", qt)
                yield (w, "dO", qt)
                inner[w] += 1
                if inner[w] >= n:
                    yield (w, "dK", kv_tile)
                    yield (w, "dV", kv_tile)
                    inner[w] = 0
                    emitted_kv[w] = False
                    pos[w] += 1
                    if pos[w] >= len(assign):
                        active[w] = False

    def flat_trace(self, n_workers: int = 1) -> list[tuple[str, int]]:
        return [(t, tile) for (_, t, tile) in self.wavefront_trace(n_workers)]


def bwd_kv_schedule(
    order: Order | str,
    n_q: int,
    n_kv: int,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
) -> BwdKVSchedule:
    """Build the transposed (dK/dV) schedule directly from grid geometry."""
    return BwdKVSchedule(
        order=Order.parse(order),
        n_q=n_q,
        n_kv=n_kv,
        causal=causal,
        window=window,
        q_block=q_block,
        kv_block=kv_block,
    )


def tile_ids(seq_len: int, block: int) -> int:
    """Number of tiles covering ``seq_len`` rows with ``block``-row tiles."""
    return -(-seq_len // block)
