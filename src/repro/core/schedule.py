"""Traversal IR — the paper's core contribution compiled into one object.

The paper ("Sawtooth Wavefront Reordering", §4) changes the order in which a
flash-attention worker streams KV tiles for consecutive Q tiles. After PR 3
that order arithmetic had been privately re-implemented in four layers
(forward/backward index_maps, the traffic model, the paged decode, the
blockwise scan); this module is now the *single source of truth*: a
:class:`Traversal` is compiled from ``(order, grid bounds, causal/SWA
trimming, GQA fold)`` and emits every lowering the system consumes:

  (a) traced ``kv_block_index`` / ``stream_block_index`` closures — the
      Pallas BlockSpec ``index_map`` arithmetic for the forward/dQ grid and
      the transposed dK/dV grid (``repro.kernels.flash_attention``), also
      used step-wise by the blockwise XLA path (``repro.core.attention``);
  (b) vectorized ``visit_order`` rows — the scalar-prefetch operand of the
      paged decode kernel (``repro.kernels.flash_decode``) and the page
      walk of ``paged_decode_attention``;
  (c) host iterators (``kv_order``/``q_order``/``fwd_grid_steps``/
      ``stream_grid_steps`` plus the wavefront traces on
      :class:`KVSchedule`/:class:`BwdKVSchedule`) — the replay twins that
      feed ``repro.kernels.traffic`` and ``repro.core.cache_sim``.

Order families (all are permutations of the trimmed range — online softmax
is traversal-order invariant, so every order is math-preserving):

  cyclic        : every pass scans tiles 0..n-1.      (reuse distance = |KV|)
  sawtooth      : odd passes scan n-1..0 (paper Alg. 4); mean reuse
                  distance halves and the pass-boundary tile always hits.
  block_snake(g): sawtooth reversal applied *within* KV-tile groups of
                  ``g`` tiles — groups ascend every pass, the direction
                  inside each group alternates with pass parity. Degenerate
                  cases: ``g=1`` is cyclic, ``g>=n`` is sawtooth. Bounding
                  the reversal to ``g`` tiles bounds the traversal's
                  *concurrent footprint*: when causal trimming
                  desynchronizes lock-step workers, sawtooth's full-range
                  opposite-direction sweeps span the whole KV range while
                  block_snake keeps co-resident accesses within ~``g``
                  tiles of each other, so ``g`` can be sized to a shared
                  LLC's capacity (``kernels/traffic.py:fwd_llc_model``).

Everything is traceable (``lax`` ops on scalar ints) so the same arithmetic
works inside Pallas ``index_map``s and ``lax.scan`` bodies.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Order",
    "Traversal",
    "KVSchedule",
    "BwdKVSchedule",
    "bwd_kv_schedule",
    "kv_index",
    "kv_index_host",
    "future_visit_window",
    "page_visit_order",
    "page_visit_order_dynamic",
    "resolve_order_group",
    "step_page_visits",
    "tile_ids",
    "num_kv_tiles_for",
    "q_tile_bounds_for",
    "DEFAULT_SNAKE_GROUP",
]

# Default block_snake group size (KV tiles) when none is configured. 8 tiles
# of a 512-row kv_block at head_dim 128 bf16 is ~2 MiB of K+V — a few
# percent of a shared last-level cache, small enough that several
# desynchronized workers' groups co-reside.
DEFAULT_SNAKE_GROUP = 8


class Order(str, enum.Enum):
    """Traversal order family of the KV inner loop."""

    CYCLIC = "cyclic"
    SAWTOOTH = "sawtooth"
    BLOCK_SNAKE = "block_snake"

    @classmethod
    def parse(cls, v: "Order | str") -> "Order":
        if isinstance(v, Order):
            return v
        try:
            return cls(str(v).lower())
        except ValueError:
            valid = ", ".join(repr(o.value) for o in cls)
            raise ValueError(
                f"unknown traversal order {v!r}; valid orders are: {valid}"
            ) from None


def _is_host_int(*vals) -> bool:
    return all(isinstance(v, (int, np.integer)) for v in vals)


def _resolve_group(order: Order, snake_group: Optional[int], n: int) -> int:
    """Effective reversal-group size over a range of ``n`` tiles.

    The three order families are one arithmetic with different group sizes:
    cyclic reverses nothing (group 1), sawtooth reverses the whole range
    (group n), block_snake reverses within groups of ``snake_group``.
    ``n`` must be a host int here; the traced path resolves with
    ``jnp.minimum`` inside :meth:`Traversal.kv_block_index`.
    """
    if order is Order.CYCLIC:
        return 1
    if order is Order.SAWTOOTH:
        return max(int(n), 1)
    g = DEFAULT_SNAKE_GROUP if snake_group is None else int(snake_group)
    if g < 1:
        raise ValueError(f"snake_group must be >= 1, got {snake_group}")
    return max(1, min(g, int(n)))


def resolve_order_group(
    order: Order | str, snake_group: Optional[int], n_kv: int
) -> int:
    """Public :func:`_resolve_group`: (order, snake_group, range) -> the
    effective reversal-group size, the *single scalar* that distinguishes
    the three order families (cyclic=1, sawtooth=n, block_snake=g).

    This is the runtime-switchable encoding of a traversal order: because
    the grouped-reversal arithmetic is one formula parameterized by this
    group, a consumer that takes the group as a **traced operand**
    (:func:`page_visit_order_dynamic`) can change order between steps with
    zero recompiles — the serve engine's online order adaptation rides on
    exactly this.
    """
    return _resolve_group(Order.parse(order), snake_group, int(n_kv))


def page_visit_order_dynamic(parity, n_kv: int, group) -> jax.Array:
    """:func:`page_visit_order` with the reversal group as a traced operand.

    ``group`` is the effective group size from :func:`resolve_order_group`
    (1 = cyclic, ``n_kv`` = sawtooth, g = block_snake) and may be a traced
    int scalar — the same compiled computation serves every order, so the
    serve engine can rebind the visit order per step without retracing.
    Out-of-range groups are clamped to [1, n_kv]; identical arithmetic to
    the static path (the parity test suite pins the equivalence).
    """
    j = jnp.arange(n_kv, dtype=jnp.int32)[None, :]
    p = jnp.atleast_1d(jnp.asarray(parity, jnp.int32))[:, None]
    g = jnp.clip(jnp.asarray(group, jnp.int32), 1, n_kv)
    base = (j // g) * g
    size = jnp.minimum(g, n_kv - base)
    rev = base + (size - 1) - (j - base)
    # group 1 (cyclic) makes rev == j, so the parity select is a no-op there.
    return jnp.where(p % 2 == 0, jnp.broadcast_to(j, rev.shape), rev)


def _snake_pos_host(parity: int, j: int, n: int, group: int) -> int:
    """Grouped-snake position of step ``j`` in a range of ``n`` tiles."""
    if group <= 1:
        return j
    base = (j // group) * group
    size = min(group, n - base)
    off = j - base
    return base + (off if parity % 2 == 0 else (size - 1) - off)


def _snake_pos_traced(parity, j, n, group):
    """Traced grouped-snake position; ``n``/``group`` may be traced scalars."""
    j = jnp.asarray(j, jnp.int32)
    group = jnp.maximum(jnp.asarray(group, jnp.int32), 1)
    base = (j // group) * group
    size = jnp.minimum(group, jnp.asarray(n, jnp.int32) - base)
    off = j - base
    rev = base + (size - 1) - off
    return jax.lax.select(jnp.asarray(parity, jnp.int32) % 2 == 0, j, rev)


def kv_index(order: Order | str, i, j, n_kv: int, *, snake_group: Optional[int] = None):
    """KV tile index for parity driver ``i``, inner step ``j``, range ``n_kv``.

    Works on python ints and on traced scalars (usable in Pallas index_maps
    and ``lax.scan`` bodies). ``i`` is the *local* iteration number of the
    worker (paper Alg. 4 uses the per-SM local counter; with round-robin
    assignment both have the same parity per worker, so the q-tile counter
    drives it). ``snake_group`` only matters for ``block_snake``.
    """
    order = Order.parse(order)
    if order is Order.CYCLIC:
        return j
    group = _resolve_group(order, snake_group, n_kv)
    if _is_host_int(i, j):
        return _snake_pos_host(int(i), int(j), n_kv, group)
    return _snake_pos_traced(i, j, n_kv, group)


def kv_index_host(
    order: Order | str, i: int, j: int, n_kv: int, *, snake_group: Optional[int] = None
) -> int:
    """Host-side (python int) version of :func:`kv_index`."""
    order = Order.parse(order)
    if order is Order.CYCLIC:
        return j
    return _snake_pos_host(i, j, n_kv, _resolve_group(order, snake_group, n_kv))


def page_visit_order(
    order: Order | str, parity, n_kv: int, *, snake_group: Optional[int] = None
) -> jax.Array:
    """Vectorized :func:`kv_index`: full visit-order rows for a batch.

    ``parity`` is a (B,)-shaped (or scalar) per-row parity driver — during
    decode the natural driver is the current cache length, so consecutive
    decode steps of one sequence alternate direction and the tail pages of
    step ``t`` are revisited first at ``t+1`` (the decode analogue of the
    paper's sawtooth win). Returns (B, n_kv) logical KV page indices in
    visit order; traced inputs are fine.
    """
    order = Order.parse(order)
    j = jnp.arange(n_kv, dtype=jnp.int32)[None, :]
    p = jnp.atleast_1d(jnp.asarray(parity, jnp.int32))[:, None]
    if order is Order.CYCLIC:
        return jnp.broadcast_to(j, (p.shape[0], n_kv))
    group = _resolve_group(order, snake_group, n_kv)
    base = (j // group) * group
    size = jnp.minimum(group, n_kv - base)
    rev = base + (size - 1) - (j - base)
    return jnp.where(p % 2 == 0, j, rev)


def future_visit_window(
    parity, n_kv: int, depth: int, group: int
) -> list[int]:
    """First ``depth`` logical pages of the *next* step's visit order.

    Host-side prefetch window: ``parity`` is the current step's per-row
    parity driver (the visited cache length, as in
    :meth:`Traversal.visit_order`), so ``parity + 1`` is the driver of the
    step about to run, and the returned logical page indices are exactly
    the prefix of the walk that step will issue. ``group`` is the effective
    reversal group from :func:`resolve_order_group` (1 = cyclic, ``n_kv`` =
    sawtooth, g = block_snake), matching the serve engine's runtime order
    operand — the tiered KV prefetcher fetches a suspended row's
    host-resident pages in this order so the pages the next step touches
    first are device-resident first. ``depth >= n_kv`` returns the full
    permutation of the next step's walk.
    """
    n = int(n_kv)
    if n <= 0:
        return []
    g = max(1, min(int(group), n))
    p = int(parity) + 1
    return [_snake_pos_host(p, j, n, g) for j in range(min(int(depth), n))]


def step_page_visits(
    order: Order | str,
    row_pages: "Sequence[Sequence[int]]",
    parities: "Sequence[int]",
    *,
    snake_group: Optional[int] = None,
) -> Iterator[tuple[int, int]]:
    """Step-level shared-page visit order of one ragged mixed serve step.

    ``row_pages[b]`` is row ``b``'s *physical* page walk domain (its block
    table prefix covering its valid KV) and ``parities[b]`` its per-row
    sawtooth parity driver (the visited length, as in
    :meth:`Traversal.visit_order`). The rows progress in lock-step — the
    paper's wavefront execution model applied to the serve step's
    (batch·kv-head, page) grid — so at inner step ``j`` every still-active
    row visits the ``j``-th page of its own traversal. Yields ``(row,
    physical_page)`` in that global interleaved order.

    This is the replay twin the cache simulator uses to model **cross-row
    LLC reuse of shared prefix pages**: rows that adopted the same physical
    prompt pages (``serve.kv_pool`` hash sharing) touch the *same* entries
    within a few interleaved steps of each other, so the shared prefix is
    fetched once per step rather than once per row — a locality axis that
    simply does not exist without page dedup.
    """
    order = Order.parse(order)
    rows = [list(p) for p in row_pages]
    if len(rows) != len(parities):
        raise ValueError(f"{len(rows)} rows vs {len(parities)} parities")
    orders = [
        [
            pages[kv_index_host(order, par, j, len(pages), snake_group=snake_group)]
            for j in range(len(pages))
        ]
        for pages, par in zip(rows, parities)
    ]
    for j in range(max((len(o) for o in orders), default=0)):
        for b, visit in enumerate(orders):
            if j < len(visit):
                yield b, visit[j]


def num_kv_tiles_for(
    q_tile: int, n_kv: int, *, causal: bool, q_block: int, kv_block: int
) -> int:
    """Number of KV tiles a given Q tile actually touches (causal trimming).

    For causal masking, Q tile ``i`` covering rows [i*q_block, (i+1)*q_block)
    needs KV tiles up to and including the one containing its last row.
    """
    if not causal:
        return n_kv
    last_row = (q_tile + 1) * q_block - 1
    return min(n_kv, last_row // kv_block + 1)


def q_tile_bounds_for(
    kv_tile: int,
    n_q: int,
    *,
    causal: bool,
    window: Optional[int],
    q_block: int,
    kv_block: int,
) -> tuple[int, int]:
    """Inclusive [lo, hi] Q-tile range that touches ``kv_tile`` (transposed
    trimming, for the dK/dV backward grid).

    The transpose of :func:`num_kv_tiles_for`: causal masking means KV tile
    ``j`` (cols [j*kb, (j+1)*kb)) is only visible to Q tiles whose last row
    reaches its first column, so ``lo`` rises with ``j``; a sliding window
    caps ``hi`` because rows beyond ``col + window - 1`` no longer see it.
    """
    lo = (kv_tile * kv_block) // q_block if causal else 0
    if window is not None:
        last_row = (kv_tile + 1) * kv_block + window - 2
        hi = min(n_q - 1, last_row // q_block)
    else:
        hi = n_q - 1
    return lo, hi


# --------------------------------------------------------------------------
# the compiled Traversal
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Traversal:
    """One attention problem's traversal, compiled for every consumer.

    Fields describe the *grid*: ``n_q``/``n_kv`` sequence tiles of
    ``q_block``/``kv_block`` rows, ``n_groups`` GQA query groups folded
    along the row axis (grid rows = ``n_groups * n_q``), causal/SWA
    trimming. ``snake_group`` parameterizes ``block_snake``; it is ignored
    by the other orders. The object is hashable/static, so it can close
    over Pallas kernels and live in jit static args.
    """

    order: Order
    n_q: int
    n_kv: int
    causal: bool = False
    window: Optional[int] = None
    q_block: int = 128
    kv_block: int = 128
    n_groups: int = 1
    snake_group: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "order", Order.parse(self.order))
        if self.n_q <= 0 or self.n_kv <= 0:
            raise ValueError(f"empty traversal: n_q={self.n_q} n_kv={self.n_kv}")
        if self.n_groups <= 0:
            raise ValueError(f"n_groups must be positive, got {self.n_groups}")
        if self.snake_group is not None and self.snake_group < 1:
            raise ValueError(f"snake_group must be >= 1, got {self.snake_group}")

    @property
    def grid_rows(self) -> int:
        """Folded Q rows of the forward grid (GQA groups x sequence tiles)."""
        return self.n_groups * self.n_q

    def group_for(self, n: int) -> int:
        """Effective reversal-group size over a trimmed range of ``n`` tiles."""
        return _resolve_group(self.order, self.snake_group, n)

    def _group_for_traced(self, n):
        """Traced :meth:`group_for`: ``n`` may be a traced scalar. Only
        called for the reversing orders (cyclic short-circuits earlier)."""
        if self.order is Order.SAWTOOTH:
            return n
        return jnp.minimum(
            jnp.int32(self.snake_group or DEFAULT_SNAKE_GROUP), n
        )

    # ---- (a) traced index arithmetic (Pallas index_maps, scan bodies) -------

    def kv_bounds(self, i):
        """Traced inclusive [lo, hi] KV-tile range visible to grid row ``i``.

        ``i`` indexes the G-folded rows; the sequence tile is ``i % n_q``.
        """
        q_tile = jax.lax.rem(jnp.asarray(i, jnp.int32), self.n_q)
        if self.causal:
            last_row = q_tile * self.q_block + (self.q_block - 1)
            hi = jnp.minimum(self.n_kv - 1, last_row // self.kv_block)
        else:
            hi = jnp.int32(self.n_kv - 1)
        if self.window is not None:
            first_visible = jnp.maximum(q_tile * self.q_block - (self.window - 1), 0)
            lo = first_visible // self.kv_block
        else:
            lo = jnp.int32(0)
        return lo, hi

    def kv_block_index(self, i, j):
        """KV block fetched at fwd/dQ grid step (i, j) + compute predicate.

        Out-of-range steps are clamped to the boundary position — the Pallas
        pipeline elides the repeated fetch and ``valid`` masks the compute
        (the TPU analogue of causal grid trimming).
        """
        lo, hi = self.kv_bounds(i)
        raw = hi - lo + 1
        # Degenerate trims (possible when SWA pushes the visible range past
        # the KV length) collapse to one always-invalid boundary step; the
        # clips are no-ops whenever raw >= 1.
        steps = jnp.maximum(raw, 1)
        jc = jnp.clip(jnp.asarray(j, jnp.int32), 0, steps - 1)
        if self.order is Order.CYCLIC:
            jj = lo + jc
        else:
            jj = lo + _snake_pos_traced(i, jc, steps, self._group_for_traced(steps))
        jj = jnp.clip(jj, 0, self.n_kv - 1)
        return jj, jnp.asarray(j, jnp.int32) < raw

    def q_bounds(self, jkv):
        """Traced inclusive [lo, hi] Q-tile range touching KV tile ``jkv``
        (transposed trimming, for the dK/dV grid)."""
        jkv = jnp.asarray(jkv, jnp.int32)
        if self.causal:
            lo = (jkv * self.kv_block) // self.q_block
        else:
            lo = jnp.int32(0)
        if self.window is not None:
            last_row = (jkv + 1) * self.kv_block + (self.window - 2)
            hi = jnp.minimum(self.n_q - 1, last_row // self.q_block)
        else:
            hi = jnp.int32(self.n_q - 1)
        return lo, hi

    def stream_block_index(self, jkv, u):
        """(group, Q tile) streamed at dK/dV grid step (jkv, u) + predicate.

        The whole per-resident stream — all ``n_groups`` GQA groups over the
        trimmed Q range — is linearized into one sweep of ``G * steps``
        positions and reordered *as one range*: sawtooth reverses it as a
        unit on odd resident counters (so the boundary bundle is
        pipeline-elided at every sweep transition), block_snake reverses
        within ``snake_group``-sized windows of the sweep. This is the
        exact transpose of the forward traversal; :class:`BwdKVSchedule`
        is the host-side (G=1) model.
        """
        lo, hi = self.q_bounds(jkv)
        raw = hi - lo + 1
        # KV tiles with an empty Q range (causal with seq_kv > seq_q, or SWA
        # past the Q length) collapse to one always-invalid boundary step.
        steps = jnp.maximum(raw, 1)
        total = self.n_groups * steps
        uc = jnp.clip(jnp.asarray(u, jnp.int32), 0, total - 1)
        if self.order is Order.CYCLIC:
            uu = uc
        else:
            uu = _snake_pos_traced(jkv, uc, total, self._group_for_traced(total))
        gg = uu // steps
        qi = jnp.clip(lo + jax.lax.rem(uu, steps), 0, self.n_q - 1)
        return gg, qi, jnp.asarray(u, jnp.int32) < self.n_groups * raw

    def kv_step(self, i, j):
        """Untrimmed traced KV position for the blockwise (masked) scan:
        step ``j`` of pass ``i`` over the full ``n_kv`` range. The XLA path
        masks instead of trimming, so it walks every tile."""
        return kv_index(self.order, i, j, self.n_kv, snake_group=self.snake_group)

    # ---- (b) vectorized visit-order rows (paged decode scalar prefetch) ------

    def visit_order(self, parity) -> jax.Array:
        """(B, n_kv) visit-order rows for per-row ``parity`` drivers.

        The paged-decode lowering: the decode paths gather a block table
        along these rows and the Pallas kernel scalar-prefetches the result
        as its KV ``index_map`` operand. Traced ``parity`` is fine.
        """
        return page_visit_order(
            self.order, parity, self.n_kv, snake_group=self.snake_group
        )

    # ---- (c) host replay (traffic models, cache simulator) -------------------

    def kv_bounds_host(self, q_tile: int) -> tuple[int, int]:
        """Host [lo, hi] KV-tile range for sequence tile ``q_tile``."""
        if self.causal:
            hi = min(self.n_kv - 1, (q_tile * self.q_block + self.q_block - 1) // self.kv_block)
        else:
            hi = self.n_kv - 1
        lo = (
            max(q_tile * self.q_block - (self.window - 1), 0) // self.kv_block
            if self.window is not None
            else 0
        )
        return lo, hi

    def q_bounds_host(self, kv_tile: int) -> tuple[int, int]:
        return q_tile_bounds_for(
            kv_tile,
            self.n_q,
            causal=self.causal,
            window=self.window,
            q_block=self.q_block,
            kv_block=self.kv_block,
        )

    def kv_order(self, q_tile: int, local_iter: Optional[int] = None) -> list[int]:
        """KV tile ids visited for ``q_tile``, trimmed, in traversal order.

        ``local_iter`` is the worker-local parity driver; defaults to the
        q-tile id (single-worker view / round-robin keeps parity per worker).
        """
        li = q_tile if local_iter is None else local_iter
        lo, hi = self.kv_bounds_host(q_tile)
        n = hi - lo + 1
        return [
            lo + kv_index_host(self.order, li, j, n, snake_group=self.snake_group)
            for j in range(n)
        ]

    def q_order(self, kv_tile: int, local_iter: Optional[int] = None) -> list[int]:
        """Q tile ids streamed while parked on ``kv_tile`` (transposed)."""
        li = kv_tile if local_iter is None else local_iter
        lo, hi = self.q_bounds_host(kv_tile)
        n = hi - lo + 1
        return [
            lo + kv_index_host(self.order, li, j, n, snake_group=self.snake_group)
            for j in range(n)
        ]

    def fwd_grid_steps(self) -> Iterator[tuple[int, int, bool]]:
        """Replay the folded forward/dQ Pallas grid: yields (row, kv, valid).

        Exactly the index_map semantics: out-of-range steps clamp to the
        boundary block (``valid=False`` — the fetch is elided, the compute
        skipped). The traffic model consumes this to count DMA bytes.
        """
        for i in range(self.grid_rows):
            lo, hi = self.kv_bounds_host(i % self.n_q)
            raw = hi - lo + 1
            steps = max(raw, 1)  # degenerate trims: one always-invalid step
            order_row = [
                min(max(lo + kv_index_host(
                    self.order, i, j, steps, snake_group=self.snake_group
                ), 0), self.n_kv - 1)
                for j in range(steps)
            ]
            for j in range(self.n_kv):
                jc = min(j, steps - 1)
                yield i, order_row[jc], j < raw

    def stream_sweep(self, resident: int, local_iter: Optional[int] = None) -> list[tuple[int, int]]:
        """The linearized (GQA group, Q tile) stream for one resident KV
        tile of the transposed grid, in traversal order. Parity defaults to
        the resident id (``stream_block_index``'s driver); wavefront models
        pass the worker-local resident counter instead (paper Alg. 4).
        Empty when causal/SWA trimming leaves no visible Q tiles."""
        li = resident if local_iter is None else local_iter
        lo, hi = self.q_bounds_host(resident)
        steps = hi - lo + 1
        total = self.n_groups * max(steps, 0)
        return [
            (uu // steps, lo + uu % steps)
            for uu in (
                kv_index_host(self.order, li, u, total, snake_group=self.snake_group)
                for u in range(total)
            )
        ]

    def worker_assignments(
        self, n_workers: int, *, transposed: bool = False
    ) -> list[list[int]]:
        """Round-robin (grid-stride) resident assignment, paper Alg. 2 —
        folded Q rows on the forward grid, KV tiles on the transposed one."""
        if n_workers <= 0:
            raise ValueError("n_workers must be positive")
        n_residents = self.n_kv if transposed else self.grid_rows
        return [list(range(w, n_residents, n_workers)) for w in range(n_workers)]

    def wavefront(
        self, n_workers: int, *, transposed: bool = False
    ) -> Iterator[tuple[int, str, object]]:
        """Lock-step persistent-worker wavefront over the folded grid.

        The paper's execution model (Alg. 2 round-robin assignment, §3.4
        lock-step progress, Alg. 4 *worker-local* parity): at each global
        step every still-active worker issues its current access, in worker
        order. One loop serves both grids:

          forward   — residents are the ``grid_rows`` folded Q rows; yields
                      ('Q', row) on entry, ('K'|'V', kv_tile) per stream
                      step, ('O', row) at row end.
          transposed — residents are the ``n_kv`` KV tiles; yields
                      ('K'|'V', jkv) on entry, ('Q'|'dO', (group, q_tile))
                      per stream step, ('dK'|'dV', jkv) at tile end.

        Residents whose trimmed stream is empty still emit their entry/exit
        bookends (their accumulators exist; they just stream nothing).
        """
        assignments = self.worker_assignments(n_workers, transposed=transposed)
        n_w = len(assignments)
        pos = [0] * n_w
        inner = [0] * n_w
        started = [False] * n_w
        stream: list = [None] * n_w
        active = [len(a) > 0 for a in assignments]
        while any(active):
            for w, assign in enumerate(assignments):
                if not active[w]:
                    continue
                res = assign[pos[w]]
                if not started[w]:
                    if transposed:
                        yield (w, "K", res)
                        yield (w, "V", res)
                        stream[w] = self.stream_sweep(res, local_iter=pos[w])
                    else:
                        yield (w, "Q", res)
                        stream[w] = self.kv_order(res % self.n_q, local_iter=pos[w])
                    started[w] = True
                if stream[w]:
                    item = stream[w][inner[w]]
                    if transposed:
                        yield (w, "Q", item)
                        yield (w, "dO", item)
                    else:
                        yield (w, "K", item)
                        yield (w, "V", item)
                    inner[w] += 1
                if not stream[w] or inner[w] >= len(stream[w]):
                    if transposed:
                        yield (w, "dK", res)
                        yield (w, "dV", res)
                    else:
                        yield (w, "O", res)
                    inner[w] = 0
                    started[w] = False
                    pos[w] += 1
                    if pos[w] >= len(assign):
                        active[w] = False

    def stream_grid_steps(self) -> Iterator[tuple[int, int, int, bool]]:
        """Replay the transposed dK/dV grid: yields (jkv, group, q, valid)."""
        for jkv in range(self.n_kv):
            lo, hi = self.q_bounds_host(jkv)
            raw = hi - lo + 1
            steps = max(raw, 1)  # empty Q range: one always-invalid step
            total = self.n_groups * steps
            sweep = [
                kv_index_host(self.order, jkv, u, total, snake_group=self.snake_group)
                for u in range(total)
            ]
            for u in range(self.grid_rows):
                uu = sweep[min(u, total - 1)]
                qi = min(max(lo + uu % steps, 0), self.n_q - 1)
                yield jkv, uu // steps, qi, u < self.n_groups * raw


# --------------------------------------------------------------------------
# schedule wrappers (host wavefront models over the Traversal IR)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVSchedule:
    """A full traversal schedule for one attention problem instance.

    A thin host-model wrapper over :class:`Traversal` (``.traversal`` is
    the compiled object): adds the paper's persistent-worker wavefront
    (Alg. 2 round-robin assignment + §3.4 lock-step trace) on top of the
    shared order arithmetic.

    Attributes:
      order: cyclic, sawtooth, or block_snake.
      n_q / n_kv: number of Q / KV tiles.
      causal: whether causal masking trims the KV range per Q tile.
      q_block / kv_block: tile sizes (rows) — used for causal trimming and
        the cache-trace sector weighting.
      snake_group: block_snake group size (tiles); None = default.
      window: sliding-window attention — trims the *low* end of each Q
        tile's KV range (the forward-grid transpose of the BwdKVSchedule
        high-end trim).
    """

    order: Order
    n_q: int
    n_kv: int
    causal: bool = False
    q_block: int = 128
    kv_block: int = 128
    snake_group: Optional[int] = None
    window: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "order", Order.parse(self.order))
        if self.n_q <= 0 or self.n_kv <= 0:
            raise ValueError(f"empty schedule: n_q={self.n_q} n_kv={self.n_kv}")

    @property
    def traversal(self) -> Traversal:
        """The compiled IR this schedule replays."""
        return Traversal(
            order=self.order,
            n_q=self.n_q,
            n_kv=self.n_kv,
            causal=self.causal,
            window=self.window,
            q_block=self.q_block,
            kv_block=self.kv_block,
            snake_group=self.snake_group,
        )

    # ---- per-worker iteration ------------------------------------------------

    def kv_range(self, q_tile: int) -> int:
        lo, hi = self.traversal.kv_bounds_host(q_tile)
        return max(hi - lo + 1, 0)

    def kv_order(self, q_tile: int, local_iter: int | None = None) -> list[int]:
        """The sequence of KV tile ids visited for ``q_tile``.

        ``local_iter`` is the worker-local iteration parity driver; defaults
        to the q_tile id itself (single-worker view / round-robin with G
        workers keeps parity consistent per worker).
        """
        return self.traversal.kv_order(q_tile, local_iter)

    def page_order(self, parity) -> jax.Array:
        """Visit order over this schedule's KV tiles for per-row ``parity``.

        The paged-decode entry point: ``decode_attention`` builds a
        ``KVSchedule`` over the gathered pages of a block table and walks
        them in this order (sawtooth alternates per decode step, keyed on
        the cache length). Traced ``parity`` is fine; returns (B, n_kv).
        """
        return self.traversal.visit_order(parity)

    # ---- global traces (cache simulation) ------------------------------------

    def worker_assignments(self, n_workers: int) -> list[list[int]]:
        """Round-robin (grid-stride) Q-tile assignment, paper Alg. 2."""
        return self.traversal.worker_assignments(n_workers)

    def wavefront_trace(self, n_workers: int) -> Iterator[tuple[int, str, int]]:
        """Lock-step wavefront access trace: yields (worker, tensor, tile).

        Models the paper's observation (§3.4) that persistent CTAs progress in
        a largely synchronized manner: at each global step every still-active
        worker issues the access for its current (q_tile, j) position, in
        worker order. Tensors: 'Q' (once per q tile), 'K','V' per inner step,
        'O' at tile end.  Tile ids for K/V are KV tile ids; Q/O tiles use the
        q-tile id (distinct tensor namespaces — the simulator keys on
        (tensor, tile)).
        """
        yield from self.traversal.wavefront(n_workers)

    def flat_trace(self, n_workers: int = 1) -> list[tuple[str, int]]:
        """Trace without worker ids (cache sees the interleaved stream)."""
        return [(t, tile) for (_, t, tile) in self.wavefront_trace(n_workers)]

    def bwd(self, window: Optional[int] = None) -> "BwdKVSchedule":
        """The transposed (dK/dV) schedule over the same tile geometry."""
        return BwdKVSchedule(
            order=self.order,
            n_q=self.n_q,
            n_kv=self.n_kv,
            causal=self.causal,
            window=self.window if window is None else window,
            q_block=self.q_block,
            kv_block=self.kv_block,
            snake_group=self.snake_group,
        )


@dataclasses.dataclass(frozen=True)
class BwdKVSchedule:
    """Transposed traversal schedule for the dK/dV backward grid.

    In the flash backward's dK/dV pass the roles flip: each worker parks on
    one *KV* tile (accumulating dK/dV) and streams the *Q*-side operands
    (Q, dO, plus the per-row LSE/delta vectors). The cyclic-traversal L2
    pathology the paper targets therefore reappears on the Q stream —
    every KV tile revisits the full sweep of Q tiles — and the same
    reordering applies, with parity keyed on the worker-local resident
    (KV-tile) counter. Causal masking trims the *low* end of the Q range
    per KV tile (the transpose of the forward's high-end trim); a sliding
    window trims the high end. Like :class:`KVSchedule`, a host wavefront
    model over the shared :class:`Traversal` arithmetic.
    """

    order: Order
    n_q: int
    n_kv: int
    causal: bool = False
    window: Optional[int] = None
    q_block: int = 128
    kv_block: int = 128
    snake_group: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "order", Order.parse(self.order))
        if self.n_q <= 0 or self.n_kv <= 0:
            raise ValueError(f"empty schedule: n_q={self.n_q} n_kv={self.n_kv}")

    @property
    def traversal(self) -> Traversal:
        return Traversal(
            order=self.order,
            n_q=self.n_q,
            n_kv=self.n_kv,
            causal=self.causal,
            window=self.window,
            q_block=self.q_block,
            kv_block=self.kv_block,
            snake_group=self.snake_group,
        )

    # ---- per-worker iteration ------------------------------------------------

    def q_bounds(self, kv_tile: int) -> tuple[int, int]:
        return q_tile_bounds_for(
            kv_tile,
            self.n_q,
            causal=self.causal,
            window=self.window,
            q_block=self.q_block,
            kv_block=self.kv_block,
        )

    def q_range(self, kv_tile: int) -> int:
        lo, hi = self.q_bounds(kv_tile)
        return max(hi - lo + 1, 0)

    def q_order(self, kv_tile: int, local_iter: int | None = None) -> list[int]:
        """The sequence of Q tile ids streamed while parked on ``kv_tile``."""
        return self.traversal.q_order(kv_tile, local_iter)

    # ---- global traces (cache simulation) ------------------------------------

    def worker_assignments(self, n_workers: int) -> list[list[int]]:
        """Round-robin KV-tile assignment (the resident tile of this grid)."""
        return self.traversal.worker_assignments(n_workers, transposed=True)

    def wavefront_trace(self, n_workers: int) -> Iterator[tuple[int, str, int]]:
        """Lock-step wavefront trace of the dK/dV grid.

        Tensors: 'K','V' once per resident KV tile, 'Q','dO' per inner
        step (Q-stream tile ids), 'dK','dV' written at tile end. Parity is
        the worker-local resident counter, mirroring
        :meth:`KVSchedule.wavefront_trace`.
        """
        for w, tensor, key in self.traversal.wavefront(n_workers, transposed=True):
            # G=1 here: unwrap the (group, q_tile) stream keys to plain ids.
            yield (w, tensor, key[1] if tensor in ("Q", "dO") else key)

    def flat_trace(self, n_workers: int = 1) -> list[tuple[str, int]]:
        return [(t, tile) for (_, t, tile) in self.wavefront_trace(n_workers)]


def bwd_kv_schedule(
    order: Order | str,
    n_q: int,
    n_kv: int,
    *,
    causal: bool = False,
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
    snake_group: Optional[int] = None,
) -> BwdKVSchedule:
    """Build the transposed (dK/dV) schedule directly from grid geometry."""
    return BwdKVSchedule(
        order=Order.parse(order),
        n_q=n_q,
        n_kv=n_kv,
        causal=causal,
        window=window,
        q_block=q_block,
        kv_block=kv_block,
        snake_group=snake_group,
    )


def tile_ids(seq_len: int, block: int) -> int:
    """Number of tiles covering ``seq_len`` rows with ``block``-row tiles."""
    return -(-seq_len // block)
