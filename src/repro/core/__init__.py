"""Core: the paper's contribution — sawtooth KV scheduling + cache analysis."""

from repro.core.schedule import (
    BwdKVSchedule,
    KVSchedule,
    Order,
    bwd_kv_schedule,
    kv_index,
    kv_index_host,
)
from repro.core.cache_model import (
    GB10,
    TPU_V5E_DMA,
    AttentionWorkload,
    HWConfig,
)
from repro.core.cache_sim import SimResult, simulate_attention, simulate_trace
from repro.core.attention import (
    decode_attention,
    flash_attention,
    flash_attention_bwd,
    mha_reference,
)

__all__ = [
    "BwdKVSchedule",
    "KVSchedule",
    "Order",
    "bwd_kv_schedule",
    "kv_index",
    "kv_index_host",
    "GB10",
    "TPU_V5E_DMA",
    "AttentionWorkload",
    "HWConfig",
    "SimResult",
    "simulate_attention",
    "simulate_trace",
    "decode_attention",
    "flash_attention",
    "flash_attention_bwd",
    "mha_reference",
]
