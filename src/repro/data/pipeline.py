"""Deterministic synthetic LM data pipeline with packing + host prefetch.

Production shape without production data: token streams are generated from a
counter-based RNG keyed on (seed, host, step) so every host produces its own
disjoint shard deterministically — restartable from any step with no state
file (exactly how a real sharded webdataset reader would be keyed), which is
what checkpoint-resume and elastic re-mesh rely on.

Documents get Zipf-ish token statistics and geometric lengths, packed
into fixed-length rows with EOS separators (no padding waste). A background
thread keeps a small prefetch queue ahead of the training loop.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticPacked", "make_batch_iterator"]

EOS = 1


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    host_index: int = 0
    host_count: int = 1


class SyntheticPacked:
    """Deterministic packed-batch source; index-addressable by step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.host_count:
            raise ValueError("global_batch must divide across hosts")
        self.per_host = cfg.global_batch // cfg.host_count

    def _rng(self, step: int) -> np.random.Generator:
        c = self.cfg
        seed_seq = np.random.SeedSequence(
            entropy=c.seed, spawn_key=(c.host_index, step)
        )
        return np.random.Generator(np.random.Philox(seed_seq))

    def batch(self, step: int) -> dict:
        """Tokens (per_host_batch, seq_len) int32, packed documents."""
        c = self.cfg
        rng = self._rng(step)
        rows = np.empty((self.per_host, c.seq_len), np.int32)
        for r in range(self.per_host):
            row = []
            while len(row) < c.seq_len:
                doc_len = 1 + min(
                    int(rng.geometric(1.0 / c.mean_doc_len)), 4 * c.mean_doc_len
                )
                # Zipf-ish: squash uniform^2 toward frequent ids; ids 0/1 reserved
                u = rng.random(doc_len)
                toks = 2 + (u * u * (c.vocab - 2)).astype(np.int64)
                row.extend(toks.tolist())
                row.append(EOS)
            rows[r] = np.asarray(row[: c.seq_len], np.int32)
        return {"tokens": rows}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_iterator(
    cfg: DataConfig, *, start_step: int = 0, prefetch: int = 2
) -> Iterator[dict]:
    """Background-thread prefetching iterator, resumable at ``start_step``."""
    src = SyntheticPacked(cfg)
    q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            try:
                q.put(src.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
