from repro.data.pipeline import DataConfig, SyntheticPacked, make_batch_iterator

__all__ = ["DataConfig", "SyntheticPacked", "make_batch_iterator"]
