"""Sharding specs: divisibility tightening + path-pattern parameter rules.

The contract with the rest of the codebase is *pattern + divisibility*:

  1. Leaf path names decide where a tensor would like to live on the mesh
     (Megatron-style: TP on the head/expert-ffn dim of input projections,
     TP on the contraction dim of output projections, FSDP on the other
     matrix dim, vocab-sharded embeddings).
  2. :func:`tighten` then drops every mesh axis that does not evenly divide
     its dim, so the same rules serve full production configs, tiny
     ``.reduced()`` CPU configs, GQA head counts smaller than the TP degree,
     and factored optimizer statistics (whose shapes are params with a dim
     reduced away).

Everything here works on both real ``Mesh``es and ``AbstractMesh`` — spec
computation allocates nothing and needs no devices, which is what lets the
512-chip dry-run and the 1-CPU test suite share one code path.
"""

from __future__ import annotations

import re
from typing import Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig

__all__ = [
    "tighten",
    "spec_for",
    "param_specs",
    "param_shardings",
    "batch_spec",
    "batch_shardings",
    "cache_shardings",
]


# --------------------------------------------------------------------------
# divisibility tightening
# --------------------------------------------------------------------------


def _mesh_sizes(mesh) -> dict[str, int]:
    """Axis name -> size for Mesh and AbstractMesh alike."""
    return dict(mesh.shape)


def _as_tuple(entry) -> tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def _collapse(names: tuple[str, ...]):
    """P((), ) -> None, P(('a',)) -> 'a' so specs compare cleanly."""
    if not names:
        return None
    if len(names) == 1:
        return names[0]
    return names


def tighten(shape: Sequence[int], spec: Sequence, mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim.

    ``spec`` has one entry per dim of ``shape``; each entry is an axis name,
    a tuple of axis names (multi-axis sharding — the longest *prefix* whose
    combined size divides the dim is kept), or None. Axes absent from the
    mesh, or already consumed by an earlier dim, are dropped too. The result
    always has exactly ``len(shape)`` entries so consumers can zip it
    against shapes.
    """
    if len(spec) != len(shape):
        raise ValueError(f"spec {tuple(spec)!r} does not match shape {tuple(shape)!r}")
    sizes = _mesh_sizes(mesh)
    out = []
    used: set[str] = set()
    for dim, entry in zip(shape, spec):
        names = tuple(a for a in _as_tuple(entry) if a in sizes and a not in used)
        keep: tuple[str, ...] = ()
        prod = 1
        for a in names:
            prod *= sizes[a]
            if dim % prod:
                break
            keep = keep + (a,)
        used.update(keep)
        out.append(_collapse(keep))
    return P(*out)


# --------------------------------------------------------------------------
# parameter rules
# --------------------------------------------------------------------------

# Column-parallel projections: (.., d_in, d_out) with d_out the TP dim.
_TP_OUT_COL = r"(?:wq|wk|wv|w_gate|w_up|in_proj|proj_in|vision_proj|lm_head)"
# Row-parallel projections: (.., d_in, d_out) with d_in the TP dim.
_TP_IN_ROW = r"(?:wo|w_down|out_proj)"

# (pattern, trailing-dims spec). Entries: "fsdp" -> pcfg.fsdp_axes (tuple,
# prefix-tightened), "tp" -> pcfg.tensor_axis, None -> replicated. The spec
# aligns to the *last* len(spec) dims; leading dims (scan-stacked layers,
# hybrid groups, experts) are replicated unless a rule says otherwise.
_RULES: list[tuple[re.Pattern, tuple]] = [
    (re.compile(r"embed/table$"), ("tp", "fsdp")),
    (re.compile(_TP_OUT_COL + r"(?:/w)?$"), ("fsdp", "tp")),
    (re.compile(_TP_OUT_COL + r"/b$"), ("tp",)),
    (re.compile(_TP_IN_ROW + r"(?:/w)?$"), ("tp", "fsdp")),
    (re.compile(_TP_IN_ROW + r"/b$"), ("fsdp",)),
    (re.compile(r"router(?:/w)?$"), ("fsdp", None)),  # router stays f32/replicated-out
    (re.compile(r"router/b$"), (None,)),
    (re.compile(r"conv_w$"), (None, "tp")),  # depthwise conv: channel dim
]

# Fallback for everything else (norm scales, biases, SSM scalars, factored
# optimizer row/col stats): ZeRO-style shard of the trailing dim over the
# FSDP axes; tighten silently replicates the small/odd ones.
_FALLBACK = ("fsdp",)


def _path_str(path) -> str:
    """'layers/attn/wq/w' from a jax key path."""
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
    )


def _resolve(entry, pcfg: ParallelConfig):
    if entry == "fsdp":
        return tuple(pcfg.fsdp_axes)
    if entry == "tp":
        return pcfg.tensor_axis
    return entry


def spec_for(path: str, shape: Sequence[int], pcfg: ParallelConfig, mesh) -> P:
    """PartitionSpec for one parameter leaf (full rank, tightened)."""
    rank = len(shape)
    trailing: tuple = _FALLBACK
    for pat, rule in _RULES:
        if pat.search(path):
            trailing = rule
            break
    trailing = trailing[max(0, len(trailing) - rank):]
    full = (None,) * (rank - len(trailing)) + tuple(
        _resolve(e, pcfg) for e in trailing
    )
    return tighten(shape, full, mesh)


def param_specs(params, pcfg: ParallelConfig, mesh):
    """Tree of PartitionSpecs matching ``params`` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: spec_for(_path_str(path), x.shape, pcfg, mesh), params
    )


def param_shardings(params, pcfg: ParallelConfig, mesh):
    """Tree of NamedShardings matching ``params``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, pcfg, mesh)
    )


# --------------------------------------------------------------------------
# batches
# --------------------------------------------------------------------------


def batch_spec(global_batch: int, pcfg: ParallelConfig, mesh) -> P:
    """Spec for the leading batch dim: data axes, tightened (batch=1 on a
    16-way data mesh falls back to replication rather than erroring)."""
    axes = tuple(a for a in pcfg.data_axes if a in _mesh_sizes(mesh))
    return tighten((global_batch,), (axes,), mesh)


def batch_shardings(batch, pcfg: ParallelConfig, mesh):
    """Batch-dim sharding for every leaf of a batch pytree."""

    def leaf(x):
        rank = len(x.shape)
        if rank == 0:
            return NamedSharding(mesh, P())
        b = batch_spec(x.shape[0], pcfg, mesh)[0]
        return NamedSharding(mesh, P(b, *([None] * (rank - 1))))

    return jax.tree.map(leaf, batch)


# --------------------------------------------------------------------------
# KV caches / decode state
# --------------------------------------------------------------------------


def cache_shardings(caches, pcfg: ParallelConfig, mesh):
    """Shardings for serving caches (stacked (L, B, S, H[, hd]) layout).

    Batch dim goes on the data axes. KV heads go on the tensor axis when
    the head count divides it; GQA head counts that don't (hkv < TP degree)
    fall back to sharding the *sequence* dim on the tensor axis — decode
    attention reduces over sequence, so GSPMD turns that into a cheap
    per-step reduce instead of replicating multi-GB caches. SSM decode
    state ('conv'/'ssd' leaves) shards its batch dim; scalars ('len',
    'kv_len') replicate.
    """
    sizes = _mesh_sizes(mesh)
    data_axes = tuple(a for a in pcfg.data_axes if a in sizes)
    tp = pcfg.tensor_axis if pcfg.tensor_axis in sizes else None

    def batch_entry(dim: int):
        return tighten((dim,), (data_axes,), mesh)[0]

    def leaf(path, x):
        name = _path_str(path).rsplit("/", 1)[-1]
        shape = x.shape
        rank = len(shape)
        spec = [None] * rank
        if name in ("k", "v", "k_scale", "v_scale"):
            h_dim = rank - 2 if name in ("k", "v") else rank - 1
            s_dim, b_dim = h_dim - 1, h_dim - 2
            if b_dim >= 0:
                spec[b_dim] = batch_entry(shape[b_dim])
                if tp is not None and shape[h_dim] % sizes[tp] == 0:
                    spec[h_dim] = tp
                elif tp is not None and shape[s_dim] % sizes[tp] == 0:
                    spec[s_dim] = tp
        elif name == "conv" and rank >= 3:  # (.., B, width-1, channels)
            spec[rank - 3] = batch_entry(shape[rank - 3])
        elif name == "ssd" and rank >= 4:  # (.., B, H, P, N)
            spec[rank - 4] = batch_entry(shape[rank - 4])
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, caches)
