"""``repro.dist`` — the distribution subsystem.

Three orthogonal pieces, consumed by every layer of the stack:

  * :mod:`repro.dist.sharding` — PartitionSpec computation for parameters,
    batches and KV caches: path-pattern rules + divisibility tightening, so
    the same code serves full production configs, ``.reduced()`` CPU smoke
    configs, and abstract (device-free) dry-run meshes.
  * :mod:`repro.dist.context` — context-local activation-sharding rules;
    model code calls ``constrain(x, role)`` which is a no-op unless a rules
    context is installed (CPU paths stay clean).
  * :mod:`repro.dist.compression` — blockwise int8 quantization and
    error-feedback compressed gradient all-reduce for cheap cross-device
    training.
"""

from repro.dist import compression, context, sharding
from repro.dist.compression import (
    dequantize_int8,
    init_residuals,
    quantize_int8,
    reduce_grads_compressed,
)
from repro.dist.context import activation_rules, constrain
from repro.dist.sharding import (
    batch_shardings,
    batch_spec,
    cache_shardings,
    param_shardings,
    param_specs,
    spec_for,
    tighten,
)

__all__ = [
    "sharding",
    "context",
    "compression",
    "tighten",
    "spec_for",
    "param_specs",
    "param_shardings",
    "batch_spec",
    "batch_shardings",
    "cache_shardings",
    "activation_rules",
    "constrain",
    "quantize_int8",
    "dequantize_int8",
    "init_residuals",
    "reduce_grads_compressed",
]
