"""Context-local activation-sharding rules.

Model code annotates intermediate activations by *role*::

    h = constrain(h, "residual")          # transformer residual stream
    buf = constrain(buf, "moe_buffer")    # (E, C, d) dispatch buffer
    x = constrain(x, "moe_tokens")        # dropless sorted token stream

Outside an :func:`activation_rules` context — unit tests, CPU smoke runs,
single-device serving — ``constrain`` is an exact no-op, so the model code
carries no distribution dependency on those paths. Inside one (the dry-run,
sequence-sharded training), roles present in the rules dict are lowered to
``with_sharding_constraint`` so GSPMD keeps the annotated layout instead of
re-deriving it per-op. Unknown roles are ignored: a rules dict only needs
to name the activations it cares about.
"""

from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Mapping, Optional

import jax

__all__ = ["activation_rules", "constrain", "current_rules"]

# role -> PartitionSpec | NamedSharding. ContextVar (not a module global) so
# rules stay scoped under async/threaded drivers.
_RULES: ContextVar[Optional[Mapping[str, object]]] = ContextVar(
    "activation_rules", default=None
)


def current_rules() -> Optional[Mapping[str, object]]:
    """The active role->spec mapping, or None when no context is installed."""
    return _RULES.get()


@contextlib.contextmanager
def activation_rules(rules: Optional[Mapping[str, object]]):
    """Install ``rules`` for the dynamic extent of the block.

    ``rules=None`` (or ``{}``) explicitly disables constraining — callers can
    pass a computed-or-None value without branching. Nesting replaces (does
    not merge) the outer rules.
    """
    token = _RULES.set(dict(rules) if rules else None)
    try:
        yield
    finally:
        _RULES.reset(token)


def constrain(x: jax.Array, role: str) -> jax.Array:
    """Apply the sharding rule registered for ``role`` to ``x``, if any.

    Bare ``PartitionSpec`` rules resolve against the ambient mesh (the
    caller's ``jax.set_mesh`` block); ``NamedSharding`` rules carry their
    own mesh. No-op when no rules context is active or the role is unlisted.
    """
    rules = _RULES.get()
    if not rules:
        return x
    spec = rules.get(role)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
