"""Int8 compression for gradients and cross-device collectives.

``quantize_int8`` is blockwise symmetric: the flattened tensor is split into
fixed-size blocks, each carrying one f32 scale = max|x|/127, so the
elementwise error is bounded by scale/2 (and every block scale is bounded by
the tensor's global scale).

``reduce_grads_compressed`` is an error-feedback compressed mean all-reduce
(the 1-bit-Adam / EF-SGD family, arXiv:2102.02888): each device quantizes
(grad + carried residual), the quantized values are mean-reduced, and each
device keeps its local quantization error as the next step's residual — so
the compression error is fed back rather than accumulated. Note this
implementation reproduces the *numerics* of the compressed exchange (the
reduce itself is an f32 ``pmean`` of the dequantized values, which XLA's
replication checker can verify); a bandwidth-optimal deployment would
all-gather the int8 payload + scales (~4x less wire traffic) and average
after dequantizing, which is bit-identical to what is computed here.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "BLOCK",
    "quantize_int8",
    "dequantize_int8",
    "quantize_int8_vec",
    "dequantize_int8_vec",
    "init_residuals",
    "reduce_grads_compressed",
]

# 256 int8 payload bytes + one f32 scale per block: ~1.6% scale overhead.
BLOCK = 256


def quantize_int8(x: jax.Array, *, block: int = BLOCK):
    """Blockwise symmetric int8. Any shape -> (q (nb, block) i8, scale (nb,) f32).

    The tensor is flattened and zero-padded to a whole number of blocks;
    all-zero blocks get scale 1.0 so dequantization is well-defined.
    """
    xf = jnp.ravel(x).astype(jnp.float32)
    pad = (-xf.size) % block
    if pad:
        xf = jnp.pad(xf, (0, pad))
    xb = xf.reshape(-1, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    """Inverse of :func:`quantize_int8`; ``shape`` trims the block padding."""
    flat = (q.astype(jnp.float32) * scale[..., None]).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape).astype(dtype)


def quantize_int8_vec(x: jax.Array):
    """Structure-preserving symmetric int8 over the last axis.

    ``x`` (..., D) -> (q (..., D) i8, scale (...,) f32), one scale per
    trailing vector. This is the KV-cache variant (one scale per
    token-head vector keeps the cache's logical shape, so sharding rules
    and paged layouts apply unchanged); :func:`quantize_int8` is the
    flat blockwise wire-format variant for collectives.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8_vec(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`quantize_int8_vec`."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_residuals(grads):
    """Zero error-feedback residuals, one f32 leaf per gradient leaf.

    Same shapes as the gradients themselves — in stacked data-parallel
    layouts the leading dim is the per-device axis, and each device's shard
    carries that device's residual.
    """
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def reduce_grads_compressed(grads, residuals, axis_name: str, *, block: int = BLOCK):
    """Error-feedback int8 mean all-reduce over a bound mesh axis.

    Must run inside ``shard_map``/``pmap`` where ``axis_name`` is bound.
    Returns ``(reduced, new_residuals)``: ``reduced`` is the across-axis
    mean of the dequantized gradients (identical on every device, so it can
    be emitted with a replicated out_spec), ``new_residuals`` is each
    device's local quantization error to carry into the next step.
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, s = quantize_int8(gf, block=block)
        local = dequantize_int8(q, s, g.shape, jnp.float32)
        new_r = gf - local
        # Mean of the per-device *dequantized* values — numerically identical
        # to gathering the int8 payload and averaging after dequantization
        # (the bandwidth-optimal wire format), but expressed as a pmean so
        # shard_map can statically prove the output is replicated.
        out = jax.lax.pmean(local, axis_name).astype(g.dtype)
        return out, new_r

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_flatten(residuals)[0]
    pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = jax.tree_util.tree_unflatten(tree, [o for o, _ in pairs])
    new_res = jax.tree_util.tree_unflatten(tree, [r for _, r in pairs])
    return reduced, new_res
