"""Family dispatch: one ``LM`` object per architecture config.

API (used by train/serve/launch):

  lm = build_model(cfg)
  params                    = lm.init(key)
  loss, metrics             = lm.loss(params, batch)
  logits, caches            = lm.prefill(params, batch, max_len)
  logits, caches            = lm.decode_step(params, tokens, caches)
  batch                     = lm.input_specs(shape_cfg)   # ShapeDtypeStructs

Batch dict contents per family (all synthesizable by data.pipeline and by
``input_specs`` for the dry-run):
  dense/moe/ssm/hybrid: {"tokens": (B, S) i32}
  vlm:    {"tokens": (B, S - P) i32, "prefix_embeds": (B, P, d)}
  encdec: {"src_embeds": (B, S, d), "tgt_tokens": (B, S) i32}
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import transformer as T

__all__ = ["LM", "build_model"]

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    input_specs: Callable


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------


def _embed_tokens(params, cfg, tokens):
    return params["embed"]["table"].astype(cfg.activation_dtype())[tokens]


def _logits(params, cfg, h):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cfg.activation_dtype()).T
        out = h @ w
    else:
        out = L.dense(params["lm_head"], h, dtype=cfg.activation_dtype())
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = jnp.tanh(out / c) * c
    return out


def _head_init(key, cfg):
    ke, kh = L.split_keys(key, 2)
    pd = cfg.parameter_dtype()
    p = {"embed": L.embed_init(ke, cfg.vocab, cfg.d_model, pd)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab, dtype=pd)
    return p


def _lm_loss(params, cfg, tokens, h, *, mask=None, aux=0.0, z_loss=1e-4):
    """Next-token CE over h (B,S,d) vs tokens (B,S)."""
    logits = _logits(params, cfg, h[:, :-1])
    labels = tokens[:, 1:]
    m = None if mask is None else mask[:, 1:]
    loss, metrics = L.cross_entropy(logits, labels, m, z_loss=z_loss)
    loss = loss + aux
    metrics["aux_loss"] = jnp.asarray(aux, jnp.float32)
    metrics["total_loss"] = loss
    return loss, metrics


def _positions(b, s):
    return jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))


# --------------------------------------------------------------------------
# decoder-only families (dense / moe / vlm)
# --------------------------------------------------------------------------


def _ffn_fn_for(cfg: ModelConfig, *, serve: bool = False):
    if cfg.family == "moe" or (cfg.moe is not None):
        dropless = serve and cfg.moe_serve_dropless
        return lambda p, c, h: MOE.moe_apply(p, c, h, dropless=dropless)
    return None


def _ffn_init_for(cfg: ModelConfig):
    if cfg.moe is not None:
        return lambda k: MOE.moe_init(k, cfg)
    return None


def _build_decoder_only(cfg: ModelConfig) -> LM:
    ffn_fn = _ffn_fn_for(cfg)
    ffn_fn_serve = _ffn_fn_for(cfg, serve=True)
    ffn_init = _ffn_init_for(cfg)
    is_vlm = cfg.family == "vlm"

    def init(key):
        kh, ks, kp = L.split_keys(key, 3)
        p = _head_init(kh, cfg)
        p["layers"] = T.stack_init(ks, cfg, cfg.n_layers, ffn_init_fn=ffn_init)
        p["ln_f"] = L.rmsnorm_init(cfg.d_model, cfg.parameter_dtype())
        if is_vlm:
            p["vision_proj"] = L.dense_init(kp, cfg.d_model, cfg.d_model, dtype=cfg.parameter_dtype())
        return p

    def _embed_batch(params, batch):
        tokens = batch["tokens"]
        x = _embed_tokens(params, cfg, tokens)
        mask = jnp.ones(tokens.shape, jnp.float32)
        if is_vlm:
            pe = L.dense(params["vision_proj"], batch["prefix_embeds"], dtype=cfg.activation_dtype())
            x = jnp.concatenate([pe, x], axis=1)
            pad = jnp.zeros((tokens.shape[0], pe.shape[1]), tokens.dtype)
            tokens = jnp.concatenate([pad, tokens], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(pe.shape[:2], jnp.float32), mask], axis=1
            )
        return x, tokens, mask

    def loss(params, batch):
        x, tokens, mask = _embed_batch(params, batch)
        b, s, _ = x.shape
        h, aux = T.stack_apply(
            params["layers"], cfg, x, _positions(b, s), causal=True, ffn_apply_fn=ffn_fn
        )
        h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return _lm_loss(params, cfg, tokens, h, mask=mask, aux=aux)

    def prefill(params, batch, max_len):
        x, tokens, _ = _embed_batch(params, batch)
        b, s, _ = x.shape
        h, caches = T.stack_prefill(
            params["layers"], cfg, x, _positions(b, s), max_len, ffn_apply_fn=ffn_fn_serve
        )
        h = L.rmsnorm(params["ln_f"], h[:, -1:], cfg.norm_eps)
        return _logits(params, cfg, h), caches

    def decode_step(params, tokens, caches):
        x = _embed_tokens(params, cfg, tokens)  # (B, 1)
        h, caches = T.stack_decode(
            params["layers"], cfg, x, caches, ffn_apply_fn=ffn_fn_serve
        )
        h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return _logits(params, cfg, h), caches

    def input_specs(shape: ShapeConfig, reduced: bool = False):
        c = cfg.reduced() if reduced else cfg
        sh = shape.reduced() if reduced else shape
        b, s = sh.global_batch, sh.seq_len
        dt = c.activation_dtype()
        if is_vlm:
            p = min(c.n_prefix_embeds, max(s // 4, 1))
            return {
                "tokens": jax.ShapeDtypeStruct((b, s - p), jnp.int32),
                "prefix_embeds": jax.ShapeDtypeStruct((b, p, c.d_model), dt),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}

    return LM(cfg, init, loss, prefill, decode_step, input_specs)


# --------------------------------------------------------------------------
# SSM / hybrid families
# --------------------------------------------------------------------------


def _build_ssm(cfg: ModelConfig) -> LM:
    hybrid = cfg.family == "hybrid"

    def init(key):
        kh, ks = L.split_keys(key, 2)
        p = _head_init(kh, cfg)
        if hybrid:
            p["layers"] = HY.hybrid_init(ks, cfg)
        else:
            keys = jnp.stack(L.split_keys(ks, cfg.n_layers))
            p["layers"] = jax.vmap(
                lambda k: {
                    "ln": L.rmsnorm_init(cfg.d_model, cfg.parameter_dtype()),
                    "mamba": SSM.mamba_init(k, cfg),
                }
            )(keys)
        p["ln_f"] = L.rmsnorm_init(cfg.d_model, cfg.parameter_dtype())
        return p

    def _backbone(params, cfg_, x, positions):
        if hybrid:
            return HY.hybrid_apply(params["layers"], cfg_, x, positions)

        def body(h, lp):
            out = SSM.mamba_apply(lp["mamba"], cfg_, L.rmsnorm(lp["ln"], h, cfg_.norm_eps))
            return h + out, None

        body = T.remat_wrap(body, cfg_)
        h, _ = T.layer_scan(cfg_, body, x, params["layers"])
        return h, jnp.zeros(())

    def loss(params, batch):
        tokens = batch["tokens"]
        x = _embed_tokens(params, cfg, tokens)
        b, s, _ = x.shape
        h, aux = _backbone(params, cfg, x, _positions(b, s))
        h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return _lm_loss(params, cfg, tokens, h, aux=aux)

    def prefill(params, batch, max_len):
        tokens = batch["tokens"]
        x = _embed_tokens(params, cfg, tokens)
        b, s, _ = x.shape
        if hybrid:
            h, caches = HY.hybrid_prefill(params["layers"], cfg, x, _positions(b, s), max_len)
        else:

            def body(h, lp):
                out, st = SSM.mamba_prefill(lp["mamba"], cfg, L.rmsnorm(lp["ln"], h, cfg.norm_eps))
                return h + out, st

            h, states = T.layer_scan(cfg, body, x, params["layers"])
            caches = {"mamba": states, "len": jnp.asarray(s, jnp.int32)}
        h = L.rmsnorm(params["ln_f"], h[:, -1:], cfg.norm_eps)
        return _logits(params, cfg, h), caches

    def decode_step(params, tokens, caches):
        x = _embed_tokens(params, cfg, tokens)
        if hybrid:
            h, caches = HY.hybrid_decode(params["layers"], cfg, x, caches)
        else:

            def body(h, sc):
                lp, st = sc
                out, st = SSM.mamba_decode(lp["mamba"], cfg, L.rmsnorm(lp["ln"], h, cfg.norm_eps), st)
                return h + out, st

            h, states = T.layer_scan(cfg, body, x, (params["layers"], caches["mamba"]))
            caches = {"mamba": states, "len": caches["len"] + 1}
        h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return _logits(params, cfg, h), caches

    def input_specs(shape: ShapeConfig, reduced: bool = False):
        sh = shape.reduced() if reduced else shape
        return {"tokens": jax.ShapeDtypeStruct((sh.global_batch, sh.seq_len), jnp.int32)}

    return LM(cfg, init, loss, prefill, decode_step, input_specs)


# --------------------------------------------------------------------------
# encoder-decoder family
# --------------------------------------------------------------------------


def _build_encdec(cfg: ModelConfig) -> LM:
    def init(key):
        kh, ks = L.split_keys(key, 2)
        p = _head_init(kh, cfg)
        p.update(ED.encdec_init(ks, cfg))
        p["ln_f"] = L.rmsnorm_init(cfg.d_model, cfg.parameter_dtype())
        return p

    def loss(params, batch):
        enc_out = ED.encode(params, cfg, batch["src_embeds"].astype(cfg.activation_dtype()))
        tgt = batch["tgt_tokens"]
        x = _embed_tokens(params, cfg, tgt)
        h = ED.decode_train(params, cfg, x, enc_out)
        h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return _lm_loss(params, cfg, tgt, h)

    def prefill(params, batch, max_len):
        enc_out = ED.encode(params, cfg, batch["src_embeds"].astype(cfg.activation_dtype()))
        tgt = batch["tgt_tokens"]
        x = _embed_tokens(params, cfg, tgt)
        h, caches = ED.encdec_prefill(params, cfg, x, enc_out, max_len)
        h = L.rmsnorm(params["ln_f"], h[:, -1:], cfg.norm_eps)
        return _logits(params, cfg, h), caches

    def decode_step(params, tokens, caches):
        x = _embed_tokens(params, cfg, tokens)
        h, caches = ED.encdec_decode(params, cfg, x, caches)
        h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
        return _logits(params, cfg, h), caches

    def input_specs(shape: ShapeConfig, reduced: bool = False):
        c = cfg.reduced() if reduced else cfg
        sh = shape.reduced() if reduced else shape
        b, s = sh.global_batch, sh.seq_len
        return {
            "src_embeds": jax.ShapeDtypeStruct((b, s, c.d_model), c.activation_dtype()),
            "tgt_tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }

    return LM(cfg, init, loss, prefill, decode_step, input_specs)


# --------------------------------------------------------------------------


def build_model(cfg: ModelConfig) -> LM:
    if cfg.family in ("dense", "moe", "vlm"):
        return _build_decoder_only(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return _build_ssm(cfg)
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    raise ValueError(f"unknown family {cfg.family!r}; expected one of {FAMILIES}")
