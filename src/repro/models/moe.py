"""Top-k routed MoE FFN (GShard/Mixtral-style, capacity-based, static shapes).

Dispatch uses a scatter into an (E, C, d) expert buffer and a gather back —
fully static shapes so it lowers cleanly under pjit; with experts sharded on
the 'model' axis GSPMD materializes the dispatch/combine as all-to-all-class
collectives (the dominant collective term for the MoE archs, see
EXPERIMENTS.md §Roofline).

Aux losses: load-balance (Switch-style over full softmax probs × dispatch
fractions) + router z-loss; returned as a scalar the caller folds into the
training loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.context import constrain
from repro.models import layers as L

__all__ = ["moe_init", "moe_apply", "expert_capacity"]


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(n_tokens * m.top_k / m.num_experts * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # pad to a multiple of 8


def moe_init(key, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_ff_expert, m.num_experts
    kr, kg, ku, kd = L.split_keys(key, 4)
    pd = cfg.parameter_dtype()
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    return {
        "router": L.dense_init(kr, d, e, dtype=jnp.float32),
        "w_gate": (jax.random.normal(kg, (e, d, ff)) * s_in).astype(pd),
        "w_up": (jax.random.normal(ku, (e, d, ff)) * s_in).astype(pd),
        "w_down": (jax.random.normal(kd, (e, ff, d)) * s_out).astype(pd),
    }


def moe_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, *, dropless: bool = False
) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    dropless=True uses the sort + ``lax.ragged_dot`` grouped-GEMM path (no
    capacity, no token dropping) — the serving configuration. Training uses
    the capacity path (GShard-style) whose static buffer shapes shard
    predictably under pjit.
    """
    if dropless:
        return _moe_dropless(p, cfg, x)
    m = cfg.moe
    dt = cfg.activation_dtype()
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    cap = expert_capacity(t, cfg)

    xf = x.reshape(t, d)
    logits = L.dense(p["router"], xf.astype(jnp.float32))  # (T, E) f32 router
    probs = jax.nn.softmax(logits, axis=-1)

    top_logits, sel = jax.lax.top_k(logits, k)  # (T, k)
    weights = jax.nn.softmax(top_logits, axis=-1).astype(jnp.float32)

    # --- flat assignment stream (token-major priority) ---------------------
    e_flat = sel.reshape(-1)  # (T*k,)
    w_flat = weights.reshape(-1)
    oh = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # (T*k, E)
    pos_all = jnp.cumsum(oh, axis=0) - 1
    pos = jnp.take_along_axis(pos_all, e_flat[:, None], axis=1)[:, 0]  # (T*k,)
    keep = (pos < cap).astype(jnp.float32)
    pos_c = jnp.minimum(pos, cap - 1)

    # --- dispatch: scatter tokens into (E, C, d) buffers --------------------
    x_rep = jnp.repeat(xf, k, axis=0).astype(dt)  # (T*k, d)
    buf = jnp.zeros((e, cap, d), dt)
    buf = buf.at[e_flat, pos_c].add(x_rep * keep[:, None].astype(dt))
    buf = constrain(buf, "moe_buffer")

    # --- expert SwiGLU -------------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))

    # --- combine: gather back, weight, reduce over k -------------------------
    y_flat = y_buf[e_flat, pos_c] * (w_flat * keep)[:, None].astype(dt)
    y = y_flat.reshape(t, k, d).sum(axis=1).reshape(b, s, d)

    # --- aux losses -----------------------------------------------------------
    me = probs.mean(axis=0)                                   # (E,) mean router prob
    ce = oh.astype(jnp.float32).mean(axis=0) * (1.0 / k) * e  # dispatch fraction
    load_balance = e * jnp.sum(me * ce) / e                   # Switch aux (≈1 when uniform)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = m.aux_loss_coef * load_balance + m.router_z_coef * z
    return y.astype(x.dtype), aux


def _moe_dropless(p: dict, cfg: ModelConfig, x: jax.Array):
    """Dropless grouped-GEMM MoE (vLLM/MegaBlocks-style) via lax.ragged_dot."""
    m = cfg.moe
    dt = cfg.activation_dtype()
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k

    xf = x.reshape(t, d)
    logits = L.dense(p["router"], xf.astype(jnp.float32))
    top_logits, sel = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(top_logits, axis=-1)

    e_flat = sel.reshape(-1)
    w_flat = weights.reshape(-1)
    order = jnp.argsort(e_flat)  # stable in jnp
    inv = jnp.argsort(order)
    x_sorted = constrain(jnp.repeat(xf, k, axis=0)[order].astype(dt), "moe_tokens")
    group_sizes = jnp.bincount(e_flat, length=e).astype(jnp.int32)

    g = jax.lax.ragged_dot(x_sorted, p["w_gate"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(x_sorted, p["w_up"].astype(dt), group_sizes)
    h = jax.nn.silu(g) * u
    y_sorted = jax.lax.ragged_dot(h, p["w_down"].astype(dt), group_sizes)

    y_flat = y_sorted[inv] * w_flat[:, None].astype(dt)
    y = y_flat.reshape(t, k, d).sum(axis=1).reshape(b, s, d)
    return y.astype(x.dtype), jnp.zeros((), jnp.float32)
