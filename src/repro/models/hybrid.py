"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block
applied every k layers (arXiv:2411.15242).

The shared block takes concat(hidden, initial_embedding) through a down
projection (the Zamba concat trick), runs GQA attention + SwiGLU with shared
parameters at every application site, and adds back to the residual stream.
Per-invocation LoRA deltas from the paper are omitted (DESIGN.md §5).

Layers are scanned in groups of ``shared_attn_every`` Mamba blocks followed
by one shared-block application; each application site keeps its own KV
cache during serving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.context import constrain
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T

__all__ = [
    "hybrid_init",
    "hybrid_apply",
    "hybrid_prefill",
    "hybrid_decode",
    "hybrid_init_caches",
    "n_groups",
]


def n_groups(cfg: ModelConfig) -> int:
    every = cfg.ssm.shared_attn_every
    assert every > 0 and cfg.n_layers % every == 0, (cfg.n_layers, every)
    return cfg.n_layers // every


def _mamba_layer_init(key, cfg: ModelConfig) -> dict:
    kl, km = L.split_keys(key, 2)
    return {
        "ln": L.rmsnorm_init(cfg.d_model, cfg.parameter_dtype()),
        "mamba": ssm.mamba_init(km, cfg),
    }


def hybrid_init(key, cfg: ModelConfig) -> dict:
    k_layers, k_sh_in, k_attn, k_ffn = L.split_keys(key, 4)
    pd = cfg.parameter_dtype()
    keys = jnp.stack(L.split_keys(k_layers, cfg.n_layers))
    mamba_layers = jax.vmap(lambda k: _mamba_layer_init(k, cfg))(keys)
    # reshape stacked leaves to (groups, every, ...)
    g, e = n_groups(cfg), cfg.ssm.shared_attn_every
    mamba_layers = jax.tree.map(
        lambda x: x.reshape((g, e) + x.shape[1:]), mamba_layers
    )
    shared = {
        "proj_in": L.dense_init(k_sh_in, 2 * cfg.d_model, cfg.d_model, dtype=pd),
        "ln_attn": L.rmsnorm_init(cfg.d_model, pd),
        "attn": T.attn_init(k_attn, cfg),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, pd),
        "ffn": T.ffn_init(k_ffn, cfg),
    }
    return {"mamba": mamba_layers, "shared": shared}


def _shared_block(shared, cfg, h, h0, positions):
    zin = L.dense(
        shared["proj_in"], jnp.concatenate([h, h0], axis=-1), dtype=cfg.activation_dtype()
    )
    a = T.attn_apply(
        shared["attn"],
        cfg,
        L.rmsnorm(shared["ln_attn"], zin, cfg.norm_eps),
        positions=positions,
        causal=True,
    )
    z = zin + a
    f = T.ffn_apply(shared["ffn"], cfg, L.rmsnorm(shared["ln_ffn"], z, cfg.norm_eps))
    return h + (z + f - zin)  # residual contribution of the shared block


def hybrid_apply(params, cfg: ModelConfig, x, positions):
    shared = params["shared"]
    h0 = x

    def group(h, gp):
        def inner(hh, lp):
            return hh + ssm.mamba_apply(
                lp["mamba"], cfg, L.rmsnorm(lp["ln"], hh, cfg.norm_eps)
            ), None

        h, _ = T.layer_scan(cfg, inner, h, gp)
        h = _shared_block(shared, cfg, h, h0, positions)
        return constrain(h, "residual"), jnp.zeros((), jnp.float32)

    group = T.remat_wrap(group, cfg)
    h, _ = T.layer_scan(cfg, group, x, params["mamba"])
    return h, jnp.zeros(())


def hybrid_init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    g, e = n_groups(cfg), cfg.ssm.shared_attn_every
    one_state = ssm.mamba_init_state(cfg, batch)
    mamba = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (g, e) + x.shape), one_state
    )
    attn = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (g,) + x.shape),
        T.init_cache(cfg, batch, max_len),
    )
    return {"mamba": mamba, "attn": attn, "len": jnp.zeros((), jnp.int32)}


def hybrid_prefill(params, cfg: ModelConfig, x, positions, max_len: int):
    shared = params["shared"]
    h0 = x
    b = x.shape[0]

    def group(h, gp):
        def inner(hh, lp):
            out, st = ssm.mamba_prefill(
                lp["mamba"], cfg, L.rmsnorm(lp["ln"], hh, cfg.norm_eps)
            )
            return hh + out, st

        h, states = T.layer_scan(cfg, inner, h, gp)
        zin = L.dense(
            shared["proj_in"], jnp.concatenate([h, h0], axis=-1), dtype=cfg.activation_dtype()
        )
        a, (k, v) = T.attn_apply(
            shared["attn"],
            cfg,
            L.rmsnorm(shared["ln_attn"], zin, cfg.norm_eps),
            positions=positions,
            causal=True,
            return_kv=True,
        )
        z = zin + a
        f = T.ffn_apply(shared["ffn"], cfg, L.rmsnorm(shared["ln_ffn"], z, cfg.norm_eps))
        h = h + (z + f - zin)
        cache = T.fill_cache(cfg, T.init_cache(cfg, b, max_len), k, v)
        return h, (states, cache)

    h, (mamba_states, attn_caches) = T.layer_scan(cfg, group, x, params["mamba"])
    caches = {
        "mamba": mamba_states,
        "attn": attn_caches,
        "len": jnp.asarray(x.shape[1], jnp.int32),
    }
    return h, caches


def hybrid_decode(params, cfg: ModelConfig, x, caches):
    shared = params["shared"]
    h0 = x
    pos = caches["len"]

    def group(h, scanned):
        gp, mstates, acache = scanned
        acache = dict(acache, len=pos)

        def inner(hh, sc):
            lp, st = sc
            out, st = ssm.mamba_decode(
                lp["mamba"], cfg, L.rmsnorm(lp["ln"], hh, cfg.norm_eps), st
            )
            return hh + out, st

        h, mstates = T.layer_scan(cfg, inner, h, (gp, mstates))
        zin = L.dense(
            shared["proj_in"], jnp.concatenate([h, h0], axis=-1), dtype=cfg.activation_dtype()
        )
        a, acache = T.attn_decode(
            shared["attn"], cfg, L.rmsnorm(shared["ln_attn"], zin, cfg.norm_eps), acache
        )
        z = zin + a
        f = T.ffn_apply(shared["ffn"], cfg, L.rmsnorm(shared["ln_ffn"], z, cfg.norm_eps))
        h = h + (z + f - zin)
        return h, (mstates, acache)

    h, (mamba_states, attn_caches) = T.layer_scan(
        cfg, group, x, (params["mamba"], caches["mamba"], caches["attn"])
    )
    new = {"mamba": mamba_states, "attn": attn_caches, "len": pos + 1}
    return h, new
