"""Shared neural-net building blocks (pure-JAX, pytree params).

Parameters are plain nested dicts of arrays. Initializers take an explicit
key; shapes follow conventions that ``repro.dist.sharding`` pattern-matches
on (leaf path names like 'wq'/'w_up'/'experts' decide the PartitionSpec).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "embed_init",
    "rope",
    "cross_entropy",
    "split_keys",
]


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def dense_init(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: Optional[float] = None,
) -> dict:
    scale = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p: dict, x: jax.Array, *, dtype=None) -> jax.Array:
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


def embed_init(key: jax.Array, vocab: int, dim: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


def rope(
    x: jax.Array, positions: jax.Array, *, theta: float = 10000.0
) -> jax.Array:
    """Rotary position embedding. x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos - xf2 * sin
    r2 = xf2 * cos + xf1 * sin
    out = jnp.concatenate([r1, r2], axis=-1)
    if 2 * half != d:  # odd head_dim tail passes through
        out = jnp.concatenate([out, x[..., 2 * half :].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(
    logits: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    *,
    z_loss: float = 0.0,
) -> tuple[jax.Array, dict]:
    """Token-mean softmax CE with optional z-regularization.

    logits (..., V) any float dtype (reduced in f32); labels int (...,).
    Never materializes probabilities; safe for vocab-sharded logits under
    GSPMD (logsumexp reduces the sharded axis).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {
        "loss": loss,
        "tokens": denom,
        "ppl_proxy": jnp.exp(jnp.clip(loss, max=20.0)),
    }
    return loss, metrics
