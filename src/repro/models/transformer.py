"""Transformer backbone: GQA attention (RoPE, SWA, QKV-bias), SwiGLU FFN,
scanned+remat'd layer stacks, KV caches for serving.

Used directly by the dense archs and reused by the MoE / hybrid / enc-dec /
VLM families (they swap the FFN or interleave blocks). All attention goes
through ``repro.kernels.ops.attention`` and therefore through the paper's
schedulable KV traversal.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import compression
from repro.dist.context import constrain
from repro.kernels import ops
from repro.models import layers as L

__all__ = [
    "attn_init",
    "attn_apply",
    "attn_decode",
    "ffn_init",
    "ffn_apply",
    "layer_init",
    "stack_init",
    "stack_apply",
    "stack_prefill",
    "stack_decode",
    "init_cache",
    "fill_cache",
    "remat_wrap",
]


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, *, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    hd = cfg.hd
    kq, kk, kv, ko = L.split_keys(key, 4)
    pd = cfg.parameter_dtype()
    return {
        "wq": L.dense_init(kq, d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=pd),
        "wk": L.dense_init(kk, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=pd),
        "wv": L.dense_init(kv, d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=pd),
        "wo": L.dense_init(ko, cfg.n_heads * hd, d, dtype=pd),
    }


def _qkv(p, cfg: ModelConfig, x, kv_src, positions, kv_positions, *, use_rope=True):
    dt = cfg.activation_dtype()
    b, s, _ = x.shape
    skv = kv_src.shape[1]
    hd = cfg.hd
    q = L.dense(p["wq"], x, dtype=dt).reshape(b, s, cfg.n_heads, hd)
    k = L.dense(p["wk"], kv_src, dtype=dt).reshape(b, skv, cfg.n_kv_heads, hd)
    v = L.dense(p["wv"], kv_src, dtype=dt).reshape(b, skv, cfg.n_kv_heads, hd)
    if use_rope:
        q = L.rope(q, positions, theta=cfg.rope_theta)
        k = L.rope(k, kv_positions, theta=cfg.rope_theta)
    return q, k, v


def attn_apply(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    kv_src: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    causal: bool = True,
    use_rope: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    cross = kv_src is not None
    kv_src = x if kv_src is None else kv_src
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _qkv(p, cfg, x, kv_src, positions, kv_positions, use_rope=use_rope)
    o = ops.attention(
        q,
        k,
        v,
        order=cfg.attn_order,
        snake_group=cfg.snake_group,
        causal=causal and not cross,
        window=cfg.window if (causal and not cross) else None,
        q_block=cfg.q_block,
        kv_block=cfg.kv_block,
        impl=cfg.attn_impl,
        score_dtype=cfg.score_dtype,
        bwd_q_block=cfg.bwd_q_block,
        bwd_kv_block=cfg.bwd_kv_block,
    )
    b, s, _, _ = o.shape
    out = L.dense(p["wo"], o.reshape(b, s, -1), dtype=cfg.activation_dtype())
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    cache: dict,
    *,
    cross: bool = False,
):
    """Decode step. cache: {"k","v": (B,S_max,Hkv,hd), "len": scalar}.

    The paged layout is *ragged*: ``x`` may carry C > 1 chunk positions per
    row, with per-row valid counts in ``cache["q_len"]`` (default: all C) —
    one call serves decode rows (q_len 1) and chunked-prefill rows (q_len
    up to C) together. The contiguous layouts stay single-token.
    """
    dt = cfg.activation_dtype()
    b, one, _ = x.shape
    hd = cfg.hd
    q = L.dense(p["wq"], x, dtype=dt).reshape(b, -1, cfg.n_heads, hd)
    if not cross and "k_pages" in cache:
        k = L.dense(p["wk"], x, dtype=dt).reshape(b, -1, cfg.n_kv_heads, hd)
        v = L.dense(p["wv"], x, dtype=dt).reshape(b, -1, cfg.n_kv_heads, hd)
        o, cache = _attn_decode_paged(cfg, cache, q, k, v)
        out = L.dense(p["wo"], o.reshape(b, o.shape[1], -1), dtype=dt)
        return out, cache
    assert one == 1, "contiguous decode takes a single query position"
    if not cross:
        pos = cache["len"]
        k = L.dense(p["wk"], x, dtype=dt).reshape(b, 1, cfg.n_kv_heads, hd)
        v = L.dense(p["wv"], x, dtype=dt).reshape(b, 1, cfg.n_kv_heads, hd)
        q = L.rope(q, jnp.full((b, 1), pos), theta=cfg.rope_theta)
        k = L.rope(k, jnp.full((b, 1), pos), theta=cfg.rope_theta)
        s_max = cache["k"].shape[1]
        write = pos % s_max if cfg.window is not None else pos  # SWA ring buffer
        cache = _cache_write(cfg, cache, "k", k, write)
        cache = _cache_write(cfg, cache, "v", v, write)
        cache["len"] = pos + 1
        valid = jnp.minimum(pos + 1, s_max)
        o = ops.attention_decode(
            q,
            _cache_read(cfg, cache, "k"),
            _cache_read(cfg, cache, "v"),
            valid,
            order=cfg.attn_order,
            snake_group=cfg.snake_group,
            impl=cfg.attn_impl,
        )
    else:
        # cross-attention: static encoder K/V, no rope (matches prefill path)
        o = ops.attention_decode(
            q, cache["k"], cache["v"], cache["kv_len"], impl=cfg.attn_impl
        )
    out = L.dense(p["wo"], o.reshape(b, 1, -1), dtype=dt)
    return out, cache


def _paged_write(cfg: ModelConfig, cache: dict, k, v, starts, q_lens) -> dict:
    """Chunked write-at-offset into a paged cache — THE paged write path.

    k/v: (B, C, Hkv, hd) chunk values; row b's positions ``starts[b] + t``
    for ``t < q_lens[b]`` are written through the block table (logical page
    ``pos // page``, offset ``pos % page``). Invalid chunk rows (``t >=
    q_len`` — padding of a ragged step, or inactive serve slots) are routed
    to the reserved dummy page 0, so the fixed-shape scatter stays total.
    Both prefill (``fill_cache``: starts 0, q_lens = S) and ragged serve
    steps (decode rows at C=1, prefill chunks at C>1) funnel through here.
    """
    b, c = k.shape[:2]
    bt = cache["block_table"]
    page = cache["k_pages"].shape[1]
    capacity = bt.shape[1] * page
    tq = jnp.arange(c, dtype=jnp.int32)[None, :]
    pos = starts[:, None] + tq                             # (B, C)
    valid = tq < q_lens[:, None]
    wpos = jnp.minimum(pos, capacity - 1)  # clamp like the contiguous path
    page_log = wpos // page
    offset = wpos % page
    phys = jnp.take_along_axis(bt, page_log, axis=1)       # (B, C)
    phys = jnp.where(valid, phys, 0)                       # dummy page 0

    out = dict(cache)
    for name, val in (("k_pages", k), ("v_pages", v)):
        if cfg.kv_cache_dtype == "int8":
            qv, sc = _quantize_kv(val)                     # (B,C,H,hd),(B,C,H)
            out[name] = out[name].at[phys, offset].set(qv)
            out[name + "_scale"] = out[name + "_scale"].at[phys, offset].set(sc)
        else:
            out[name] = out[name].at[phys, offset].set(val.astype(out[name].dtype))
    return out


def _attn_decode_paged(cfg: ModelConfig, cache: dict, q, k, v):
    """Ragged chunk step against a paged cache: per-row lengths + valid
    chunk counts, block-table write-at-offset, schedule-ordered ragged
    paged attention (causal inside the chunk). Rows whose ``q_len`` is 0
    (free continuous-batching slots) write only into the reserved dummy
    page and read back exact zeros."""
    b, c = q.shape[:2]
    lens = cache["len"]  # (B,) tokens already cached (chunk positions follow)
    bt = cache["block_table"]
    page = cache["k_pages"].shape[1]
    capacity = bt.shape[1] * page
    q_lens = cache.get("q_len")
    if q_lens is None:
        q_lens = jnp.full((b,), c, jnp.int32)

    positions = lens[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (B, C)
    q = L.rope(q, positions, theta=cfg.rope_theta)
    k = L.rope(k, positions, theta=cfg.rope_theta)

    cache = dict(cache)
    cache = _paged_write(cfg, cache, k, v, lens, q_lens)
    cache["len"] = lens + q_lens

    valid = jnp.minimum(lens + q_lens, capacity)
    # ``order_group`` rides the cache dict like ``q_len``: a traced
    # effective reversal-group scalar that overrides cfg.attn_order for
    # this step (the serve engine's runtime order switch; absent outside
    # the continuous path, where the static config order applies).
    o = ops.attention_decode(
        q,
        _cache_read(cfg, cache, "k_pages"),
        _cache_read(cfg, cache, "v_pages"),
        valid,
        order=cfg.attn_order,
        snake_group=cfg.snake_group,
        impl=cfg.attn_impl,
        block_table=bt,
        q_lens=q_lens,
        order_group=cache.get("order_group"),
    )
    return o, cache


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head)-vector symmetric int8. x (B,S,H,D) -> (q, scale)."""
    return compression.quantize_int8_vec(x)


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return compression.dequantize_int8_vec(q, scale, dtype)


def _cache_read(cfg: ModelConfig, cache: dict, name: str) -> jax.Array:
    if cfg.kv_cache_dtype == "int8":
        return _dequantize_kv(cache[name], cache[name + "_scale"], cfg.activation_dtype())
    return cache[name]


def page_geometry(cfg: ModelConfig, max_len: int) -> tuple[int, int]:
    """(page rows, blocks-per-sequence) for a paged cache of ``max_len``.

    Page size defaults to ``kv_block`` so physical pages coincide with the
    KV tiles the schedule walks — a block-table entry is then exactly one
    schedule step (DESIGN.md §8).
    """
    page = cfg.page_size or cfg.kv_block
    page = max(1, min(page, max_len))
    return page, -(-max_len // page)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, dtype=None) -> dict:
    """Self-attention KV cache; SWA archs get a window-sized ring buffer.
    kv_cache_dtype='int8' stores quantized values + per-vector scales.

    ``cfg.kv_layout == 'paged'`` switches to a page-pool layout: k/v pages
    (n_pages, page, Hkv, hd) plus a per-row ``block_table`` (B, n_blocks)
    initialized to the identity mapping (row i owns pages [i*n, (i+1)*n)),
    and per-row ``len`` (B,). A serving pool (repro.serve.kv_pool) re-maps
    block tables as sequences join and leave the running batch.
    """
    if cfg.kv_layout == "paged":
        if cfg.window is not None:
            raise ValueError(
                "paged KV layout requires full attention; sliding-window "
                "archs keep the ring-buffer layout (kv_layout='contiguous')"
            )
        page, bpr = page_geometry(cfg, max_len)
        shape = (batch * bpr, page, cfg.n_kv_heads, cfg.hd)
        cache = {
            "len": jnp.zeros((batch,), jnp.int32),
            "block_table": jnp.arange(batch * bpr, dtype=jnp.int32).reshape(
                batch, bpr
            ),
        }
        if cfg.kv_cache_dtype == "int8":
            for name in ("k_pages", "v_pages"):
                cache[name] = jnp.zeros(shape, jnp.int8)
                cache[name + "_scale"] = jnp.ones(shape[:3], jnp.float32)
        else:
            dt = dtype or cfg.activation_dtype()
            cache["k_pages"] = jnp.zeros(shape, dt)
            cache["v_pages"] = jnp.zeros(shape, dt)
        return cache
    size = min(max_len, cfg.window) if cfg.window is not None else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.hd)
    cache = {"len": jnp.zeros((), jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        for name in ("k", "v"):
            cache[name] = jnp.zeros(shape, jnp.int8)
            cache[name + "_scale"] = jnp.ones(shape[:3], jnp.float32)
    else:
        dt = dtype or cfg.activation_dtype()
        cache["k"] = jnp.zeros(shape, dt)
        cache["v"] = jnp.zeros(shape, dt)
    return cache


def _cache_write(cfg: ModelConfig, cache: dict, name: str, val: jax.Array, pos) -> dict:
    """Write ``val`` (B,s,H,D) at sequence offset ``pos`` (traced ok)."""
    out = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        q, scale = _quantize_kv(val)
        out[name] = jax.lax.dynamic_update_slice_in_dim(cache[name], q, pos, axis=1)
        out[name + "_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache[name + "_scale"], scale, pos, axis=1
        )
    else:
        out[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], val.astype(cache[name].dtype), pos, axis=1
        )
    return out


def fill_cache(cfg: ModelConfig, cache: dict, k: jax.Array, v: jax.Array) -> dict:
    """Write prefill K/V into a fresh cache (handles SWA truncation).

    Paged caches must come straight from :func:`init_cache` (identity block
    table): row i's logical pages are then physically contiguous, so the
    prefill scatter is a reshape.
    """
    if "k_pages" in cache:
        return _fill_cache_paged(cfg, cache, k, v)
    s = k.shape[1]
    size = cache["k"].shape[1]
    if s >= size:
        k, v = k[:, -size:], v[:, -size:]
        if cfg.window is not None:
            # Ring-buffer layout: decode writes position p at index p % size,
            # so the kept tail (positions s-size..s-1) must land on those
            # indices — otherwise the first decode writes evict the wrong
            # (non-oldest) entries. Rolling by s % size puts position p at
            # index p % size.
            shift = s % size
            if shift:
                k = jnp.roll(k, shift, axis=1)
                v = jnp.roll(v, shift, axis=1)
    cache = _cache_write(cfg, cache, "k", k, 0)
    cache = _cache_write(cfg, cache, "v", v, 0)
    cache["len"] = jnp.asarray(s, jnp.int32)
    return cache


def _fill_cache_paged(cfg: ModelConfig, cache: dict, k: jax.Array, v: jax.Array) -> dict:
    b, s = k.shape[:2]
    page = cache["k_pages"].shape[1]
    capacity = cache["block_table"].shape[1] * page
    if s > capacity:
        k, v = k[:, -capacity:], v[:, -capacity:]
        s = capacity
    out = _paged_write(
        cfg,
        cache,
        k,
        v,
        jnp.zeros((b,), jnp.int32),
        jnp.full((b,), s, jnp.int32),
    )
    out["len"] = jnp.full((b,), s, jnp.int32)
    return out


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------


def ffn_init(key, cfg: ModelConfig, *, d_ff: Optional[int] = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    kg, ku, kd = L.split_keys(key, 3)
    pd = cfg.parameter_dtype()
    return {
        "w_gate": L.dense_init(kg, d, ff, dtype=pd),
        "w_up": L.dense_init(ku, d, ff, dtype=pd),
        "w_down": L.dense_init(kd, ff, d, dtype=pd),
    }


def ffn_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = cfg.activation_dtype()
    g = L.dense(p["w_gate"], x, dtype=dt)
    u = L.dense(p["w_up"], x, dtype=dt)
    return L.dense(p["w_down"], jax.nn.silu(g) * u, dtype=dt)


# --------------------------------------------------------------------------
# layer + stack (scan over stacked params)
# --------------------------------------------------------------------------


def layer_init(key, cfg: ModelConfig, *, ffn_init_fn=None) -> dict:
    ka, kf = L.split_keys(key, 2)
    pd = cfg.parameter_dtype()
    f_init = ffn_init_fn or (lambda k: ffn_init(k, cfg))
    return {
        "ln_attn": L.rmsnorm_init(cfg.d_model, pd),
        "attn": attn_init(ka, cfg),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, pd),
        "ffn": f_init(kf),
    }


def remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def layer_scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked layer params, or a python-unrolled loop when
    cfg.scan_layers=False (dry-run roofline: XLA cost_analysis counts while
    bodies once, so trip-count-correct metrics need unrolled HLO)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def stack_init(key, cfg: ModelConfig, n_layers: int, *, ffn_init_fn=None) -> dict:
    keys = jnp.stack(L.split_keys(key, n_layers))
    return jax.vmap(lambda k: layer_init(k, cfg, ffn_init_fn=ffn_init_fn))(keys)


def _layer_fwd(lp, cfg: ModelConfig, x, positions, *, causal, ffn_apply_fn):
    h = x + attn_apply(
        lp["attn"], cfg, L.rmsnorm(lp["ln_attn"], x, cfg.norm_eps), positions=positions, causal=causal
    )
    extras = None
    y = ffn_apply_fn(lp["ffn"], cfg, L.rmsnorm(lp["ln_ffn"], h, cfg.norm_eps))
    if isinstance(y, tuple):  # MoE returns (out, aux)
        y, extras = y
    return h + y, extras


def stack_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    ffn_apply_fn=None,
):
    """Scan the layer stack; returns (hidden, aux_sum)."""
    ffn_fn = ffn_apply_fn or (lambda p, c, h: ffn_apply(p, c, h))

    def body(h, lp):
        out, extras = _layer_fwd(
            lp, cfg, h, positions, causal=causal, ffn_apply_fn=ffn_fn
        )
        out = constrain(out, "residual")
        aux = extras if extras is not None else jnp.zeros((), jnp.float32)
        return out, aux

    body = remat_wrap(body, cfg)
    h, auxes = layer_scan(cfg, body, x, params)
    return h, jnp.sum(auxes)


def stack_prefill(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    max_len: int,
    *,
    ffn_apply_fn=None,
):
    """Forward + build per-layer KV caches (stacked on a leading L axis)."""
    ffn_fn = ffn_apply_fn or (lambda p, c, h: ffn_apply(p, c, h))
    b = x.shape[0]

    def body(h, lp):
        xn = L.rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
        a, (k, v) = attn_apply(
            lp["attn"], cfg, xn, positions=positions, causal=True, return_kv=True
        )
        h = h + a
        y = ffn_fn(lp["ffn"], cfg, L.rmsnorm(lp["ln_ffn"], h, cfg.norm_eps))
        if isinstance(y, tuple):
            y = y[0]
        cache = fill_cache(cfg, init_cache(cfg, b, max_len), k, v)
        return constrain(h + y, "residual"), cache

    body = remat_wrap(body, cfg)
    h, caches = layer_scan(cfg, body, x, params)
    return h, caches


def stack_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    caches: dict,
    *,
    ffn_apply_fn=None,
):
    """One-token step through all layers, updating stacked caches."""
    ffn_fn = ffn_apply_fn or (lambda p, c, h: ffn_apply(p, c, h))

    def body(h, scanned):
        lp, cache = scanned
        xn = L.rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
        a, cache = attn_decode(lp["attn"], cfg, xn, cache)
        h = h + a
        y = ffn_fn(lp["ffn"], cfg, L.rmsnorm(lp["ln_ffn"], h, cfg.norm_eps))
        if isinstance(y, tuple):
            y = y[0]
        return h + y, cache

    h, caches = layer_scan(cfg, body, x, (params, caches))
    return h, caches
