"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD form for train/prefill (quadratic intra-chunk + linear
inter-chunk state passing), exact recurrent step for decode. Matches the
sequential oracle ``repro.kernels.ref.ssd_ref`` (tested).

The SSD chunk stream is itself a cyclic tile traversal; sawtooth chunk
re-ordering does not apply to the forward (each chunk is visited once) but
the backward's re-read of (x, B, C) chunks is a retraversal — exposed as a
beyond-paper experiment, see DESIGN.md §5.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L

__all__ = [
    "ssd_chunked",
    "mamba_init",
    "mamba_apply",
    "mamba_decode",
    "mamba_init_state",
    "mamba_prefill",
    "d_inner",
    "n_ssm_heads",
]


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def n_ssm_heads(cfg: ModelConfig) -> int:
    di = d_inner(cfg)
    assert di % cfg.ssm.head_dim == 0, (di, cfg.ssm.head_dim)
    return di // cfg.ssm.head_dim


# --------------------------------------------------------------------------
# chunked SSD scan
# --------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  — post-softplus, >= 0
    a: jax.Array,   # (H,)       — negative decay rates
    b: jax.Array,   # (B, S, N)
    c: jax.Array,   # (B, S, N)
    *,
    chunk: int = 128,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). f32 internally."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 -> no update, no decay
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    af = a.astype(jnp.float32)

    da = dtf * af[None, None, None, :]            # (b,nc,c,h), <= 0
    cum = jnp.cumsum(da, axis=2)                  # inclusive within-chunk
    cum_h = cum.transpose(0, 1, 3, 2)             # (b,nc,h,c)

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (c_i.b_j) x_j
    diff = cum_h[..., :, None] - cum_h[..., None, :]          # (b,nc,h,c,c)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    # double-where: masked (upper-triangle) diffs are >= 0 and can overflow
    # exp to inf, which the backward turns into 0*inf = NaN grads — zero the
    # exponent under the mask too so both passes stay finite.
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, diff, 0.0)), 0.0)
    cb = jnp.einsum("bzin,bzjn->bzij", cf, bf)                # (b,nc,c,c)
    w = cb[:, :, None] * decay * dtf.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", w, xf)

    # chunk state contributions: S_c = sum_j exp(cum_last - cum_j) dt_j x_j b_j^T
    cum_last = cum[:, :, -1:, :]                              # (b,nc,1,h)
    decay_end = jnp.exp(cum_last - cum)                       # (b,nc,c,h)
    s_c = jnp.einsum("bzch,bzcn,bzchp->bzhpn", dtf * decay_end, bf, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (b,nc,h)

    # inter-chunk: running state scan
    s0 = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(carry, t):
        s_ck, dk = t
        s_in = carry
        return dk[..., None, None] * s_in + s_ck, s_in

    final, s_in_all = jax.lax.scan(
        body,
        s0,
        (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in_all, 0, 1)                       # (b,nc,h,p,n)

    y_inter = jnp.einsum("bzcn,bzch,bzhpn->bzchp", cf, jnp.exp(cum), s_in)
    y = (y_intra + y_inter).reshape(bsz, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


# --------------------------------------------------------------------------
# Mamba-2 block
# --------------------------------------------------------------------------


def mamba_init(key, cfg: ModelConfig) -> dict:
    m = cfg.ssm
    d = cfg.d_model
    di = d_inner(cfg)
    h = n_ssm_heads(cfg)
    n = m.state_dim
    conv_ch = di + 2 * n
    k_in, k_conv, k_out, k_a, k_dt = L.split_keys(key, 5)
    pd = cfg.parameter_dtype()
    return {
        "in_proj": L.dense_init(k_in, d, 2 * di + 2 * n + h, dtype=pd),
        "conv_w": (jax.random.normal(k_conv, (m.conv_width, conv_ch)) * 0.2).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h).astype(jnp.float32)
        ),  # A in [-16, -1]
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": (jax.random.uniform(k_dt, (h,)) * 2.0 - 4.0).astype(jnp.float32),
        "norm": L.rmsnorm_init(di, pd),
        "out_proj": L.dense_init(k_out, di, d, dtype=pd),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv1d, width w.shape[0]. xbc (B, S, Ch)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    s = xbc.shape[1]
    out = sum(
        pad[:, u : u + s, :] * w[u][None, None, :].astype(xbc.dtype)
        for u in range(width)
    )
    return out + bias[None, None, :].astype(xbc.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di = d_inner(cfg)
    n = cfg.ssm.state_dim
    h = n_ssm_heads(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * n]
    dt_raw = zxbcdt[..., 2 * di + 2 * n :]
    assert dt_raw.shape[-1] == h
    return z, xbc, dt_raw


def _ssm_inputs(cfg: ModelConfig, p: dict, xbc_conv: jax.Array, dt_raw: jax.Array):
    di = d_inner(cfg)
    n = cfg.ssm.state_dim
    h = n_ssm_heads(cfg)
    xbc_act = jax.nn.silu(xbc_conv)
    x_in = xbc_act[..., :di]
    b_in = xbc_act[..., di : di + n]
    c_in = xbc_act[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])
    shp = x_in.shape[:-1] + (h, cfg.ssm.head_dim)
    return x_in.reshape(shp), b_in, c_in, dt, a


def _finish(cfg: ModelConfig, p: dict, y_heads, x_heads, z):
    di = d_inner(cfg)
    y = y_heads + p["d_skip"][None, None, :, None] * x_heads.astype(jnp.float32)
    y = y.reshape(y.shape[0], y.shape[1], di)
    y = L.rmsnorm(p["norm"], (y * jax.nn.silu(z.astype(jnp.float32))).astype(z.dtype), cfg.norm_eps)
    return L.dense(p["out_proj"], y, dtype=cfg.activation_dtype())


def mamba_apply(
    p: dict, cfg: ModelConfig, x: jax.Array, *, init_state=None
) -> jax.Array:
    """Full-sequence Mamba-2 block. x (B, S, d) -> (B, S, d)."""
    dt_act = cfg.activation_dtype()
    zxbcdt = L.dense(p["in_proj"], x, dtype=dt_act)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x_h, b_in, c_in, dt, a = _ssm_inputs(cfg, p, xbc, dt_raw)
    y, _ = ops.ssd(
        x_h, dt, a, b_in, c_in, chunk=cfg.ssm.chunk, init_state=init_state,
        impl=cfg.ssd_impl,
    )
    return _finish(cfg, p, y.astype(jnp.float32), x_h, z)


def mamba_init_state(cfg: ModelConfig, batch: int) -> dict:
    m = cfg.ssm
    di = d_inner(cfg)
    h = n_ssm_heads(cfg)
    return {
        "conv": jnp.zeros((batch, m.conv_width - 1, di + 2 * m.state_dim), cfg.activation_dtype()),
        "ssd": jnp.zeros((batch, h, m.head_dim, m.state_dim), jnp.float32),
    }


def mamba_prefill(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also returns the decode state."""
    dt_act = cfg.activation_dtype()
    zxbcdt = L.dense(p["in_proj"], x, dtype=dt_act)
    z, xbc_raw, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    x_h, b_in, c_in, dt, a = _ssm_inputs(cfg, p, xbc, dt_raw)
    y, final = ops.ssd(
        x_h, dt, a, b_in, c_in, chunk=cfg.ssm.chunk, impl=cfg.ssd_impl
    )
    out = _finish(cfg, p, y.astype(jnp.float32), x_h, z)
    w = cfg.ssm.conv_width
    conv_state = xbc_raw[:, -(w - 1) :, :]
    pad = (w - 1) - conv_state.shape[1]
    if pad > 0:
        conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
    return out, {"conv": conv_state, "ssd": final}


def mamba_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """One-token step. x (B, 1, d); state from mamba_init_state/prefill."""
    dt_act = cfg.activation_dtype()
    zxbcdt = L.dense(p["in_proj"], x, dtype=dt_act)
    z, xbc_t, dt_raw = _split_proj(cfg, zxbcdt)

    hist = jnp.concatenate([state["conv"], xbc_t], axis=1)  # (B, w, Ch)
    conv_out = (
        jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
        + p["conv_b"].astype(jnp.float32)
    )[:, None, :].astype(dt_act)
    new_conv = hist[:, 1:, :]

    x_h, b_in, c_in, dt, a = _ssm_inputs(cfg, p, conv_out, dt_raw)
    # exact recurrence (matches kernels.ref.ssd_ref)
    dtf = dt[:, 0]  # (B, H)
    decay = jnp.exp(dtf * a[None, :])[..., None, None]
    upd = (dtf[..., None] * x_h[:, 0].astype(jnp.float32))[..., :, None] * b_in[
        :, 0, None, None, :
    ].astype(jnp.float32)
    s_new = decay * state["ssd"] + upd
    y = jnp.einsum("bhpn,bn->bhp", s_new, c_in[:, 0].astype(jnp.float32))[:, None]
    out = _finish(cfg, p, y, x_h, z)
    return out, {"conv": new_conv, "ssd": s_new}
