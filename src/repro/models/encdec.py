"""Encoder-decoder backbone (seamless-m4t style: speech/text enc -> text dec).

The modality frontend is a stub per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S_src, d) to the encoder. The decoder is a
standard causal transformer with per-layer cross-attention into the encoder
output; cross K/V are computed once at prefill and cached.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.context import constrain
from repro.models import layers as L
from repro.models import transformer as T

__all__ = [
    "encdec_init",
    "encode",
    "decode_train",
    "encdec_prefill",
    "encdec_decode",
]


def _dec_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = L.split_keys(key, 3)
    pd = cfg.parameter_dtype()
    return {
        "ln_self": L.rmsnorm_init(cfg.d_model, pd),
        "self_attn": T.attn_init(k1, cfg),
        "ln_cross": L.rmsnorm_init(cfg.d_model, pd),
        "cross_attn": T.attn_init(k2, cfg),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, pd),
        "ffn": T.ffn_init(k3, cfg),
    }


def encdec_init(key, cfg: ModelConfig) -> dict:
    k_enc, k_dec = L.split_keys(key, 2)
    enc = T.stack_init(k_enc, cfg, cfg.n_encoder_layers)
    keys = jnp.stack(L.split_keys(k_dec, cfg.n_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(keys)
    return {"encoder": enc, "decoder": dec}


def encode(params, cfg: ModelConfig, src_embeds: jax.Array) -> jax.Array:
    pos = jnp.arange(src_embeds.shape[1])[None, :]
    h, _ = T.stack_apply(params["encoder"], cfg, src_embeds, pos, causal=False)
    return h


def _dec_layer(lp, cfg, h, enc_out, positions, enc_positions):
    a = T.attn_apply(
        lp["self_attn"],
        cfg,
        L.rmsnorm(lp["ln_self"], h, cfg.norm_eps),
        positions=positions,
        causal=True,
    )
    h = h + a
    c = T.attn_apply(
        lp["cross_attn"],
        cfg,
        L.rmsnorm(lp["ln_cross"], h, cfg.norm_eps),
        positions=positions,
        kv_src=enc_out,
        kv_positions=enc_positions,
        causal=False,
        use_rope=False,
    )
    h = h + c
    f = T.ffn_apply(lp["ffn"], cfg, L.rmsnorm(lp["ln_ffn"], h, cfg.norm_eps))
    return h + f


def decode_train(params, cfg: ModelConfig, tgt_embeds, enc_out):
    positions = jnp.arange(tgt_embeds.shape[1])[None, :]
    enc_positions = jnp.arange(enc_out.shape[1])[None, :]

    def body(h, lp):
        out = _dec_layer(lp, cfg, h, enc_out, positions, enc_positions)
        return constrain(out, "residual"), None

    body = T.remat_wrap(body, cfg)
    h, _ = T.layer_scan(cfg, body, tgt_embeds, params["decoder"])
    return h


def encdec_prefill(params, cfg: ModelConfig, tgt_embeds, enc_out, max_len: int):
    """Teacher-forced pass over the target prefix + build self/cross caches."""
    b, s, _ = tgt_embeds.shape
    positions = jnp.arange(s)[None, :]
    enc_positions = jnp.arange(enc_out.shape[1])[None, :]
    dt = cfg.activation_dtype()
    hd = cfg.hd

    def body(h, lp):
        xn = L.rmsnorm(lp["ln_self"], h, cfg.norm_eps)
        a, (k, v) = T.attn_apply(
            lp["self_attn"], cfg, xn, positions=positions, causal=True, return_kv=True
        )
        h = h + a
        hx = L.rmsnorm(lp["ln_cross"], h, cfg.norm_eps)
        # cross K/V computed once from encoder output
        skv = enc_out.shape[1]
        ck = L.dense(lp["cross_attn"]["wk"], enc_out, dtype=dt).reshape(
            b, skv, cfg.n_kv_heads, hd
        )
        cv = L.dense(lp["cross_attn"]["wv"], enc_out, dtype=dt).reshape(
            b, skv, cfg.n_kv_heads, hd
        )
        c = T.attn_apply(
            lp["cross_attn"],
            cfg,
            hx,
            positions=positions,
            kv_src=enc_out,
            kv_positions=enc_positions,
            causal=False,
            use_rope=False,
        )
        h = h + c
        f = T.ffn_apply(lp["ffn"], cfg, L.rmsnorm(lp["ln_ffn"], h, cfg.norm_eps))
        self_cache = T.fill_cache(cfg, T.init_cache(cfg, b, max_len), k, v)
        cross_cache = {"k": ck, "v": cv, "kv_len": jnp.asarray(skv, jnp.int32)}
        return h + f, {"self": self_cache, "cross": cross_cache}

    h, caches = T.layer_scan(cfg, body, tgt_embeds, params["decoder"])
    return h, caches


def encdec_decode(params, cfg: ModelConfig, x, caches):
    def body(h, scanned):
        lp, cache = scanned
        xn = L.rmsnorm(lp["ln_self"], h, cfg.norm_eps)
        a, self_cache = T.attn_decode(lp["self_attn"], cfg, xn, cache["self"])
        h = h + a
        hx = L.rmsnorm(lp["ln_cross"], h, cfg.norm_eps)
        c, _ = T.attn_decode(lp["cross_attn"], cfg, hx, cache["cross"], cross=True)
        h = h + c
        f = T.ffn_apply(lp["ffn"], cfg, L.rmsnorm(lp["ln_ffn"], h, cfg.norm_eps))
        return h + f, {"self": self_cache, "cross": cache["cross"]}

    h, caches = T.layer_scan(cfg, body, x, (params["decoder"], caches))
    return h, caches
