"""Serving launcher: load (or init) params and serve synthetic batched
requests through the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --requests 16 --max-new 24

``--scheduler continuous`` serves over the paged KV pool with continuous
batching (token-only full-attention archs); ``auto`` picks it when the
arch supports it and falls back to the static-group path otherwise.

Telemetry (``repro.obs``): ``--metrics-out metrics.jsonl`` dumps the
engine's registry (TTFT/TPOT histograms, per-kind token counters, pool and
scheduler gauges, ``llc.modeled_miss_bytes{order=...}``) one JSON line per
series, and ``--trace-out trace.json`` writes the step spans as
Chrome-trace JSON — open it in ``chrome://tracing`` or Perfetto. The
``llc.*`` gauges sample every ``--llc-every`` mixed steps (0 disables);
``--log-every`` prints a periodic one-line stats summary mid-stream.

``--attn-order auto`` turns on online traversal-order adaptation
(``repro.serve.adapt``): the engine seeds its initial order from the
hillclimb autotune cache (``--autotune-cache``) and then, every
``--adapt-epoch`` mixed steps, re-picks the order from the live modeled-LLC
gauges (hysteresis via ``--adapt-hysteresis`` / ``--adapt-confirm``).
Switches rebind the step's ``order_group`` operand — zero recompiles.

Resilience (DESIGN.md §12): ``--admission optimistic`` oversubscribes the
pool (mid-flight exhaustion is answered by victim preemption + chunked
re-prefill restore, bounded by ``--max-preemptions``), ``--max-queue``
load-sheds the newest arrived requests, ``--admit-watermark`` pauses
admission under pool pressure, and ``--deadline-s`` gives every synthetic
request a wall-clock deadline. Every request resolves with a typed
``status`` (ok/deadline/cancelled/shed/failed) instead of raising.

Tiered KV memory (DESIGN.md §13): ``--host-pages N`` backs the device pool
with an N-page host tier — at ``--spill-watermark`` occupancy the engine
spills the coldest slot (largest modeled reuse distance) to the host
instead of preempting it, and streams pages back ``--prefetch-depth`` per
step in the traversal's visit order, overlapped with in-flight steps.

Speculative decoding (DESIGN.md §14): ``--draft ngram`` turns on
self-drafting prompt-lookup speculation — every decode row plans up to
``--draft-len`` draft tokens into the same ragged mixed step as a
q_len=K+1 verification chunk; accepted tokens commit, rejected drafts
roll the row's KV length back (host-side, no new kernel, still exactly
two compiled step widths). ``--draft model`` uses a draft *model* with
its own paged cache instead (``--draft-model ARCH``; defaults to the
serving model itself — self-speculation). Output streams are bitwise
identical to ``--draft none`` for greedy and sampled decoding alike.
``--chaos-step-fail N`` injects one transient device-step failure at
mixed step N (the CI speculative chaos smoke: the step retries once and
the stream is unchanged).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.schedule import Order
from repro.models import build_model
from repro.serve import FaultPlan, Request, ServeEngine, supports_continuous
from repro.train.checkpoint import latest_step, restore_pytree


def pick_scheduler(choice: str, cfg) -> str:
    if choice != "auto":
        return choice
    ok = supports_continuous(cfg)
    if not ok:
        print(
            f"scheduler=auto: {cfg.name} (family={cfg.family}, window={cfg.window}) "
            "does not support continuous batching; using static groups"
        )
    return "continuous" if ok else "static"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None, help="restore params from here")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--attn-order", default="sawtooth",
                    choices=[o.value for o in Order] + ["auto"],
                    help="KV traversal order (core/schedule.py Traversal IR); "
                         "'auto' enables online adaptation: seed from the "
                         "autotune cache, then re-pick from the live "
                         "modeled-LLC gauges every --adapt-epoch steps")
    ap.add_argument("--snake-group", type=int, default=None,
                    help="block_snake reversal window in KV tiles")
    ap.add_argument("--adapt-epoch", type=int, default=8,
                    help="adaptation decision cadence in mixed steps "
                         "(--attn-order auto)")
    ap.add_argument("--adapt-hysteresis", type=float, default=0.05,
                    help="minimum fractional modeled-miss-byte improvement "
                         "before an order switch (--attn-order auto)")
    ap.add_argument("--adapt-confirm", type=int, default=2,
                    help="consecutive qualifying samples required before "
                         "switching (--attn-order auto)")
    ap.add_argument("--autotune-cache",
                    default="artifacts/hillclimb/autotune_cache.jsonl",
                    metavar="PATH",
                    help="hillclimb autotune-cache JSONL consulted at engine "
                         "start to seed the initial order (--attn-order auto; "
                         "missing file is fine)")
    ap.add_argument(
        "--scheduler", default="auto", choices=["auto", "static", "continuous"]
    )
    ap.add_argument("--page-size", type=int, default=None, help="KV page rows")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="tokens per ragged mixed step (decode rows + prefill "
                         "chunks; default: batch size + one chunk)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens per prefill chunk (default: 4 pages)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable the paged pool's content-hash prefix "
                         "sharing / copy-on-write page dedup")
    ap.add_argument("--admission", default="reserve",
                    choices=["reserve", "optimistic"],
                    help="pool admission discipline: 'reserve' guarantees "
                         "the worst case up front; 'optimistic' reserves "
                         "only prompts and answers mid-flight exhaustion "
                         "with victim preemption + chunked re-prefill")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound on the arrived waiting queue; newest "
                         "requests beyond it are load-shed (status=shed)")
    ap.add_argument("--admit-watermark", type=float, default=None,
                    help="pool-occupancy fraction at which admission "
                         "pauses (default 0.9 optimistic / 1.0 reserve)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline from engine "
                         "start; expired requests resolve status=deadline "
                         "with their partial tokens")
    ap.add_argument("--max-preemptions", type=int, default=2,
                    help="preemption bound per request before it resolves "
                         "status=failed")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="allocatable KV pool pages (default: every slot's "
                         "worst case; smaller = oversubscribed pool)")
    ap.add_argument("--host-pages", type=int, default=None,
                    help="host-offload page tier capacity in pages "
                         "(DESIGN.md §13); enables the TieredPagePool so "
                         "cold slots spill to host instead of being "
                         "preempted (default: tiering off)")
    ap.add_argument("--spill-watermark", type=float, default=None,
                    help="device-pool occupancy fraction at which the "
                         "coldest slot (largest modeled reuse distance) "
                         "spills to the host tier (default: "
                         "min(0.85, admit watermark); needs --host-pages)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="host pages staged back per step boundary while a "
                         "spilled slot resumes, in the next step's "
                         "traversal visit order (needs --host-pages)")
    ap.add_argument("--draft", default="none",
                    choices=["none", "ngram", "model"],
                    help="speculative decoding drafter (DESIGN.md §14): "
                         "'ngram' self-drafts via prompt lookup; 'model' "
                         "runs a draft model with its own paged cache "
                         "(continuous scheduler only)")
    ap.add_argument("--draft-len", type=int, default=4, metavar="K",
                    help="draft tokens planned per decode row per step "
                         "(verified as one q_len=K+1 ragged chunk; clamped "
                         "to the prefill chunk and the token budget)")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    help="arch for --draft model (reduced like the target; "
                         "default: the serving model itself — "
                         "self-speculation)")
    ap.add_argument("--chaos-step-fail", type=int, default=0, metavar="N",
                    help="inject one transient device-step failure at mixed "
                         "step N (retried once; the CI speculative chaos "
                         "smoke)")
    ap.add_argument("--chaos-fetch-fail", type=int, default=0, metavar="N",
                    help="inject N tier.fetch faults (dropped host->device "
                         "transfers; the prefetcher requeues and retries) — "
                         "the CI tiering chaos smoke (needs --host-pages)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the obs metrics registry as JSONL here")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the span trace as Chrome-trace JSON here")
    ap.add_argument("--llc-every", type=int, default=8,
                    help="sample modeled-LLC gauges every N mixed steps "
                         "(continuous path; 0 disables)")
    ap.add_argument("--llc-capacity-mib", type=float, default=None,
                    help="modeled LLC capacity for the llc.* gauges (MiB; "
                         "default matches hillclimb --sweep-orders)")
    ap.add_argument("--log-every", type=int, default=0, metavar="STEPS",
                    help="print a one-line stats summary every N mixed steps")
    args = ap.parse_args()

    if args.attn_order == "block_snake" and args.snake_group is None:
        valid = ", ".join(repr(o.value) for o in Order) + ", 'auto'"
        ap.error(
            f"traversal order 'block_snake' needs --snake-group (the reversal "
            f"window in KV tiles); valid orders are: {valid}"
        )
    adapt = args.attn_order == "auto"

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not adapt:
        # 'auto' keeps the arch's configured order as the pre-seed starting
        # point; the controller re-seeds/re-picks it from there.
        cfg = cfg.with_(attn_order=args.attn_order)
    cfg = cfg.with_(snake_group=args.snake_group)
    lm = build_model(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, step = restore_pytree({"params": params}, args.ckpt_dir)
        params = state["params"]
        print(f"restored params from step {step}")

    drafter = None
    if args.draft != "none":
        from repro.serve import make_drafter

        draft_lm, draft_params = lm, params
        if args.draft == "model" and args.draft_model:
            draft_cfg = get_config(args.draft_model)
            if args.reduced:
                draft_cfg = draft_cfg.reduced()
            draft_lm = build_model(draft_cfg)
            draft_params = draft_lm.init(jax.random.PRNGKey(1))
        drafter = make_drafter(
            args.draft,
            lm=draft_lm,
            params=draft_params,
            n_slots=args.batch_size,
            max_len=args.max_len,
            page_size=args.page_size,
            prefill_chunk=args.prefill_chunk,
        )

    faults = None
    if args.chaos_fetch_fail > 0 or args.chaos_step_fail > 0:
        faults = FaultPlan()
        if args.chaos_fetch_fail > 0:
            faults.fetch_fail(0, times=args.chaos_fetch_fail)
        if args.chaos_step_fail > 0:
            faults.fail_device_step(args.chaos_step_fail)

    eng = ServeEngine(
        lm,
        params,
        batch_size=args.batch_size,
        max_len=args.max_len,
        scheduler=pick_scheduler(args.scheduler, cfg),
        page_size=args.page_size,
        token_budget=args.token_budget,
        prefill_chunk=args.prefill_chunk,
        prefix_sharing=not args.no_prefix_sharing,
        llc_every=args.llc_every,
        llc_capacity_bytes=(
            args.llc_capacity_mib * 2**20 if args.llc_capacity_mib else None
        ),
        log_every_steps=args.log_every,
        adapt_order=adapt,
        adapt_epoch=args.adapt_epoch,
        adapt_hysteresis=args.adapt_hysteresis,
        adapt_confirm=args.adapt_confirm,
        autotune_cache=args.autotune_cache,
        admission=args.admission,
        max_queue=args.max_queue,
        admit_watermark=args.admit_watermark,
        max_preemptions=args.max_preemptions,
        pool_pages=args.pool_pages,
        host_pages=args.host_pages,
        spill_watermark=args.spill_watermark,
        prefetch_depth=args.prefetch_depth,
        drafter=drafter,
        draft_len=args.draft_len,
        faults=faults,
    )
    if adapt and eng.order_ctl is not None:
        src = eng.order_ctl.seeded_from
        seeded = "seeded from autotune cache" if src else "no autotune-cache hit"
        print(
            f"order adaptation on: starting order={eng.order_ctl.order.value} "
            f"({seeded}), epoch={args.adapt_epoch}, "
            f"hysteresis={args.adapt_hysteresis}, confirm={args.adapt_confirm}"
        )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            tokens=rng.integers(2, cfg.vocab, size=rng.integers(4, 32)).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
            rid=i,
            deadline_s=args.deadline_s,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    results = eng.generate(reqs)
    dt = time.time() - t0
    ok = [r for r in results if r.status == "ok"]
    tok = sum(r.steps for r in results)
    print(f"served {len(results)} requests, {tok} tokens in {dt:.2f}s ({tok/dt:.1f} tok/s)")
    if len(ok) < len(results):
        by = {}
        for r in results:
            by[r.status] = by.get(r.status, 0) + 1
        print("  statuses: " + ", ".join(f"{k}={v}" for k, v in sorted(by.items())))
    stats = eng.last_stats
    if stats is not None:
        print(
            f"  {stats.mixed_steps} mixed steps ({stats.wide_steps} wide), "
            f"{stats.pages_adopted} prefix pages adopted "
            f"({stats.prompt_tokens_adopted} tokens), "
            f"{stats.cow_forks} CoW forks"
        )
        if stats.preemptions or stats.shed or stats.deadline_miss or stats.failed:
            print(
                f"  resilience: {stats.preemptions} preemptions "
                f"({stats.restore_tokens} tokens re-prefilled), "
                f"{stats.shed} shed, {stats.deadline_miss} deadline, "
                f"{stats.cancelled} cancelled, {stats.failed} failed"
            )
        if stats.draft_tokens:
            print(
                f"  speculative: {stats.draft_tokens} drafted, "
                f"{stats.accepted_tokens} accepted "
                f"({stats.acceptance_rate:.0%}), "
                f"{stats.rollback_tokens} rolled back"
            )
        if stats.spills or stats.tier_fetches:
            hit_rate = stats.prefetch_hits / max(stats.tier_fetches, 1)
            print(
                f"  tiering: {stats.spills} spills, {stats.tier_fetches} "
                f"fetches (hit rate {hit_rate:.0%}, "
                f"{stats.prefetch_wasted} wasted)"
            )
    for r in results[:4]:
        print(f"  rid={r.rid} -> {r.tokens.tolist()}")

    if args.metrics_out:
        from repro.obs import write_metrics_jsonl

        n = write_metrics_jsonl(
            eng.obs, args.metrics_out, extra={"arch": args.arch}
        )
        print(f"wrote {n} metric series -> {args.metrics_out}")
    if args.trace_out:
        eng.tracer.write(args.trace_out)
        print(
            f"wrote {len(eng.tracer.events())} trace events -> {args.trace_out} "
            "(open in chrome://tracing or ui.perfetto.dev)"
        )


if __name__ == "__main__":
    main()
