"""Production mesh builders.

Functions, never module-level constants: importing this module must not
touch jax device state (assignment rule; also keeps smoke tests on 1 CPU
device honest).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _axis_types(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_axis_types(len(axes)))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=_axis_types(2)
    )
