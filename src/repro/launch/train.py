"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
      --steps 100 --batch 8 --seq 256 --mesh 1x1

Full-size configs target the production mesh (run under a real TPU runtime);
--reduced runs the same code path end-to-end on CPU (examples/train_lm.py
drives a ~100M-param variant through a few hundred steps).
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import ParallelConfig, TrainConfig, get_config
from repro.core.schedule import Order
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model
from repro.train.fault_tolerance import FailureInjector
from repro.train.loop import run_training


def parse_mesh(s: str):
    if s == "production":
        return make_production_mesh()
    if s == "multipod":
        return make_production_mesh(multi_pod=True)
    parts = [int(x) for x in s.split("x")]
    assert len(parts) == 2, "mesh must be DxM, 'production', or 'multipod'"
    return make_local_mesh(*parts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=None, help="override width")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adamw_factored"])
    ap.add_argument("--attn-order", default="sawtooth",
                    choices=[o.value for o in Order],
                    help="KV traversal order (core/schedule.py Traversal IR)")
    ap.add_argument("--snake-group", type=int, default=None,
                    help="block_snake reversal window in KV tiles "
                    "(default: schedule default; sweep with "
                    "benchmarks/hillclimb.py --sweep-orders)")
    ap.add_argument(
        "--attn-impl",
        default=None,
        choices=["auto", "pallas", "pallas_interpret", "xla", "jnp", "reference"],
        help="attention impl; fused flash backward for pallas*/xla, "
        "'jnp' keeps the recompute-VJP fallback",
    )
    ap.add_argument("--bwd-q-block", type=int, default=None,
                    help="fused-backward q tile (default: q_block)")
    ap.add_argument("--bwd-kv-block", type=int, default=None,
                    help="fused-backward kv tile (default: kv_block)")
    ap.add_argument("--crash-at", type=int, default=None, help="inject failure (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the obs metrics registry as JSONL here "
                         "(step time/throughput/loss/grad-norm series)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write step/checkpoint spans as Chrome-trace JSON")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    overrides = {"attn_order": args.attn_order, "snake_group": args.snake_group}
    if args.attn_impl:
        overrides.update(attn_impl=args.attn_impl)
    if args.bwd_q_block:
        overrides.update(bwd_q_block=args.bwd_q_block)
    if args.bwd_kv_block:
        overrides.update(bwd_kv_block=args.bwd_kv_block)
    if args.d_model:
        overrides.update(d_model=args.d_model)
    if args.layers:
        overrides.update(n_layers=args.layers)
    cfg = cfg.with_(**overrides)

    lm = build_model(cfg)
    mesh = parse_mesh(args.mesh)
    tcfg = TrainConfig(
        lr=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir,
        optimizer=args.optimizer,
        seed=args.seed,
    )
    pcfg = ParallelConfig(
        fsdp_axes=("data",), data_axes=("data",), microbatches=args.microbatches
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    injector = FailureInjector(crash_at=(args.crash_at,)) if args.crash_at else None
    res = run_training(
        lm, tcfg, pcfg, mesh, steps=args.steps, data_cfg=dcfg, injector=injector
    )
    print(
        f"done: final_step={res.final_step} resumed_from={res.resumed_from} "
        f"first_loss={res.losses[0] if res.losses else None} "
        f"last_loss={res.losses[-1] if res.losses else None} "
        f"interrupted={res.interrupted}"
    )
    if args.metrics_out and res.registry is not None:
        from repro.obs import write_metrics_jsonl

        n = write_metrics_jsonl(
            res.registry, args.metrics_out, extra={"arch": args.arch}
        )
        print(f"wrote {n} metric series -> {args.metrics_out}")
    if args.trace_out and res.tracer is not None:
        res.tracer.write(args.trace_out)
        print(f"wrote {len(res.tracer.events())} trace events -> {args.trace_out}")


if __name__ == "__main__":
    main()
