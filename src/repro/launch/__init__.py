# NOTE: repro.launch.dryrun sets XLA_FLAGS at import; import it only as a
# script entry point (python -m repro.launch.dryrun), never from library code.
from repro.launch.mesh import make_local_mesh, make_production_mesh

__all__ = ["make_local_mesh", "make_production_mesh"]
