import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh) cell.

For each cell this
  1. builds the full-size config and the pjit-sharded step function
     (train_step / prefill_step / serve_step per the shape kind),
  2. ``.lower().compile()``s it against ShapeDtypeStruct inputs (no
     allocation) on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh,
  3. records memory_analysis / cost_analysis / per-collective HLO bytes and
     the derived roofline terms into artifacts/dryrun/<cell>.json.

Must be run as a module: PYTHONPATH=src python -m repro.launch.dryrun
(the XLA_FLAGS lines above run before any jax import — assignment rule).

long_500k is skipped (and recorded as such) for pure full-attention archs;
SWA / SSM / hybrid archs run it (DESIGN.md §5).
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline as rf
from repro.configs import ARCH_IDS, SHAPES, ParallelConfig, TrainConfig, get_config
from repro.dist import sharding as shd
from repro.dist.context import activation_rules
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import build_model
from repro.train.step import make_train_state, make_train_step, state_shardings

DEFAULT_OUT = "artifacts/dryrun"

# archs where long_500k decode is meaningful (sub-quadratic / bounded KV)
LONG_OK = {"mixtral-8x7b", "mamba2-130m", "zamba2-2_7b"}
ALL_ARCHS = [a for a in ARCH_IDS if a != "paper-gb10"]


def dryrun_parallel_cfg(mesh, shape_kind: str, overrides: dict | None = None) -> ParallelConfig:
    kw: dict = {}
    if "pod" not in mesh.shape:
        kw["fsdp_axes"] = ("data",)
        kw["data_axes"] = ("data",)
    if shape_kind == "train":
        kw["microbatches"] = 8
    if overrides:
        kw.update(overrides)
    return ParallelConfig(**kw)


def cfg_for_dryrun(arch: str, overrides: dict | None = None):
    cfg = get_config(arch)
    kw = dict(attn_impl="xla", remat="full")
    if overrides:
        kw.update(overrides)
    return cfg.with_(**kw)


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    mesh_name: str,
    *,
    cfg_overrides: dict | None = None,
    par_overrides: dict | None = None,
    reduced: bool = False,
):
    """Returns (record dict, lowered, compiled)."""
    shape = SHAPES[shape_name]
    cfg = cfg_for_dryrun(arch, cfg_overrides)
    if reduced:
        cfg = cfg.reduced().with_(attn_impl="xla")
        shape = shape.reduced()
    pcfg = dryrun_parallel_cfg(mesh, shape.kind, par_overrides)
    lm = build_model(cfg)

    rules = None
    if pcfg.seq_shard_activations:
        from jax.sharding import PartitionSpec as P

        dp = tuple(a for a in pcfg.data_axes if a in mesh.shape)
        rules = {
            "residual": P(dp, pcfg.tensor_axis, None),
            "moe_tokens": P((dp + (pcfg.tensor_axis,)) if pcfg.tensor_axis in mesh.shape else dp, None),
        }

    t0 = time.time()
    with jax.set_mesh(mesh), activation_rules(rules):
        params_sds = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
        batch_sds = lm.input_specs(shape, reduced=reduced)
        if shape.kind == "train":
            micro = pcfg.microbatches
            if shape.global_batch % max(micro, 1):
                pcfg = dataclasses.replace(pcfg, microbatches=1)
            tcfg = TrainConfig()
            state_sds = jax.eval_shape(
                lambda k: make_train_state(lm, tcfg, k), jax.random.PRNGKey(0)
            )
            step, _ = make_train_step(lm, tcfg, pcfg, mesh)
            st_sh = state_shardings(state_sds, pcfg, mesh)
            b_sh = shd.batch_shardings(batch_sds, pcfg, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            p_sh = shd.param_shardings(params_sds, pcfg, mesh)
            b_sh = shd.batch_shardings(batch_sds, pcfg, mesh)
            fn = lambda p, b: lm.prefill(p, b, shape.seq_len)
            lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
                params_sds, batch_sds
            )
        else:  # decode
            max_len = shape.seq_len
            _, caches_sds = jax.eval_shape(
                lambda p, b: lm.prefill(p, b, max_len), params_sds, batch_sds
            )
            tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            p_sh = shd.param_shardings(params_sds, pcfg, mesh)
            t_sh = shd.batch_shardings(tok_sds, pcfg, mesh)
            c_sh = shd.cache_shardings(caches_sds, pcfg, mesh)
            lowered = jax.jit(
                lm.decode_step, in_shardings=(p_sh, t_sh, c_sh), donate_argnums=(2,)
            ).lower(params_sds, tok_sds, caches_sds)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = dict(compiled.cost_analysis() or {})
    hlo_text = compiled.as_text()
    coll = hlo_mod.collective_bytes(hlo_text)
    chips = mesh.devices.size
    terms = rf.analyze(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        cost={k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
        coll=coll,
        cfg=cfg,
        shape_cfg=shape,
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "cost": {"flops": cost.get("flops", 0.0), "bytes_accessed": cost.get("bytes accessed", 0.0)},
        "collectives": coll,
        "roofline": terms.to_row(),
        "param_count": rf.param_count(cfg),
        "active_param_count": rf.active_param_count(cfg),
    }
    return record, lowered, compiled


def should_skip(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return "long_500k needs sub-quadratic attention; pure full-attention arch (DESIGN.md §5)"
    return None


# --------------------------------------------------------------------------
# trip-count-corrected roofline (XLA cost_analysis counts while bodies ONCE;
# we compile python-unrolled depth-1 and depth-2 variants and extrapolate
# affinely to full depth — exact for homogeneous layer stacks)
# --------------------------------------------------------------------------


def _depth_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.ssm.shared_attn_every
    return cfg.n_layers


def _depth_overrides(cfg, units: int) -> dict:
    if cfg.family == "hybrid":
        return {"n_layers": units * cfg.ssm.shared_attn_every}
    if cfg.family == "encdec":
        return {"n_layers": units, "n_encoder_layers": units}
    return {"n_layers": units}


def extrapolate_cell(arch: str, shape_name: str, mesh, mesh_name: str,
                     *, cfg_overrides: dict | None = None,
                     par_overrides: dict | None = None):
    """Roofline record with while-trip-count correction."""
    full_cfg = cfg_for_dryrun(arch, cfg_overrides)
    units = _depth_units(full_cfg)
    recs = {}
    for u in (1, 2):
        ov = dict(cfg_overrides or {})
        ov.update(_depth_overrides(full_cfg, u))
        ov["scan_layers"] = False
        pov = dict(par_overrides or {})
        pov["microbatches"] = 1  # flops/bytes are ~batch-linear, m-invariant
        rec, _, _ = lower_cell(
            arch, shape_name, mesh, mesh_name, cfg_overrides=ov, par_overrides=pov
        )
        recs[u] = rec

    def lin(f):
        # affine in depth; clamped below at the measured depth-2 value (XLA
        # CSE can make depth-1 modules anomalously expensive, which would
        # extrapolate to nonsense-negative slopes)
        a, b = f(recs[1]), f(recs[2])
        return max(a + (units - 1) * (b - a), b, 0.0)

    cost = {
        "flops": lin(lambda r: r["cost"]["flops"]),
        "bytes accessed": lin(lambda r: r["cost"]["bytes_accessed"]),
    }
    kinds = set(recs[1]["collectives"]) | set(recs[2]["collectives"])
    coll = {k: lin(lambda r: r["collectives"].get(k, 0.0)) for k in kinds}
    shape = SHAPES[shape_name]
    terms = rf.analyze(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=mesh.devices.size,
        cost=cost,
        coll=coll,
        cfg=full_cfg,
        shape_cfg=shape,
    )
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "ok",
        "method": "unrolled depth-1/2 affine extrapolation to full depth "
                  f"({units} units), microbatches=1",
        "depth_units": units,
        "cost": cost,
        "collectives": coll,
        "roofline": terms.to_row(),
        "depth1": {"cost": recs[1]["cost"], "collectives": recs[1]["collectives"]},
        "depth2": {"cost": recs[2]["cost"], "collectives": recs[2]["collectives"]},
    }


def run_extrapolation(archs, shapes, out_dir: str, *, resume: bool = True,
                      mesh_name: str = "single", suffix: str = "rf",
                      cfg_overrides: dict | None = None,
                      par_overrides: dict | None = None):
    """§Roofline pass (single-pod only, per assignment)."""
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        for shape_name in shapes:
            cell = f"{arch}__{shape_name}__{mesh_name}"
            path = os.path.join(out_dir, f"{cell}.{suffix}.json")
            if resume and os.path.exists(path):
                with open(path) as f:
                    results.append(json.load(f))
                print(f"[skip-cached] rf {cell}")
                continue
            if should_skip(arch, shape_name):
                continue
            mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
            try:
                rec = extrapolate_cell(
                    arch, shape_name, mesh, mesh_name,
                    cfg_overrides=cfg_overrides, par_overrides=par_overrides,
                )
                r = rec["roofline"]
                print(
                    f"[rf] {cell}: bottleneck={r['bottleneck']} "
                    f"Tc={r['compute_s']:.4f} Tm={r['memory_s']:.4f} "
                    f"Tx={r['collective_s']:.4f} util={r['hw_flops_util']:.3f} "
                    f"useful={r['useful_ratio']:.3f}"
                )
            except Exception as e:
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"[rf ERROR] {cell}: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            results.append(rec)
    return results


def run(archs, shapes, meshes, out_dir: str, *, resume: bool = True, save_hlo: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                cell = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(out_dir, cell + ".json")
                if resume and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    results.append(rec)
                    print(f"[skip-cached] {cell}: {rec['status']}")
                    continue
                skip = should_skip(arch, shape_name)
                if skip:
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "skipped", "reason": skip,
                    }
                else:
                    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
                    try:
                        rec, lowered, compiled = lower_cell(
                            arch, shape_name, mesh, mesh_name
                        )
                        if save_hlo:
                            with open(os.path.join(out_dir, cell + ".hlo.txt"), "w") as f:
                                f.write(compiled.as_text())
                        r = rec["roofline"]
                        print(
                            f"[ok] {cell}: compile={rec['compile_s']}s "
                            f"flops/dev={rec['cost']['flops']:.3e} "
                            f"coll/dev={rec['collectives'].get('total',0):.3e}B "
                            f"bottleneck={r['bottleneck']} util={r['hw_flops_util']:.3f}"
                        )
                        del lowered, compiled
                    except Exception as e:
                        rec = {
                            "arch": arch, "shape": shape_name, "mesh": mesh_name,
                            "status": "error", "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-4000:],
                        }
                        print(f"[ERROR] {cell}: {type(e).__name__}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors / {len(results)} cells")
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id(s), comma-sep, or 'all'")
    ap.add_argument("--shape", default="all", help="shape name(s) or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument(
        "--roofline", action="store_true",
        help="run the trip-count-corrected roofline pass (single-pod)",
    )
    args = ap.parse_args()

    archs = ALL_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    if args.roofline:
        results = run_extrapolation(archs, shapes, args.out, resume=not args.no_resume)
    else:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        results = run(
            archs, shapes, meshes, args.out, resume=not args.no_resume, save_hlo=args.save_hlo
        )
    if any(r["status"] == "error" for r in results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
