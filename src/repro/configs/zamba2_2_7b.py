"""Zamba2-2.7B [arXiv:2411.15242; hf]: 54L d2560, Mamba2 backbone +
shared attention block (32H, kv=32) every 6 layers, d_ff=10240,
ssm_state=64, vocab 32000."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2_7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=128, shared_attn_every=6),
    param_dtype="bfloat16",
)
