"""Config system: model / parallelism / train / shape configs + registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``repro.configs.registry`` maps ``--arch`` ids to them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "ModelConfig",
    "ShapeConfig",
    "ParallelConfig",
    "TrainConfig",
    "SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length
    # hybrid (zamba2-style): apply a shared attention block every k layers
    shared_attn_every: int = 0    # 0 = pure SSM


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # defaults to d_model // n_heads
    qkv_bias: bool = False
    window: Optional[int] = None            # sliding-window attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encdec
    n_encoder_layers: int = 0
    # vlm / audio stubs: number of prefix embedding positions fed by the
    # (stubbed) modality frontend for train/prefill shapes
    n_prefix_embeds: int = 0
    # serving
    eos_id: int = 1                          # end-of-sequence token id
    # execution
    dtype: str = "bfloat16"                  # activation/compute dtype
    param_dtype: str = "float32"
    attn_impl: str = "auto"                  # auto | pallas | pallas_interpret
                                             # | xla (fused blockwise bwd)
                                             # | jnp (recompute-VJP fallback)
                                             # | reference
    attn_order: str = "sawtooth"             # KV traversal order: cyclic |
                                             # sawtooth (the paper's technique,
                                             # on by default) | block_snake
                                             # (capacity-bounded reversal —
                                             # core/schedule.py Traversal IR)
    snake_group: Optional[int] = None        # block_snake reversal window in
                                             # KV tiles; None = schedule
                                             # default. Size to the modeled
                                             # LLC (benchmarks/hillclimb.py
                                             # --sweep-orders).
    q_block: int = 512
    kv_block: int = 512
    bwd_q_block: Optional[int] = None        # fused-backward kernel tiles;
    bwd_kv_block: Optional[int] = None       # None = inherit q_block/kv_block
                                             # (autotuned separately — the bwd
                                             # working set is larger; see
                                             # benchmarks/hillclimb.py)
    remat: str = "full"                      # none | full | dots
    score_dtype: str = "float32"             # attention score/probs dtype in
                                             # the blockwise XLA path (bf16
                                             # halves the dominant HBM term)
    moe_serve_dropless: bool = True          # serve MoE via ragged_dot
    ssd_impl: str = "auto"                   # pallas | pallas_interpret | xla
    kv_cache_dtype: str = "bfloat16"         # bfloat16 | int8 (per-vector
                                             # symmetric scales; halves the
                                             # decode-cache HBM footprint)
    kv_layout: str = "contiguous"            # contiguous | paged (shared page
                                             # pool + per-sequence block
                                             # tables; full attention only —
                                             # DESIGN.md §8)
    page_size: Optional[int] = None          # KV page rows; defaults to
                                             # kv_block so pages coincide with
                                             # the schedule's KV tiles
    scan_layers: bool = True                 # False: python-unrolled layer loop
                                             # (dry-run roofline extrapolation —
                                             # XLA counts while bodies once)
    logit_softcap: Optional[float] = None

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def parameter_dtype(self):
        return jnp.dtype(self.param_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab=256,
            head_dim=16,
            q_block=64,
            kv_block=64,
            param_dtype="float32",
            dtype="float32",
            remat="none",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=32
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm,
                state_dim=16,
                head_dim=16,
                chunk=32,
                shared_attn_every=2 if self.ssm.shared_attn_every else 0,
            )
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
        if self.n_prefix_embeds:
            kw["n_prefix_embeds"] = 8
        if self.window is not None:
            kw["window"] = 32
        return self.with_(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 128), global_batch=min(self.global_batch, 2)
        )


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How logical dims map onto the mesh + runtime knobs."""

    fsdp_axes: Sequence[str] = ("pod", "data")   # parameter/optimizer sharding
    tensor_axis: str = "model"                    # TP / EP axis
    data_axes: Sequence[str] = ("pod", "data")   # batch sharding
    seq_shard_activations: bool = False           # sequence-shard residuals
    microbatches: int = 1                         # gradient accumulation
    grad_compression: str = "none"                # none | int8_pod
    zero_grads: bool = True                       # reduce-scattered grads


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    z_loss: float = 1e-4
    optimizer: str = "adamw"          # adamw | adamw_factored
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
