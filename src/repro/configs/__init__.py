from repro.configs.base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
)
from repro.configs.registry import ARCH_IDS, all_configs, get_config

__all__ = [
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "ParallelConfig",
    "ShapeConfig",
    "SSMConfig",
    "TrainConfig",
    "ARCH_IDS",
    "all_configs",
    "get_config",
]
