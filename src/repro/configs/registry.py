"""--arch registry: maps public ids (hyphens or underscores) to configs."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "olmoe-1b-7b",
    "mixtral-8x7b",
    "llama3-405b",
    "deepseek-7b",
    "qwen2-72b",
    "codeqwen1_5-7b",
    "seamless-m4t-medium",
    "mamba2-130m",
    "zamba2-2_7b",
    "phi-3-vision-4_2b",
    "paper-gb10",
]


def _module_for(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    norm = arch.replace(".", "_").replace("-", "_")
    for known in ARCH_IDS:
        if _module_for(known) == norm:
            mod = importlib.import_module(f"repro.configs.{_module_for(known)}")
            return mod.CONFIG
    raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS if a != "paper-gb10"}
