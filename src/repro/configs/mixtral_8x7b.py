"""Mixtral-8x7B [arXiv:2401.04088; hf]: 32L d4096 32H (GQA kv=8) MoE 8e top-2,
d_ff=14336, vocab 32000, SWA window 4096."""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    rope_theta=1000000.0,
    param_dtype="bfloat16",
)
