"""Mamba2-130m [arXiv:2405.21060; unverified]: 24L d768, attention-free SSD,
ssm_state=128, vocab 50280. Sawtooth KV scheduling inapplicable
(DESIGN.md §5)."""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128),
    tie_embeddings=True,
)
