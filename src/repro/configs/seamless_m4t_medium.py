"""SeamlessM4T-medium [arXiv:2308.11596; hf]: enc-dec, 12L enc + 12L dec,
d1024 16H (kv=16) d_ff=4096, vocab 256206. Modality frontend is a stub:
input_specs() provides precomputed frame embeddings (assignment rule)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    param_dtype="bfloat16",
)
