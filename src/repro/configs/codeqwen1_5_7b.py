"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: 32L d4096 32H (kv=32) d_ff=13440,
vocab 92416, qwen1.5-arch (QKV bias)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1_5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1000000.0,
    param_dtype="bfloat16",
)
