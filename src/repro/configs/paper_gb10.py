"""The paper's own benchmark configuration (GB10 CuTile experiments, §4.3):
single attention workload, batch 8, seq 128K, head_dim 64, tile 64."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-gb10",
    family="dense",
    n_layers=1,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=256,
    vocab=256,
    head_dim=64,
    q_block=64,
    kv_block=64,
)
