"""Phi-3-vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
backbone 32L d3072 32H (kv=32) d_ff=8192, vocab 32064 + CLIP frontend.
Frontend is a stub: input_specs() provides precomputed patch embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4_2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    n_prefix_embeds=1024,   # ~1 image of CLIP-L/14 patches at 576px
    rope_theta=10000.0,
    param_dtype="bfloat16",
)
