"""Persistent autotune cache: shared key normalization + engine-start reader.

``benchmarks/hillclimb.py`` appends sweep winners to
``artifacts/hillclimb/autotune_cache.jsonl`` (one stamped JSONL record per
winner, ``repro.obs.export.append_jsonl`` format). This module is the other
half of that contract — the *reader* a serve engine consults at startup to
seed its initial traversal order (DESIGN.md §11) — plus the key
normalization both sides share so writer-side keys and reader-side lookups
can never drift:

* :func:`canonicalize_key` — the canonical JSON-able form of a key dict
  (stable types, insertion-order-free); the hillclimb writer passes its
  keys through this before appending.
* :func:`normalize_autotune_key` — hashable ``(kind, key)`` identity used
  for last-writer-wins dedup on load.
* :func:`load_autotune_cache` — parse + dedup the JSONL; unknown
  ``schema_version`` entries are skipped with a warning, never a crash
  (a newer writer must not brick an older reader).
* :func:`lookup_order_winner` — nearest-bucket lookup for ``order_sweep``
  entries: exact arch match required, then closest (seq_bucket,
  capacity_mib) in log-space, backend match used as a tiebreaker. Sweeps
  are run at a handful of footprints; an engine serving max_len=4096 should
  still benefit from the s8192 sweep next door.
"""

from __future__ import annotations

import math
import warnings
from typing import Optional

import numpy as np

from repro.obs.export import SCHEMA_VERSION, load_jsonl

__all__ = [
    "canonicalize_key",
    "normalize_autotune_key",
    "load_autotune_cache",
    "lookup_order_winner",
]


def canonicalize_key(key: dict) -> dict:
    """Canonical JSON-able form of an autotune-cache key dict.

    Ints stay ints (bools are rejected — a key field flipping between
    ``True`` and ``1`` is a schema bug, not a normalization job), floats are
    rounded to 6 places (capacity_mib arithmetic noise must not split cache
    entries), everything else becomes ``str``. Keys are emitted sorted so
    two writers building the same logical key serialize identically.
    """
    out = {}
    for k in sorted(key):
        v = key[k]
        if isinstance(v, bool):
            raise TypeError(f"autotune key field {k!r} is a bool; use an int or str")
        if isinstance(v, (int, np.integer)):
            out[str(k)] = int(v)
        elif isinstance(v, (float, np.floating)):
            out[str(k)] = round(float(v), 6)
        elif v is None:
            out[str(k)] = None
        else:
            out[str(k)] = str(v)
    return out


def normalize_autotune_key(kind: str, key: dict) -> tuple:
    """Hashable identity of one cache entry: ``(kind, sorted key items)``.

    Both the hillclimb writer (via :func:`canonicalize_key`) and the
    :func:`load_autotune_cache` dedup use this, so "same key" means the
    same thing on both sides of the JSONL file.
    """
    canon = canonicalize_key(key)
    return (str(kind), tuple(canon.items()))


def load_autotune_cache(path: str) -> list[dict]:
    """Load + dedup the autotune-cache JSONL; last writer wins per key.

    Returns the surviving records in file order (oldest first). Records
    with an unknown ``schema_version`` are skipped with a warning; records
    without a parseable key/kind are skipped silently (they cannot be
    addressed, so they cannot be looked up either). Missing file -> [].
    """
    try:
        rows = load_jsonl(path)
    except FileNotFoundError:
        return []
    dedup: dict[tuple, dict] = {}
    for rec in rows:
        sv = rec.get("schema_version")
        if sv != SCHEMA_VERSION:
            warnings.warn(
                f"{path}: skipping autotune-cache entry with unknown "
                f"schema_version={sv!r} (reader speaks {SCHEMA_VERSION})",
                stacklevel=2,
            )
            continue
        kind, key = rec.get("kind"), rec.get("key")
        if not isinstance(kind, str) or not isinstance(key, dict):
            continue
        dedup[normalize_autotune_key(kind, key)] = rec
    return list(dedup.values())


def _log_dist(a: float, b: float) -> float:
    """|log2(a/b)| with zero/negative guarded — bucket distances multiply
    across octaves, so nearest-bucket must compare ratios, not differences
    (4096 is 'one octave' from both 2048 and 8192)."""
    a, b = max(float(a), 1e-9), max(float(b), 1e-9)
    return abs(math.log2(a / b))


def lookup_order_winner(
    entries: list[dict],
    *,
    arch: str,
    seq_bucket: int,
    capacity_mib: float,
    backend: Optional[str] = None,
) -> Optional[dict]:
    """Best ``order_sweep`` winner for (arch, seq, capacity[, backend]).

    Exact arch match is required (traversal winners depend on head
    geometry); among those, the entry with the smallest log-space
    (seq_bucket, capacity_mib) distance wins, ties broken toward a matching
    backend. Returns the full record (``rec["winner"]`` holds
    order/snake_group) or None when no arch-matching sweep exists.
    """
    best, best_rank = None, None
    for rec in entries:
        if rec.get("kind") != "order_sweep":
            continue
        key = rec.get("key", {})
        if str(key.get("arch")) != str(arch):
            continue
        rank = (
            _log_dist(key.get("seq_bucket", 0), seq_bucket)
            + _log_dist(key.get("capacity_mib", 0), capacity_mib),
            0 if backend is None or key.get("backend") == backend else 1,
        )
        if best_rank is None or rank < best_rank:
            best, best_rank = rec, rank
    return best
