"""Sinks: registry → JSONL metrics dump, and a shared JSONL record format.

The registry/tracer never write anything themselves; these helpers are the
only place bytes leave the process, so the no-sink serve path stays free of
I/O. Two consumers share one line format:

* ``write_metrics_jsonl(registry, path)`` — one line per metric series
  (``{"schema_version", "ts", "kind", "name", "labels", ...value fields}``),
  the structured companion to BENCH_serve.json that
  ``benchmarks/check_metrics.py`` validates in CI;
* ``append_jsonl(path, record)`` — append one stamped record; used by
  ``benchmarks/hillclimb.py`` to persist sweep winners
  (``artifacts/hillclimb/autotune_cache.jsonl``), seeding the persistent
  autotune cache format ROADMAP item 4's engine-start lookup will consult.

``SCHEMA_VERSION`` covers both: bump it when a field changes meaning, and
trend-line tooling can partition on it.
"""

from __future__ import annotations

import json
import os
import time
from typing import Iterator, Optional

from repro.obs.metrics import Registry, render_series

__all__ = [
    "SCHEMA_VERSION",
    "metric_records",
    "write_metrics_jsonl",
    "append_jsonl",
    "load_jsonl",
]

SCHEMA_VERSION = 1


def metric_records(
    registry: Registry, *, ts: Optional[float] = None, extra: Optional[dict] = None
) -> Iterator[dict]:
    """One JSON-ready dict per registered series."""
    ts = time.time() if ts is None else ts
    for m in registry.series():
        rec = {
            "schema_version": SCHEMA_VERSION,
            "ts": ts,
            "kind": m.kind,
            "name": m.name,
            "labels": dict(m.labels),
            "series": render_series(m.name, m.labels),
        }
        if m.kind == "histogram":
            cum, buckets = 0, []
            for le, c in zip(m.buckets + ("+Inf",), m.counts):
                cum += c
                buckets.append([le, cum])
            rec.update(
                buckets=buckets, count=m.count, sum=m.sum, nan_count=m.nan_count
            )
        else:
            rec["value"] = m.value
        if extra:
            rec.update(extra)
        yield rec


def write_metrics_jsonl(
    registry: Registry, path: str, *, extra: Optional[dict] = None
) -> int:
    """Dump every series as one JSONL line; returns the line count."""
    n = 0
    with open(path, "w") as f:
        for rec in metric_records(registry, extra=extra):
            f.write(json.dumps(rec) + "\n")
            n += 1
    return n


def append_jsonl(path: str, record: dict, *, kind: str) -> dict:
    """Append one ``kind``-tagged record, stamped with schema version and
    wall time. Returns the stamped record."""
    rec = {"schema_version": SCHEMA_VERSION, "ts": time.time(), "kind": kind}
    rec.update(record)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


def load_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
