"""``repro.obs`` — unified telemetry: metrics registry, span traces, sinks,
and the live modeled-LLC sampler.

Layering (DESIGN.md §10): hot paths record into a :class:`Registry` and a
:class:`Tracer` (cheap, in-process, no I/O); sinks (``repro.obs.export``)
pull snapshots into JSONL / Chrome-trace files on demand; consumers are CI
schema checks (``benchmarks/check_metrics.py``), trace viewers, and —
next — the online traversal-order adaptation that reads
``llc.modeled_miss_bytes`` (ROADMAP item 4).

``span``/``instant`` are process-default-tracer conveniences; engines and
the train loop carry their own instances so streams don't interleave.
"""

from repro.obs.autotune import (
    canonicalize_key,
    load_autotune_cache,
    lookup_order_winner,
    normalize_autotune_key,
)
from repro.obs.export import (
    SCHEMA_VERSION,
    append_jsonl,
    load_jsonl,
    metric_records,
    write_metrics_jsonl,
)
from repro.obs.llc import DEFAULT_CAPACITY_BYTES, LLCSampler
from repro.obs.metrics import (
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    Registry,
    default_registry,
)
from repro.obs.trace import SpanEvent, Tracer, default_tracer, instant, span

__all__ = [
    "SCHEMA_VERSION",
    "append_jsonl",
    "canonicalize_key",
    "load_autotune_cache",
    "load_jsonl",
    "lookup_order_winner",
    "normalize_autotune_key",
    "metric_records",
    "write_metrics_jsonl",
    "DEFAULT_CAPACITY_BYTES",
    "LLCSampler",
    "LATENCY_BUCKETS_S",
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "default_registry",
    "SpanEvent",
    "Tracer",
    "default_tracer",
    "instant",
    "span",
]
