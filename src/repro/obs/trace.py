"""Span-based tracing with a ring buffer and Chrome-trace JSON export.

A :class:`Tracer` records *complete* spans (``ph="X"``: name, start, wall
duration) and *instant* events (``ph="i"``: compiles, admissions, watchdog
trips) into a bounded ``deque`` — long serve streams keep the most recent
``capacity`` events instead of growing without bound. Recording is a
``perf_counter_ns`` pair plus one ``deque.append``; no I/O happens until
:meth:`Tracer.write` exports the buffer as Chrome-trace JSON (the
``chrome://tracing`` / Perfetto "JSON Array Format": a ``traceEvents`` list
of events with microsecond ``ts``/``dur``), so a whole serve stream can be
opened as a timeline.

Span nesting needs no explicit parent ids: events on the same pid/tid nest
by timestamp containment, which is exactly how the engine uses it —
``serve.step`` wraps ``serve.plan_step`` and ``serve.device_step`` (the
device span is closed only after the step's outputs are materialized, so it
covers real device time, not async dispatch).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import os
import threading
import time
from typing import Optional

__all__ = ["SpanEvent", "Tracer", "default_tracer", "span", "instant"]


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    name: str
    ts_ns: int                    # perf_counter_ns at span start
    dur_ns: int                   # -1 for instant events
    tid: int
    args: Optional[dict] = None

    @property
    def end_ns(self) -> int:
        return self.ts_ns + max(self.dur_ns, 0)


class Tracer:
    """Bounded in-process span recorder + Chrome-trace exporter."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._events: collections.deque[SpanEvent] = collections.deque(maxlen=capacity)
        self.dropped = 0              # events evicted by the ring buffer

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Record a complete span around the with-body (exceptions included:
        the span still closes, so a crashed step is visible in the trace)."""
        t0 = time.perf_counter_ns()
        try:
            yield self
        finally:
            self._append(
                SpanEvent(
                    name=name,
                    ts_ns=t0,
                    dur_ns=time.perf_counter_ns() - t0,
                    tid=threading.get_ident(),
                    args=args or None,
                )
            )

    def instant(self, name: str, **args) -> None:
        self._append(
            SpanEvent(
                name=name,
                ts_ns=time.perf_counter_ns(),
                dur_ns=-1,
                tid=threading.get_ident(),
                args=args or None,
            )
        )

    def _append(self, ev: SpanEvent) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    def events(self) -> list[SpanEvent]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.dropped = 0

    # ---- export --------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """Chrome-trace "JSON Object Format": ``{"traceEvents": [...]}``.

        Spans export as complete events (``ph="X"``, with ``dur``), instants
        as ``ph="i"`` with thread scope. ``ts``/``dur`` are microseconds
        (floats are legal per the spec); events are sorted by ``ts`` as the
        viewers expect.
        """
        pid = os.getpid()
        out = []
        for ev in sorted(self._events, key=lambda e: e.ts_ns):
            rec = {
                "name": ev.name,
                "cat": "obs",
                "pid": pid,
                "tid": ev.tid,
                "ts": ev.ts_ns / 1e3,
            }
            if ev.dur_ns >= 0:
                rec["ph"] = "X"
                rec["dur"] = ev.dur_ns / 1e3
            else:
                rec["ph"] = "i"
                rec["s"] = "t"
            if ev.args:
                rec["args"] = dict(ev.args)
            out.append(rec)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


_default = Tracer()


def default_tracer() -> Tracer:
    return _default


def span(name: str, **args):
    """``with obs.span("plan_step"):`` against the process-default tracer."""
    return _default.span(name, **args)


def instant(name: str, **args) -> None:
    _default.instant(name, **args)
