"""Periodic modeled-LLC sampler: the paper's cache analysis, live.

The offline benches evaluate ``kernels.traffic.fwd_llc_model`` /
``shared_prefix_llc_model`` at hand-picked footprints; this sampler
evaluates them against the *live* ``serve.kv_pool.PagedKVPool`` state every
``every`` mixed steps and emits the results as registry gauges:

* ``llc.modeled_miss_bytes{order=...,model=fwd}`` — the forward-wavefront
  LRU model at the pool's current longest-row footprint, one gauge per
  candidate traversal order (the engine's current order always included);
* ``llc.modeled_miss_bytes{order=...,model=shared_prefix}`` — the
  cross-row shared-prefix decode model at the live row count / shared-page
  count (emitted only when the pool actually holds shared pages);
* ``llc.footprint_bytes`` / ``llc.capacity_bytes`` / ``llc.active_rows`` /
  ``llc.shared_pages`` — the inputs, so a dashboard can plot modeled misses
  against the footprint that produced them;
* ``llc.best_order_index`` — argmin over the fwd gauges (index into
  :attr:`LLCSampler.orders`), i.e. *the* decision signal the online order
  adaptation (``repro.serve.adapt.OrderAdaptController``) consumes. Beyond
  the gauges (last-write-wins), every sample also appends one entry to
  :attr:`LLCSampler.history` — footprint + per-order modeled miss bytes +
  the order in effect — so controllers and benches can account modeled
  bytes over time, not just read the latest value.

The model replay is host-side Python over O(tiles²) wavefront steps — at
serve page granularity that is thousands of dict operations, so sampling
every step would be felt; ``every`` defaults to 8 and ``every<=0`` disables
the sampler entirely (the zero-overhead default for benches).

``fwd_spec_for`` is deliberately public and deterministic: tests (and
dashboards) re-derive the exact ``FlashGridSpec`` the sampler used at a
given footprint and check gauge parity against a direct ``fwd_llc_model``
call.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.kernels.traffic import (
    FlashGridSpec,
    fwd_llc_model,
    shared_prefix_llc_model,
)
from repro.obs.metrics import Registry

__all__ = ["LLCSampler", "DEFAULT_CAPACITY_BYTES"]

# Default modeled LLC capacity: 3 MiB, matching the fixed-hardware view the
# hillclimb --sweep-orders ranking uses (so live gauges and offline sweep
# winners are comparable on the same axis).
DEFAULT_CAPACITY_BYTES = 3 * 2**20


class LLCSampler:
    """Evaluate the traffic LLC models against live pool state, per epoch."""

    def __init__(
        self,
        registry: Registry,
        *,
        page: int,
        n_heads: int,
        n_kv_heads: int,
        head_dim: int,
        elem_bytes: int,
        current_order: str,
        snake_group: Optional[int] = None,
        orders: Sequence[str] = ("cyclic", "sawtooth"),
        every: int = 8,
        n_workers: int = 8,
        capacity_bytes: float = DEFAULT_CAPACITY_BYTES,
    ):
        self.registry = registry
        self.page = page
        self.n_groups = max(1, n_heads // max(n_kv_heads, 1))
        self.n_kv_heads = n_kv_heads
        self.head_dim = head_dim
        self.elem_bytes = elem_bytes
        self.current_order = str(current_order)
        self.snake_group = snake_group
        # Current order first (it is the one actually running), then the
        # alternates — ≥2 orders total so modeled-vs-live dashboards always
        # have a comparison series.
        self.orders = [self.current_order] + [
            o for o in orders if o != self.current_order
        ]
        self.every = every
        self.n_workers = n_workers
        self.capacity_bytes = float(capacity_bytes)
        self.samples = 0
        # Per-sample record of the fwd-model evaluation: the adaptation
        # controller reads the latest entry to decide a switch, and benches
        # integrate modeled bytes over the run. Bounded so a long-lived
        # server can't grow it without limit.
        self.history: list[dict] = []
        self.history_cap = 4096

    @property
    def last_fwd_miss(self) -> Optional[dict]:
        """Per-order modeled fwd miss bytes of the latest sample (or None)."""
        return self.history[-1]["fwd_miss"] if self.history else None

    # ---- deterministic model inputs (public: tests re-derive these) ----------

    def fwd_spec_for(self, kv_tokens: int) -> FlashGridSpec:
        """The forward-grid spec modeled at a ``kv_tokens``-token footprint:
        a causal pass over the live KV at page-size tiles (page == kv tile by
        construction of the paged pool, DESIGN.md §8)."""
        kv_tokens = max(self.page, -(-kv_tokens // self.page) * self.page)
        return FlashGridSpec(
            seq_q=kv_tokens,
            seq_kv=kv_tokens,
            n_groups=self.n_groups,
            head_dim=self.head_dim,
            q_block=self.page,
            kv_block=self.page,
            elem_bytes=self.elem_bytes,
            causal=True,
        )

    def verify_spec_for(self, kv_tokens: int, step_q: int) -> FlashGridSpec:
        """The grid spec of one speculative *verification* sweep: a
        ``step_q``-token query chunk (K drafts + 1) attending the full
        ``kv_tokens`` footprint. Rectangular and non-causal — the chunk
        reads every prior KV page; only the intra-chunk triangle is masked,
        which at page granularity rounds away. This is the footprint the
        traversal-order models must see under speculative decoding: the
        same KV sweep now amortized over ``step_q`` query rows."""
        kv_tokens = max(self.page, -(-kv_tokens // self.page) * self.page)
        return FlashGridSpec(
            seq_q=max(self.page, -(-step_q // self.page) * self.page),
            seq_kv=kv_tokens,
            n_groups=self.n_groups,
            head_dim=self.head_dim,
            q_block=self.page,
            kv_block=self.page,
            elem_bytes=self.elem_bytes,
            causal=False,
        )

    def pool_footprint(self, pool) -> dict:
        """Live footprint summary: active rows, longest row (tokens),
        distinct held pages, shared (refcount>1) pages, resident KV bytes."""
        lens = [int(x) for x in pool.lens if int(x) > 0]
        held = {pid for pages in pool._slot_pages for pid in pages}
        shared = sum(1 for pid in held if pool._ref[pid] > 1)
        page_bytes = self.page * self.n_kv_heads * self.head_dim * self.elem_bytes
        return {
            "active_rows": len(lens),
            "max_len": max(lens, default=0),
            "distinct_pages": len(held),
            "shared_pages": shared,
            "resident_bytes": 2 * len(held) * page_bytes,  # K + V
        }

    # ---- sampling ------------------------------------------------------------

    def maybe_sample(self, step_epoch: int, pool, step_q: Optional[int] = None) -> bool:
        """Sample iff enabled and ``step_epoch`` lands on the period."""
        if self.every <= 0 or step_epoch % self.every != 0:
            return False
        return self.sample(pool, step_q=step_q)

    def sample(self, pool, step_q: Optional[int] = None) -> bool:
        fp = self.pool_footprint(pool)
        if fp["max_len"] == 0:
            return False
        reg = self.registry
        reg.gauge("llc.footprint_bytes").set(fp["resident_bytes"])
        reg.gauge("llc.capacity_bytes").set(self.capacity_bytes)
        reg.gauge("llc.active_rows").set(fp["active_rows"])
        reg.gauge("llc.shared_pages").set(fp["shared_pages"])
        # ``step_q`` is the widest decode/verify chunk of the step that
        # triggered the sample: 1 on plain decode, K+1 under speculative
        # decoding. Gauged so dashboards (and the adaptation controller's
        # inputs) see the per-sweep query width the footprint is amortized
        # over, and — when the chunk is wider than one token — the verify
        # model is evaluated per order alongside the fwd model.
        if step_q is not None:
            reg.gauge("llc.step_q_tokens").set(int(step_q))

        spec = self.fwd_spec_for(fp["max_len"])
        fwd_miss = []
        for order in self.orders:
            res = fwd_llc_model(
                spec,
                order,
                snake_group=self.snake_group if order == "block_snake" else None,
                n_workers=self.n_workers,
                capacity_bytes=self.capacity_bytes,
            )
            fwd_miss.append(res.misses)
            reg.gauge("llc.modeled_miss_bytes", order=order, model="fwd").set(
                res.misses
            )
        reg.gauge("llc.best_order_index").set(fwd_miss.index(min(fwd_miss)))

        verify_miss: Optional[dict] = None
        if step_q is not None and step_q > 1:
            vspec = self.verify_spec_for(fp["max_len"], int(step_q))
            verify_miss = {}
            for order in self.orders:
                res = fwd_llc_model(
                    vspec,
                    order,
                    snake_group=(
                        self.snake_group if order == "block_snake" else None
                    ),
                    n_workers=self.n_workers,
                    capacity_bytes=self.capacity_bytes,
                )
                verify_miss[order] = res.misses
                reg.gauge(
                    "llc.modeled_miss_bytes", order=order, model="verify"
                ).set(res.misses)

        # Shared-prefix decode model: evaluated when the pool actually holds
        # shared pages across >1 rows, and recorded into the history entry
        # alongside the fwd reading (with the live shared-page fraction) so
        # the order-adaptation controller can blend the two signals when
        # sharing dominates the footprint (DESIGN.md §11 follow-up).
        shared_miss: Optional[dict] = None
        shared_frac = (
            fp["shared_pages"] / fp["distinct_pages"] if fp["distinct_pages"] else 0.0
        )
        if fp["shared_pages"] and fp["active_rows"] > 1:
            prefix_pages = max(1, fp["shared_pages"])
            own = max(self.page, fp["max_len"] - prefix_pages * self.page)
            shared_miss = {}
            for order in self.orders:
                res = shared_prefix_llc_model(
                    order,
                    n_rows=fp["active_rows"],
                    prefix_pages=prefix_pages,
                    own_tokens=own,
                    n_steps=self.every,
                    page=self.page,
                    n_kv_heads=self.n_kv_heads,
                    head_dim=self.head_dim,
                    elem_bytes=self.elem_bytes,
                    capacity_bytes=self.capacity_bytes,
                    snake_group=(
                        self.snake_group if order == "block_snake" else None
                    ),
                )
                shared_miss[order] = res.misses
                reg.gauge(
                    "llc.modeled_miss_bytes", order=order, model="shared_prefix"
                ).set(res.misses)

        # ``current_order`` here is the order in effect when the sample was
        # taken; a controller that switches on this sample rewrites the
        # entry so the history reflects the order driving the *next* steps.
        self.history.append(
            {
                "sample": self.samples,
                "max_len": fp["max_len"],
                "footprint_bytes": fp["resident_bytes"],
                "active_rows": fp["active_rows"],
                "fwd_miss": dict(zip(self.orders, fwd_miss)),
                "shared_miss": shared_miss,
                "shared_frac": shared_frac,
                "step_q": 1 if step_q is None else int(step_q),
                "verify_miss": verify_miss,
                "current_order": self.current_order,
            }
        )
        if len(self.history) > self.history_cap:
            del self.history[: -self.history_cap]

        self.samples += 1
        reg.counter("llc.samples").inc()
        return True
