"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Zero dependencies, and deliberately *passive*: recording a metric is a plain
Python attribute update on a pre-resolved handle (no locks, no I/O, no
formatting), so the serve/train hot loops can instrument every step without
a measurable cost when no sink is attached. Exporters (``repro.obs.export``)
pull a :meth:`Registry.snapshot` — a plain dict of plain values — whenever
*they* want one; nothing is pushed.

Series are identified by ``(name, labels)``; the rendered form is the
Prometheus-ish ``name{k=v,k2=v2}`` with labels sorted by key, so e.g.
``serve.step.tokens{kind=decode}`` and ``serve.step.tokens{kind=prefill}``
are two independent counters under one name. ``Registry.counter`` /
``gauge`` / ``histogram`` are get-or-create: call once in setup, keep the
handle, and ``inc``/``set``/``observe`` in the loop.

Histograms use fixed upper-bound buckets (cumulative counts at export, raw
per-bucket counts internally) with a default latency ladder spanning 100 µs
to 100 s. NaN observations are *dropped* (and tallied in ``nan_count``):
the engine reports TPOT as NaN for single-token generations, which must not
poison the distribution.
"""

from __future__ import annotations

import bisect
import math
from typing import Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "LATENCY_BUCKETS_S",
    "default_registry",
]

# Default histogram ladder for wall-clock seconds: 1e-4 .. 100 s, roughly
# 1-2-5 per decade — wide enough for CPU-smoke TTFTs and TPU step times.
LATENCY_BUCKETS_S = (
    1e-4, 2e-4, 5e-4,
    1e-3, 2e-3, 5e-3,
    1e-2, 2e-2, 5e-2,
    0.1, 0.2, 0.5,
    1.0, 2.0, 5.0,
    10.0, 20.0, 50.0, 100.0,
)


def render_series(name: str, labels: dict) -> str:
    """``name{k=v,...}`` with labels sorted by key (bare name if none)."""
    if not labels:
        return name
    body = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{body}}}"


class Counter:
    """Monotonically increasing value (floats allowed: byte counts)."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} decremented by {n}")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram; ``buckets`` are inclusive upper bounds, with
    an implicit +inf overflow bucket. NaN observations are dropped (counted
    in ``nan_count``) so sentinel values can't skew sums or percentiles."""

    __slots__ = ("name", "labels", "buckets", "counts", "count", "sum", "nan_count")
    kind = "histogram"

    def __init__(self, name: str, labels: dict, buckets=LATENCY_BUCKETS_S):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != len(buckets):
            raise ValueError(f"histogram {name}: buckets must be strictly increasing")
        self.name = name
        self.labels = labels
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)  # +1: overflow
        self.count = 0
        self.sum = 0.0
        self.nan_count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            self.nan_count += 1
            return
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the bucket holding the
        q-th observation; +inf overflow reported as the last finite bound)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return float("nan")
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return self.buckets[i] if i < len(self.buckets) else self.buckets[-1]
        return self.buckets[-1]


class Registry:
    """Get-or-create store of metric handles, keyed by (name, labels)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = render_series(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(name, dict(labels), **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"{key} already registered as a {m.kind}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, buckets: Optional[tuple] = None, **labels) -> Histogram:
        if buckets is None:
            return self._get(Histogram, name, labels)
        m = self._get(Histogram, name, labels, buckets=buckets)
        if m.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"{render_series(name, labels)}: conflicting buckets")
        return m

    def series(self) -> list:
        """All registered metric handles, in registration order."""
        return list(self._metrics.values())

    def find(self, name: str, **labels):
        """The handle for an exact series, or None (no creation)."""
        return self._metrics.get(render_series(name, labels))

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Counter/gauge value of an exact series (``default`` if absent)."""
        m = self.find(name, **labels)
        return default if m is None else m.value

    def snapshot(self) -> dict:
        """Plain-dict view: ``{"counters": {series: value}, "gauges": {...},
        "histograms": {series: {"buckets": [[le, cumulative], ...],
        "count": n, "sum": s, "nan_count": k}}}`` — JSON-serializable,
        detached from the live handles."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for key, m in self._metrics.items():
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                cum, cumulative = 0, []
                # "+Inf" as a string: strict-JSON sinks reject Infinity.
                for le, c in zip(m.buckets + ("+Inf",), m.counts):
                    cum += c
                    cumulative.append([le, cum])
                out["histograms"][key] = {
                    "buckets": cumulative,
                    "count": m.count,
                    "sum": m.sum,
                    "nan_count": m.nan_count,
                }
        return out

    def reset(self) -> None:
        self._metrics.clear()


_default = Registry()


def default_registry() -> Registry:
    """The process-wide registry (components default to their own private
    registries; this one backs the module-level convenience handles)."""
    return _default
