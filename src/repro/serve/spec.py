"""Speculative-decoding drafters for the continuous serve engine.

The engine's unified ragged mixed step (DESIGN.md §9) already verifies
arbitrary per-row ``q_len`` chunks with in-kernel causal masks — exactly
the primitive speculative decoding needs. A :class:`Drafter` proposes up
to K draft tokens per decode row each step boundary; the engine packs
``[cur, d_1..d_K]`` into that row as a ``q_len = K+1`` verification chunk
(the same shape a prefill chunk takes, so the two compiled step widths
survive), samples every chunk position in the one device step, commits the
longest draft prefix matching the sampled targets plus one bonus token,
and rolls the rejected tail back out of the KV pool
(``PagedKVPool.rollback`` — a host-side len decrement plus tail-page
release, no new kernel).

Two built-in drafters:

* :class:`NgramDrafter` — self-drafting prompt-lookup (PLD): the
  continuation of the most recent earlier occurrence of the row's trailing
  n-gram in its own prompt + generated stream. Pure host-side numpy, zero
  device cost, and strong on repetitive streams (summarization, code,
  templated output) where the model mostly re-emits what it has seen.

* :class:`ModelDrafter` — a small zoo model as draft, with its own
  :class:`~repro.serve.kv_pool.PagedKVPool` and its own two-width jitted
  ragged step (so the target engine's ``compiled_step_count()`` is
  untouched). The draft cache is synced lazily: before drafting, the
  longest common prefix of what the drafter has absorbed and the row's
  live committed stream is computed and the divergent tail — draft tokens
  the target rejected — is ``rollback``-ed, then the unabsorbed suffix is
  caught up chunk-wise and K greedy drafts are decoded. Drafting greedily
  is always sound: drafts are guesses, the target's verification sampling
  is what defines the output distribution.

Drafters are best-effort and stateless from the engine's point of view:
``draft_batch`` receives each row's full committed stream (prompt +
generated, including the last emitted token) and may return fewer than K
tokens (or none) for any row — the row then just runs as a plain
``q_len=1`` decode row.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import build_model
from repro.serve.kv_pool import PagedKVPool, assemble_cache_view

__all__ = ["Drafter", "NgramDrafter", "ModelDrafter", "make_drafter"]


class Drafter:
    """Draft-token proposer interface (one instance per engine).

    Lifecycle: ``reset()`` at each ``generate`` stream start, ``release(slot)``
    whenever the engine retires a slot (finish, preempt, failure), and
    ``draft_batch(items)`` once per step boundary with every eligible decode
    row. Per-slot state (the model drafter's cache bookkeeping) must key on
    the slot index — a released slot may be reused by a different request.
    """

    def reset(self) -> None:
        """A new generate stream begins; drop any per-slot state."""

    def release(self, slot: int) -> None:
        """``slot`` was retired; drop its state (the slot id will be reused)."""

    def draft(self, slot: int, context: np.ndarray, k: int) -> list[int]:
        """Propose up to ``k`` draft tokens continuing ``context`` (the
        row's full committed stream: prompt + generated, last token
        included). May return fewer, or ``[]`` to skip speculation."""
        raise NotImplementedError

    def draft_batch(
        self, items: Sequence[tuple[int, np.ndarray, int]]
    ) -> dict[int, list[int]]:
        """Draft for every ``(slot, context, k)`` row; default loops over
        :meth:`draft`. Batched drafters (one device pass for all rows)
        override this."""
        return {slot: self.draft(slot, ctx, k) for slot, ctx, k in items}


class NgramDrafter(Drafter):
    """Self-drafting n-gram / prompt-lookup drafter (no draft model).

    For the longest n in ``[ngram_min, ngram_max]`` whose trailing n-gram
    of ``context`` has an earlier occurrence, propose the tokens that
    followed the *most recent* such occurrence. Matching is exact and
    vectorized (one sliding-window comparison per n); cost is O(n_gram *
    len(context)) host work per row and no device work at all.
    """

    def __init__(self, *, ngram_max: int = 4, ngram_min: int = 1):
        if not 1 <= ngram_min <= ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got [{ngram_min}, {ngram_max}]"
            )
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min

    def draft(self, slot: int, context: np.ndarray, k: int) -> list[int]:
        ctx = np.asarray(context, np.int32)
        n = len(ctx)
        if k < 1 or n < self.ngram_min + 1:
            return []
        for n_gram in range(min(self.ngram_max, n - 1), self.ngram_min - 1, -1):
            pat = ctx[-n_gram:]
            windows = np.lib.stride_tricks.sliding_window_view(ctx, n_gram)
            hits = np.nonzero((windows == pat).all(axis=1))[0]
            # Drop the trailing occurrence itself (lag 0).
            hits = hits[hits + n_gram < n]
            if hits.size:
                # Copy-from-lag: the most recent earlier occurrence ends L
                # tokens back; predict d_i = seq[n+i-L] with the read allowed
                # to run into the drafts themselves. On an L-periodic tail
                # (the regime this drafter exists for) that extends the
                # match's continuation cyclically to the full k instead of
                # stopping at the L (< k) tokens left before the stream end.
                lag = n - n_gram - int(hits[-1])
                seq = [int(t) for t in ctx]
                for i in range(k):
                    seq.append(seq[n + i - lag])
                return seq[n:]
        return []


class ModelDrafter(Drafter):
    """A small model drafting greedily from its own paged KV cache.

    ``lm``/``params`` must share the target's tokenizer/vocab (the classic
    draft-model requirement); ``lm`` must be a token-only full-attention
    family (the same eligibility as continuous serving). The drafter keeps
    one cache slot per engine slot in a private pool sized for the worst
    case (``admission="reserve"`` with full-capacity reservations), so
    draft-side growth can never fail mid-flight.

    Cache sync is lazy and dogfoods the pool's speculative rollback: at
    each ``draft_batch``, the longest common prefix of the tokens this
    drafter has absorbed and the row's live committed stream is kept,
    ``PagedKVPool.rollback`` disowns the divergent tail (drafts the target
    rejected), and the unabsorbed suffix is caught up in ``chunk``-token
    ragged rows — through the drafter's own two-width jitted step, which
    also decodes the K greedy drafts (the last catch-up chunk's final
    logits already yield d_1). Passing the *target's* ``lm``/``params``
    turns this into self-speculation: every greedy draft matches the
    target's greedy choice bitwise, a useful acceptance-machinery check.
    """

    def __init__(
        self,
        lm,
        params,
        *,
        n_slots: int,
        max_len: int,
        page_size: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
    ):
        cfg = lm.cfg
        if cfg.window is not None:
            raise ValueError("ModelDrafter needs full attention (window=None)")
        page = min(page_size or cfg.page_size or cfg.kv_block, max_len)
        self.lm = build_model(cfg.with_(kv_layout="paged", page_size=page))
        self.params = params
        self.n_slots = n_slots
        self.pool = PagedKVPool(
            cfg.with_(kv_layout="paged", page_size=page),
            cfg.n_layers,
            n_slots,
            max_len,
            prefix_sharing=False,
            admission="reserve",
        )
        self.chunk = max(1, min(prefill_chunk or 4 * page, max_len))
        self.pad = cfg.eos_id
        # slot -> tokens whose KV the draft cache holds (len == pool len)
        self._absorbed: dict[int, list[int]] = {}
        self._step = None
        self.steps = 0  # drafter device steps (bench accounting)

    # -- lifecycle -----------------------------------------------------------

    def reset(self) -> None:
        for slot in list(self._absorbed):
            self.release(slot)

    def release(self, slot: int) -> None:
        if slot in self._absorbed:
            self.pool.release(slot)
            del self._absorbed[slot]

    # -- the drafter's own ragged step (private jit cache, two widths) -------

    def _step_fn(self):
        if self._step is None:
            lm = self.lm
            n_layers = lm.cfg.n_layers

            def step(params, tokens, pages, bt, lens, qlens):
                caches = assemble_cache_view(pages, bt, lens, n_layers, qlens)
                logits, caches = lm.decode_step(params, tokens, caches)
                last = jnp.maximum(qlens - 1, 0)
                logits = jnp.take_along_axis(
                    logits, last[:, None, None], axis=1
                )[:, 0]
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return toks, {name: caches[name] for name in pages}

            self._step = jax.jit(step)
        return self._step

    # -- drafting ------------------------------------------------------------

    def draft_batch(
        self, items: Sequence[tuple[int, np.ndarray, int]]
    ) -> dict[int, list[int]]:
        pool = self.pool
        step_fn = self._step_fn()
        pending: dict[int, list[int]] = {}
        need: dict[int, int] = {}
        out: dict[int, list[int]] = {}
        for slot, ctx, k in items:
            ctx = [int(t) for t in np.asarray(ctx, np.int32)]
            # Drafting d_1..d_k absorbs ctx + d_1..d_{k-1}: clamp k to the
            # drafter's own cache capacity.
            k = min(int(k), pool.capacity - len(ctx) + 1)
            if k < 1:
                continue
            absorbed = self._absorbed.get(slot)
            if absorbed is None:
                # Worst-case reservation (sharing off -> nothing adopted,
                # len stays 0): draft-side growth can never fail mid-round.
                if pool.admit(slot, np.asarray(ctx, np.int32), pool.capacity) is None:
                    continue  # draft pool full: skip speculation for the row
                absorbed = self._absorbed[slot] = []
            lcp = 0
            while (
                lcp < len(absorbed) and lcp < len(ctx)
                and absorbed[lcp] == ctx[lcp]
            ):
                lcp += 1
            if len(absorbed) > lcp:
                # Target rejected some of our drafts (or the stream was
                # restored differently): disown the divergent tail.
                pool.rollback(slot, len(absorbed) - lcp)
                del absorbed[lcp:]
            pending[slot] = ctx[lcp:]
            need[slot] = k
            out[slot] = []
        # Unified catch-up + draft rounds: rows still absorbing context feed
        # a chunk; rows with d_i in hand feed it back (q_len=1) for d_{i+1}.
        # The round width is 1 or ``chunk`` — the same two-width discipline
        # as the engine, so this private jit cache is bounded too.
        while True:
            feeds: dict[int, list[int]] = {}
            for slot in out:
                if pending[slot]:
                    feeds[slot] = pending[slot][: self.chunk]
                elif out[slot] and len(out[slot]) < need[slot]:
                    feeds[slot] = [out[slot][-1]]
            if not feeds:
                break
            width = 1 if all(len(f) == 1 for f in feeds.values()) else self.chunk
            tokens = np.full((self.n_slots, width), self.pad, np.int32)
            qlens = np.zeros((self.n_slots,), np.int32)
            for slot, seg in feeds.items():
                pool.ensure_writable(slot, len(seg))
                tokens[slot, : len(seg)] = seg
                qlens[slot] = len(seg)
            toks, pages = step_fn(
                self.params,
                jnp.asarray(tokens),
                pool.pages,
                pool.block_tables,
                pool.lens,
                qlens,
            )
            pool.update_pages(pages)
            toks = np.asarray(toks)
            self.steps += 1
            for slot, seg in feeds.items():
                pool.advance(slot, len(seg))
                self._absorbed[slot].extend(seg)
                del pending[slot][: len(seg)]
                if not pending[slot]:
                    out[slot].append(int(toks[slot]))
        return {slot: d[: need[slot]] for slot, d in out.items()}


def make_drafter(
    kind: str,
    *,
    lm=None,
    params=None,
    n_slots: int = 8,
    max_len: int = 1024,
    ngram_max: int = 4,
    page_size: Optional[int] = None,
    prefill_chunk: Optional[int] = None,
) -> Optional[Drafter]:
    """Launcher-facing factory: ``none`` -> None, ``ngram`` ->
    :class:`NgramDrafter`, ``model`` -> :class:`ModelDrafter` (requires
    ``lm``/``params``)."""
    if kind in (None, "none"):
        return None
    if kind == "ngram":
        return NgramDrafter(ngram_max=ngram_max)
    if kind == "model":
        if lm is None or params is None:
            raise ValueError("drafter kind 'model' needs lm and params")
        return ModelDrafter(
            lm,
            params,
            n_slots=n_slots,
            max_len=max_len,
            page_size=page_size,
            prefill_chunk=prefill_chunk,
        )
    raise ValueError(f"unknown drafter kind {kind!r}")
