"""Deterministic fault injection for the serve stack (DESIGN.md §12).

A :class:`FaultPlan` is a seeded, schedule-addressable list of faults —
"exhaust the pool at step 6", "cancel rid 2 at step 3", "fail the device
step once at step 9" — threaded behind no-op hooks in ``PagePool`` /
``PagedKVPool`` / ``ServeEngine``. With no plan attached every hook is a
single ``is None`` check; with a plan attached the injected failures take
the *same* code paths real ones do (``PoolExhausted`` out of
``PagePool.alloc``, an exception out of the device-step dispatch, a host
``cancel`` at a step boundary), so the resilience machinery — preemption,
retry, typed statuses — is exercised end to end without needing a genuinely
starved pool or a flaky accelerator.

Addressing is by **mixed-step index**: the engine calls
:meth:`FaultPlan.begin_step` at every step boundary, and a fault arms once
the step counter reaches its ``step``. Each fault fires ``times`` times
(consumed on firing), and every firing is appended to :attr:`FaultPlan.fired`
— the engine asserts ``PagedKVPool.check_invariants`` after any step in
which a fault fired, so an injection that corrupts pool bookkeeping fails
loudly at the step that broke it, not requests later.

Sites:

* ``"pool.alloc"``    — ``PagePool.alloc`` raises :class:`~repro.serve.kv_pool.PoolExhausted`.
* ``"pool.admit"``    — ``PagedKVPool.admit`` reports no pages (admission pressure).
* ``"device.step"``   — the engine's mixed-step dispatch raises ``StepFault``
  (retried once before the step's rows are failed).
* ``"cancel"``        — the engine host-cancels ``rid`` at the step boundary.
* ``"tier.spill"``    — ``TieredPagePool.spill_slot`` refuses (host writer
  stalled); the engine falls back to preemption.
* ``"tier.fetch"``    — one host→device page fetch fails; the prefetcher
  requeues the page and retries at the next step boundary.

``FaultPlan.random(seed, ...)`` derives a small reproducible chaos schedule
from a seed — the CI chaos smoke runs one fixed seed so a red job is
re-runnable bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Fault", "FaultPlan", "StepFault", "FAULT_SITES"]

FAULT_SITES = (
    "pool.alloc",
    "pool.admit",
    "device.step",
    "cancel",
    "tier.spill",
    "tier.fetch",
)


class StepFault(RuntimeError):
    """The injected (or real, wrapped) device-step failure type."""


@dataclasses.dataclass
class Fault:
    """One scheduled fault: fires at sites matching ``site`` from mixed
    step ``step`` on, ``times`` times total; ``rid`` targets a request
    (cancel faults only)."""

    site: str
    step: int
    times: int = 1
    rid: Optional[int] = None
    note: str = ""

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(f"unknown fault site {self.site!r}; use {FAULT_SITES}")


class FaultPlan:
    """Seeded, schedule-addressable fault list with firing bookkeeping."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.faults: list[Fault] = []
        self.fired: list[dict] = []       # {site, step, rid, note} per firing
        self._step = -1                   # begin_step not called yet: nothing arms
        self._fired_this_step = 0

    # ---- schedule builders (chainable) ---------------------------------------

    def add(self, fault: Fault) -> "FaultPlan":
        self.faults.append(fault)
        return self

    def exhaust_pool(self, step: int, times: int = 1) -> "FaultPlan":
        """Make the next ``times`` page allocations at/after ``step`` raise
        ``PoolExhausted`` — the mid-flight pressure the preemption answers."""
        return self.add(Fault("pool.alloc", step, times, note="exhaust_pool"))

    def refuse_admission(self, step: int, times: int = 1) -> "FaultPlan":
        """Make ``PagedKVPool.admit`` report no pages ``times`` times."""
        return self.add(Fault("pool.admit", step, times, note="refuse_admission"))

    def fail_device_step(self, step: int, times: int = 1, note: str = "") -> "FaultPlan":
        """Fail the mixed-step dispatch ``times`` times at/after ``step``
        (one transient failure is retried; two consecutive fail the rows)."""
        return self.add(Fault("device.step", step, times, note=note or "fail_device_step"))

    def cancel(self, step: int, rid: int) -> "FaultPlan":
        """Host-cancel request ``rid`` at the ``step`` boundary."""
        return self.add(Fault("cancel", step, rid=rid, note="cancel"))

    def spill_stall(self, step: int, times: int = 1) -> "FaultPlan":
        """Make the tiered pool refuse the next ``times`` slot spills at or
        after ``step`` (a stalled host-tier writer) — the engine's
        shed -> spill -> preempt resolution must fall through to
        preemption instead of wedging on the tier."""
        return self.add(Fault("tier.spill", step, times, note="spill_stall"))

    def fetch_fail(self, step: int, times: int = 1) -> "FaultPlan":
        """Fail the next ``times`` host->device page fetches at/after
        ``step`` (a dropped transfer). The prefetcher requeues the page —
        the host copy is untouched — and retries at the next boundary, so
        the suspended row resumes late but bitwise-intact."""
        return self.add(Fault("tier.fetch", step, times, note="fetch_fail"))

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        n_steps: int,
        rids: tuple = (),
        n_exhaust: int = 1,
        n_step_fail: int = 1,
        n_cancel: int = 1,
    ) -> "FaultPlan":
        """A small reproducible chaos schedule: fault steps (and cancel
        targets) drawn from a seeded generator — same seed, same plan."""
        rng = np.random.default_rng(seed)
        plan = cls(seed)
        for _ in range(n_exhaust):
            plan.exhaust_pool(int(rng.integers(1, max(n_steps, 2))))
        for _ in range(n_step_fail):
            plan.fail_device_step(int(rng.integers(1, max(n_steps, 2))))
        for _ in range(min(n_cancel, len(rids))):
            plan.cancel(
                int(rng.integers(0, max(n_steps, 1))),
                rid=int(rng.choice(np.asarray(rids))),
            )
        return plan

    # ---- engine-side protocol ------------------------------------------------

    def begin_step(self, step: int) -> None:
        """Arm faults scheduled at/before ``step`` (engine step boundary)."""
        self._step = step
        self._fired_this_step = 0

    @property
    def fired_this_step(self) -> int:
        return self._fired_this_step

    @property
    def exhausted(self) -> bool:
        """True when every scheduled fault has fully fired."""
        return all(f.times <= 0 for f in self.faults)

    def _fire(self, f: Fault) -> None:
        f.times -= 1
        self._fired_this_step += 1
        self.fired.append(
            {"site": f.site, "step": self._step, "rid": f.rid, "note": f.note}
        )

    def take(self, site: str) -> bool:
        """Consume one due fault at ``site`` (hook call sites). False when
        nothing is due — the no-op fast path."""
        for f in self.faults:
            if f.site == site and f.times > 0 and 0 <= f.step <= self._step:
                self._fire(f)
                return True
        return False

    def take_cancels(self) -> list[int]:
        """All rids whose cancel faults are due at the current step."""
        rids = []
        for f in self.faults:
            if f.site == "cancel" and f.times > 0 and 0 <= f.step <= self._step:
                self._fire(f)
                rids.append(f.rid)
        return rids

    def raise_if(self, site: str) -> None:
        """Raise ``StepFault`` when a fault at ``site`` is due (device-step
        hook: the engine wraps its dispatch with this)."""
        if self.take(site):
            note = self.fired[-1]["note"]
            raise StepFault(f"injected fault at {site} (step {self._step}): {note}")
