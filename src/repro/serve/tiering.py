"""Tiered KV memory: a host-offload page tier under the device pool
(DESIGN.md §13).

At 100k+ contexts the capacity-bound regime of the paper reappears one
level up: HBM itself becomes the tier whose footprint the wavefront
overflows. This module layers a bounded host-memory page store under
``PagedKVPool`` so the device pool becomes a *cache* over a larger host
tier. The serve engine's pressure resolution gains a middle rung —
shed → **spill** → preempt — because parking a cold slot's pages on the
host preserves its computed KV (resume is a memcpy), while preemption
throws the work away (restore is a full chunked re-prefill).

Design points:

* **Full-slot spill.** ``spill_slot`` moves *all* of a slot's device pages
  to host rows (every pool leaf — int8 payloads and their scale planes
  mirror alike), releases its device pages and reservation, and marks the
  slot *suspended*: its logical length (``lens``) is retained, its block
  table is dummied out, and the scheduler excludes it from step plans.
  Shared (refcount > 1) pages get a private host copy plus a refcount
  decrement, so prefix donors keep serving adopters.
* **Known-future prefetch.** The Traversal IR makes the access sequence of
  a resuming row *exact*, not heuristic: ``core.schedule.
  future_visit_window`` gives the next step's page visit order, and the
  engine streams host rows back in that order, ``prefetch_depth`` pages
  per step boundary, issuing the ``device_put`` transfers while the
  current mixed step is still in flight (the double-buffered overlap the
  ``tier.overlap_frac`` gauge measures).
* **Atomic re-admission.** Staged device rows live outside the pool until
  every page of the slot is host→device resident; only then does
  ``complete_resume`` allocate physical pages, splice the rows in, restore
  the block table and reservation, and hand the slot back to the planner.
  Pool invariants therefore never see a half-resident slot — they see a
  suspended slot whose logical pages are accounted by ``_offslot_pages``.
* **Reuse-distance eviction.** ``select_spill_victim`` ranks candidates by
  ``cache_sim.slot_reuse_stats`` — the slot whose page stream carries the
  largest LRU stack distances is the one an LLC-sized device tier was
  going to miss anyway — instead of plain last-touch LRU.

Prefetch accounting: every successfully staged page counts one
``tier.fetches``; it becomes a ``tier.prefetch_hits`` when the resumed
slot advances (the fetched KV was attended) or a ``tier.prefetch_wasted``
when the slot is released first — so ``hits + wasted == fetches`` once a
stream drains, and ``check_invariants`` asserts the running version
(``hits + wasted + pending == fetches``) continuously.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_pool import PagedKVPool, PoolExhausted

__all__ = ["HostPageStore", "TieredPagePool", "select_spill_victim"]


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_pages(dst: jax.Array, rows: jax.Array, dst_ids: jax.Array) -> jax.Array:
    """dst (L, P, ...): a staged chunk of rows (L, k, ...) scattered onto
    physical pages ``dst_ids`` (k,) in one call.

    Donated like ``kv_pool._copy_page`` — the splice updates the pool
    buffer in place instead of cloning the whole leaf per fetched page.
    One dispatch per leaf per staged chunk (not per page): the chunk is
    whatever ``issue_fetches`` staged together, so splice cost scales with
    transfer batches, not pages."""
    return dst.at[:, dst_ids].set(rows)


def select_spill_victim(candidates) -> Optional[int]:
    """Spill victim policy (DESIGN.md §13): pick from ``candidates`` —
    tuples ``(slot, priority, shared_donor, mean_reuse_distance)`` — the
    slot with the lowest priority, preferring non-donors (spilling a donor
    host-copies pages that stay device-resident anyway), then the LARGEST
    mean reuse distance (the coldest page stream — the device tier was
    missing those pages regardless), slot index as the deterministic
    tiebreak. Returns None when there is nothing to spill."""
    if not candidates:
        return None
    return min(candidates, key=lambda c: (c[1], bool(c[2]), -c[3], c[0]))[0]


class HostPageStore:
    """Bounded host-memory store of spilled page rows.

    A row is one physical page across every pool leaf — ``{leaf name ->
    (L, page, ...) ndarray}`` — so int8 pools mirror their payloads and
    float32 scale planes together. Handles are opaque monotonically
    increasing ints; capacity is counted in pages (rows), matching the
    device pool's accounting unit.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"host tier needs >= 1 page, got {capacity}")
        self.capacity = int(capacity)
        self._rows: dict[int, dict[str, np.ndarray]] = {}
        self._next = 0

    @property
    def used(self) -> int:
        return len(self._rows)

    @property
    def free(self) -> int:
        return self.capacity - len(self._rows)

    @property
    def nbytes(self) -> int:
        return sum(
            a.nbytes for row in self._rows.values() for a in row.values()
        )

    def put(self, row: dict) -> int:
        if self.free <= 0:
            raise PoolExhausted(
                f"host page tier full: capacity {self.capacity}"
            )
        h = self._next
        self._next += 1
        self._rows[h] = row
        return h

    def get(self, handle: int) -> dict:
        return self._rows[handle]

    def pop(self, handle: int) -> dict:
        return self._rows.pop(handle)


@dataclasses.dataclass
class _Suspended:
    """Host-side state of one spilled slot."""

    handles: list[int]            # host handle per logical page (in order)
    reserved: int                 # device reservation to restore on resume
    queue: list[int] = dataclasses.field(default_factory=list)
                                  # logical pages awaiting fetch, visit-order
    staged: set[int] = dataclasses.field(default_factory=set)
                                  # logical pages already staged on device
    chunks: list = dataclasses.field(default_factory=list)
                                  # [(logical pgs, {leaf -> (L, k, ...)
                                  # device stack})] — one device_put batch
                                  # per leaf per issue_fetches call

    @property
    def started(self) -> bool:
        return bool(self.queue or self.staged)


class TieredPagePool(PagedKVPool):
    """``PagedKVPool`` over a :class:`HostPageStore`: the device pool as a
    cache tier.

    New lifecycle verbs (all host-side; the engine drives them at step
    boundaries): :meth:`spill_slot` parks a slot on the host,
    :meth:`start_resume` fixes its fetch order, :meth:`issue_fetches`
    stages ``device_put`` transfers (overlappable with an in-flight step),
    :meth:`complete_resume` splices fully staged slots back in. ``advance``
    and ``release`` are overridden only to classify pending prefetches as
    hits/wasted; every inherited operation (admit/CoW/registry/…) is
    unchanged and fully interoperates with suspended slots.
    """

    def __init__(self, *args, host_pages: int, **kwargs):
        super().__init__(*args, **kwargs)
        self.host = HostPageStore(host_pages)
        self._suspended: dict[int, _Suspended] = {}
        self._pending: dict[int, int] = {}  # slot -> staged, unclassified fetches
        # Plain-int twins of the tier.* registry series (registry-less use).
        self.spills = 0
        self.fetches = 0
        self.prefetch_hits = 0
        self.prefetch_wasted = 0
        self.fetch_failures = 0
        self.spill_bytes = 0
        self.fetch_bytes = 0
        self._overlapped = 0
        if self._registry is not None:
            r = self._registry
            self._t_spills = r.counter("tier.spills")
            self._t_fetches = r.counter("tier.fetches")
            self._t_hits = r.counter("tier.prefetch_hits")
            self._t_wasted = r.counter("tier.prefetch_wasted")
            self._t_fetch_fail = r.counter("tier.fetch_failures")
            self._t_spill_b = r.counter("tier.spill_bytes")
            self._t_fetch_b = r.counter("tier.fetch_bytes")
            self.emit_gauges()  # tier.* gauges exist from step 0

    # ---- queries -------------------------------------------------------------

    def suspended_slots(self) -> list[int]:
        return sorted(self._suspended)

    def is_suspended(self, slot: int) -> bool:
        return slot in self._suspended

    def shielded(self, slot: int) -> bool:
        """Slot has staged-but-unclassified prefetches (just resumed, has
        not stepped yet). The engine excludes shielded slots from spill
        victim candidacy — re-spilling before one step both wastes the
        fetches and invites spill/resume ping-pong."""
        return slot in self._pending

    def fetch_backlog(self) -> int:
        """Host pages still queued for fetch across all resuming slots."""
        return sum(len(s.queue) for s in self._suspended.values())

    def resume_ready(self, slot: int) -> bool:
        sus = self._suspended.get(slot)
        return (
            sus is not None
            and not sus.queue
            and len(sus.staged) == len(sus.handles)
        )

    def resume_need(self, slot: int) -> int:
        """Device pages ``complete_resume`` will claim (pages + reservation)."""
        sus = self._suspended[slot]
        return len(sus.handles) + sus.reserved

    def can_spill(self, slot: int) -> bool:
        return (
            slot not in self._suspended
            and bool(self._slot_pages[slot])
            and self.host.free >= len(self._slot_pages[slot])
        )

    # ---- spill ---------------------------------------------------------------

    def spill_slot(self, slot: int) -> bool:
        """Move every device page of ``slot`` to the host tier and suspend
        it. Returns False (slot untouched) when the slot holds no pages,
        the host tier lacks room, or an injected ``tier.spill`` fault
        models a stalled host writer — the engine then falls through to
        preemption.

        Shared pages are host-copied privately and ref-decremented: the
        surviving holders (and the prefix registry, while any holder
        lives) keep serving; the resumed slot comes back with private
        copies, exactly as if CoW had forked them."""
        if not self.can_spill(slot):
            return False
        if self.faults is not None and self.faults.take("tier.spill"):
            return False
        pids = list(self._slot_pages[slot])
        # One gather + one D2H per leaf for the whole slot (not per page);
        # the per-page host rows are views into the transferred block.
        idx = jnp.asarray(pids, dtype=jnp.int32)
        cols = {
            name: np.asarray(jnp.take(leaf, idx, axis=1))
            for name, leaf in self.pages.items()
        }
        handles = []
        for j in range(len(pids)):
            row = {name: col[:, j] for name, col in cols.items()}
            handles.append(self.host.put(row))
            nbytes = sum(a.nbytes for a in row.values())
            self.spill_bytes += nbytes
            if self._registry is not None:
                self._t_spill_b.inc(nbytes)
        for pid in pids:
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._unregister(pid)
                self.alloc.free([pid])
        res = self._slot_reserved[slot]
        self.alloc.reserved -= res
        self._slot_reserved[slot] = 0
        self._slot_pages[slot] = []
        self.block_tables[slot] = 0
        # lens[slot] is retained: it is the suspended row's logical length
        # (check_invariants covers it through _offslot_pages) and the
        # resume target.
        self._suspended[slot] = _Suspended(handles=handles, reserved=res)
        self.spills += 1
        if self._registry is not None:
            self._t_spills.inc()
        return True

    # ---- fetch / resume ------------------------------------------------------

    def start_resume(self, slot: int, order=None) -> None:
        """Fix the fetch order of suspended ``slot`` and open its queue.

        ``order`` is a (possibly partial) permutation of the slot's
        logical pages — the engine passes the next step's visit window
        (``core.schedule.future_visit_window``), so pages come back in
        exactly the order the resumed row will attend them; unnamed pages
        follow in logical order. Idempotent for already staged pages."""
        sus = self._suspended[slot]
        n = len(sus.handles)
        head = [int(p) for p in (order or []) if 0 <= int(p) < n]
        seen = set(head)
        full = head + [p for p in range(n) if p not in seen]
        sus.queue = [p for p in full if p not in sus.staged]

    def issue_fetches(self, slot: int, depth: int, *, overlapped: bool = False) -> int:
        """Stage up to ``depth`` queued host pages of ``slot`` as device
        rows (async ``device_put`` — the H2D copies queue behind whatever
        step is in flight, which is the whole point of calling this while
        one is). Returns pages staged. An injected ``tier.fetch`` fault
        drops the transfer — the host copy is untouched, the page stays
        queued, and the next boundary retries, so the row resumes late but
        bitwise-intact."""
        sus = self._suspended.get(slot)
        if sus is None:
            return 0
        pgs: list[int] = []
        while sus.queue and len(pgs) < depth:
            if self.faults is not None and self.faults.take("tier.fetch"):
                self.fetch_failures += 1
                if self._registry is not None:
                    self._t_fetch_fail.inc()
                break  # faulted page stays queued; next boundary retries
            pgs.append(sus.queue.pop(0))
        if not pgs:
            return 0
        # The whole window ships as one stacked H2D transfer per leaf; the
        # accounting (fetches, pending, bytes) stays per page.
        rows = [self.host.get(sus.handles[pg]) for pg in pgs]
        stack = {}
        nbytes = 0
        for name in rows[0]:
            h = np.stack([r[name] for r in rows], axis=1)  # (L, k, page, ...)
            stack[name] = jax.device_put(h)
            nbytes += h.nbytes
        sus.chunks.append((pgs, stack))
        sus.staged.update(pgs)
        n = len(pgs)
        self.fetches += n
        self.fetch_bytes += nbytes
        self._pending[slot] = self._pending.get(slot, 0) + n
        if overlapped:
            self._overlapped += n
        if self._registry is not None:
            self._t_fetches.inc(n)
            self._t_fetch_b.inc(nbytes)
        return n

    def complete_resume(self, slot: int) -> bool:
        """Splice a fully staged slot back into the device tier: allocate
        its physical pages, write every staged row, restore the block
        table and reservation, drop the host copies. Atomic — returns
        False (nothing changes, retried next boundary) when the device
        pool cannot cover pages + reservation right now."""
        sus = self._suspended[slot]
        if sus.queue or len(sus.staged) < len(sus.handles):
            return False
        n = len(sus.handles)
        if self.alloc.available < n + sus.reserved:
            return False
        try:
            pids = self.alloc.alloc(n)
        except PoolExhausted:  # injected pool.alloc fault: retry later
            return False
        for pg in range(n):
            self._ref[pids[pg]] = 1
            self.block_tables[slot, pg] = pids[pg]
        # One scatter per leaf per staged chunk: each chunk's rows land on
        # the physical pages its logical pages were assigned.
        for pgs, stack in sus.chunks:
            ids = jnp.asarray([pids[pg] for pg in pgs], dtype=jnp.int32)
            for name, rows in stack.items():
                self.pages[name] = _write_pages(self.pages[name], rows, ids)
        self._slot_pages[slot] = list(pids)
        self._slot_reserved[slot] = sus.reserved
        self.alloc.reserved += sus.reserved
        for h in sus.handles:
            self.host.pop(h)
        del self._suspended[slot]
        # _pending stays: classified as hits on the slot's first advance.
        return True

    # ---- lifecycle overrides (prefetch classification) -----------------------

    def advance(self, slot: int, n: int = 1) -> None:
        super().advance(slot, n)
        if slot not in self._suspended:
            pend = self._pending.pop(slot, 0)
            if pend:
                self.prefetch_hits += pend
                if self._registry is not None:
                    self._t_hits.inc(pend)

    def release(self, slot: int) -> None:
        sus = self._suspended.pop(slot, None)
        if sus is not None:
            for h in sus.handles:
                self.host.pop(h)
        pend = self._pending.pop(slot, 0)
        if pend:
            self.prefetch_wasted += pend
            if self._registry is not None:
                self._t_wasted.inc(pend)
        super().release(slot)

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        """Admissibility against the *combined* capacity: a request whose
        worst case overflows the device tier is still admissible when the
        host tier can absorb the overflow via spills."""
        worst = self.pages_for(min(prompt_len + max_new, self.capacity))
        return self.alloc.available + self.host.free >= worst

    # ---- invariants ----------------------------------------------------------

    def _offslot_pages(self, slot: int) -> int:
        sus = self._suspended.get(slot)
        return 0 if sus is None else len(sus.handles)

    def check_invariants(self) -> None:
        super().check_invariants()
        all_handles: list[int] = []
        for slot, sus in self._suspended.items():
            assert not self._slot_pages[slot], (
                f"suspended slot {slot} still holds device pages"
            )
            assert self._slot_reserved[slot] == 0, (
                f"suspended slot {slot} still holds a reservation"
            )
            n = len(sus.handles)
            all_handles.extend(sus.handles)
            assert set(sus.staged).isdisjoint(sus.queue)
            if sus.started:
                assert sorted(sus.queue + list(sus.staged)) == list(range(n))
        assert len(all_handles) == len(set(all_handles)), "host handle aliased"
        assert self.host.used == len(all_handles), (
            f"host tier leak: stored {self.host.used}, "
            f"referenced {len(all_handles)}"
        )
        assert all(v > 0 for v in self._pending.values())
        assert (
            self.fetches
            == self.prefetch_hits
            + self.prefetch_wasted
            + sum(self._pending.values())
        ), "prefetch accounting drift"

    # ---- telemetry -----------------------------------------------------------

    def emit_gauges(self, registry=None) -> None:
        super().emit_gauges(registry)
        registry = registry if registry is not None else self._registry
        if registry is None or not hasattr(self, "host"):
            return  # parent __init__ pre-creates pool.* before the tier exists
        n_alloc = self.alloc.n_pages - 1
        registry.gauge("tier.device_pages").set(n_alloc - self.alloc.free_count)
        registry.gauge("tier.host_pages").set(self.host.used)
        registry.gauge("tier.suspended_slots").set(len(self._suspended))
        registry.gauge("tier.overlap_frac").set(
            self._overlapped / max(self.fetches, 1)
        )
