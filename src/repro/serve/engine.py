"""Batched serving engine: prefill + decode over the unified LM API.

Two schedulers (``repro.serve.scheduler``):

* ``scheduler="static"`` — the original fixed-group path: requests are
  grouped into ``batch_size`` batches (left-padded into one shared prefill
  bucket), prefilled once, decoded token-by-token until every row hits its
  own EOS / ``max_new_tokens``. Works for every model family (KV caches,
  SWA ring buffers and SSM states all live behind ``lm.prefill /
  decode_step``).

* ``scheduler="continuous"`` — continuous batching over a shared paged KV
  pool (``repro.serve.kv_pool``): each request owns a slot in a persistent
  decode batch and a block-table row in the pool; requests are admitted the
  moment a slot plus enough pages free up (mid-decode, honoring per-request
  ``arrival`` times) and retire individually, so short requests never idle
  behind long ones. Decode visits the pool's pages in the paper's
  ``KVSchedule`` order (sawtooth parity driven by each row's cache length).
  Requires a token-only full-attention family (dense / moe).

Sampling is per-row in both paths: each request is sampled with its own
temperature and a PRNG stream folded from (engine seed, request seed —
defaulting to the submission index so identical requests decorrelate —
per-request sample index). A greedy request batched next to a sampling
request stays greedy, and a request's sampled stream does not depend on
which slot or group it landed in.

On TPU the decode step uses the Pallas flash-decode kernel with the
schedule from the paper's technique; on CPU it uses the jnp path.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.dist import sharding as shd
from repro.models.model import LM, build_model
from repro.serve.kv_pool import PagedKVPool, assemble_cache_view
from repro.serve.scheduler import ContinuousScheduler

__all__ = [
    "Request",
    "GenerationResult",
    "ServeEngine",
    "CONTINUOUS_FAMILIES",
    "supports_continuous",
]

EOS = 1  # legacy default, kept for callers that import it; engines use cfg.eos_id

CONTINUOUS_FAMILIES = ("dense", "moe")


def supports_continuous(cfg: ModelConfig) -> bool:
    """Whether ``cfg`` can serve under the continuous scheduler: a
    token-only full-attention family (the paged pool has no ring-buffer or
    recurrent-state layout). The single eligibility predicate — launchers
    and examples picking a scheduler automatically must use this."""
    return cfg.family in CONTINUOUS_FAMILIES and cfg.window is None


@dataclasses.dataclass
class Request:
    tokens: np.ndarray            # prompt (1D int32)
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    rid: int = 0
    seed: Optional[int] = None    # sampling stream id; defaults to the
                                  # request's submission index so identical
                                  # requests sample independently
    eos_id: Optional[int] = None  # overrides ModelConfig.eos_id
    arrival: int = 0              # decode-step arrival time (continuous only)


@dataclasses.dataclass
class GenerationResult:
    rid: int
    tokens: np.ndarray            # generated tokens (without prompt)
    steps: int


@jax.jit
def _row_keys(base: jax.Array, seeds: jax.Array, counts: jax.Array) -> jax.Array:
    """One PRNG key per row: fold (request seed, sample index) into base."""
    return jax.vmap(
        lambda s, c: jax.random.fold_in(jax.random.fold_in(base, s), c)
    )(seeds, counts)


@jax.jit
def _sample_rows(logits: jax.Array, temps: jax.Array, keys: jax.Array) -> jax.Array:
    """Per-row sampling: greedy where temp<=0, else categorical at that
    row's own temperature with that row's own key."""
    greedy = jnp.argmax(logits, axis=-1)
    sampled = jax.vmap(
        lambda l, t, k: jax.random.categorical(k, l / jnp.maximum(t, 1e-6))
    )(logits, temps, keys)
    return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)


def _bucket_len(n: int, cap: int, page: int) -> int:
    """Prefill bucket: the prompt rounded up to whole pages, capped at the
    cache capacity. Page-multiple buckets keep the per-request capacity
    clamp tight (a pow2 bucket near cap would eat the decode budget) and
    match the pool's allocation granularity; the distinct-bucket count —
    i.e. prefill compilations — is bounded by blocks-per-sequence."""
    return min(max(page, -(-n // page) * page), cap)


class ServeEngine:
    def __init__(
        self,
        lm: LM,
        params,
        *,
        batch_size: int = 8,
        max_len: int = 1024,
        seed: int = 0,
        mesh=None,
        pcfg: Optional[ParallelConfig] = None,
        scheduler: str = "static",
        page_size: Optional[int] = None,
    ):
        """Pass ``mesh`` (+ optional ParallelConfig) for sharded serving:
        params are placed on their TP/FSDP shardings and every step runs
        under the mesh context (GSPMD propagates cache/batch shardings).

        ``scheduler="continuous"`` rebuilds the model under the paged KV
        layout (``page_size`` pages, default ``kv_block``) and serves with
        continuous batching; ``"static"`` keeps the fixed-group path."""
        if scheduler not in ("static", "continuous"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if scheduler == "continuous":
            cfg = lm.cfg
            if not supports_continuous(cfg):
                raise ValueError(
                    "continuous scheduling needs a token-only full-attention "
                    f"family {CONTINUOUS_FAMILIES} (got family={cfg.family!r}, "
                    f"window={cfg.window}); use scheduler='static'"
                )
            page = min(page_size or cfg.page_size or cfg.kv_block, max_len)
            lm = build_model(cfg.with_(kv_layout="paged", page_size=page))
            self._page = page
        self.scheduler = scheduler
        self.lm = lm
        self.mesh = mesh
        self.eos = lm.cfg.eos_id
        # Cache capacity model, shared by validation here and the budgeting
        # in _generate_batch: prefill writes bucket + prefix tokens (VLM
        # prepends prefix embeddings) and decode writes max_new - 1 more
        # (the last sampled token is never written back). Only
        # full-attention caches are max_len-bounded — SSM decode state is
        # O(1) and sliding-window archs use a ring buffer.
        self._prefix = (
            min(lm.cfg.n_prefix_embeds, 8) if lm.cfg.family == "vlm" else 0
        )
        bounded = lm.cfg.window is None and lm.cfg.family != "ssm"
        if bounded and max_len <= self._prefix:
            detail = (
                f"the {self._prefix} VLM prefix embeddings leave no room"
                if self._prefix
                else "it must be positive"
            )
            raise ValueError(
                f"max_len={max_len} gives a zero-capacity KV cache ({detail}); "
                f"use max_len > {self._prefix}"
            )
        self._cap = max_len - self._prefix if bounded else None
        if mesh is not None:
            pcfg = pcfg or ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
            params = jax.device_put(params, shd.param_shardings(params, pcfg, mesh))
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len))
        self._decode = jax.jit(lm.decode_step)
        self._prefill_buckets: dict[int, object] = {}

    def _mesh_ctx(self):
        return (
            jax.set_mesh(self.mesh) if self.mesh is not None else contextlib.nullcontext()
        )

    def _eos_for(self, r: Request) -> int:
        return self.eos if r.eos_id is None else r.eos_id

    def _seed_for(self, r: Request, idx: int) -> int:
        """Effective sampling-stream id: explicit seed, else the request's
        submission index (distinct by construction, so N identical
        temperature>0 requests in one call return N independent samples)."""
        return idx if r.seed is None else r.seed

    def _pad_batch(
        self,
        prompts: Sequence[np.ndarray],
        max_bucket: Optional[int] = None,
        batch: Optional[int] = None,
        bucket: Optional[int] = None,
    ) -> jnp.ndarray:
        # Shared prefill bucket. Bounded (full-attention) caches cap it at
        # the cache capacity: an overlong prompt keeps only its most recent
        # tokens (causal LM — the tail conditions generation) instead of
        # silently overflowing the prefill bucket and then clamp-overwriting
        # the cache's last slot every decode step. max_bucket=None (SSM
        # state, SWA ring buffers) leaves prompts untouched.
        length = bucket or max(1, max(len(p) for p in prompts))  # all-empty -> 1 pad
        if max_bucket is not None:
            length = min(length, max_bucket)
        out = np.full((batch or self.batch_size, length), self.eos, np.int32)
        for i, p in enumerate(prompts):
            p = p[-length:]
            out[i, length - len(p) :] = p  # left-pad into a shared bucket
        return jnp.asarray(out)

    def generate(self, requests: Sequence[Request]) -> list[GenerationResult]:
        if self.scheduler == "continuous":
            return self._generate_continuous(requests)
        results: list[GenerationResult] = []
        for i in range(0, len(requests), self.batch_size):
            group = list(requests[i : i + self.batch_size])
            results.extend(self._generate_batch(group, base_idx=i))
        return results

    # ---- static path ---------------------------------------------------------

    def _generate_batch(
        self, group: Sequence[Request], base_idx: int = 0
    ) -> list[GenerationResult]:
        # Prompts get priority for the bounded capacity (see __init__ for
        # the capacity model); a request whose max_new_tokens exceeds what
        # remains after the shared bucket is clamped (visible via .steps),
        # not failed — one greedy request must not abort or context-starve
        # the rest of the batch.
        prefix, cap = self._prefix, self._cap
        tokens = self._pad_batch([r.tokens for r in group], max_bucket=cap)
        bucket = tokens.shape[1]
        new_limits = [
            r.max_new_tokens
            if cap is None
            else max(0, min(r.max_new_tokens, cap - bucket + 1))
            for r in group
        ]
        max_new = max(new_limits)
        if self.lm.cfg.family == "encdec":
            b, s = tokens.shape
            batch = {
                "src_embeds": jnp.zeros((b, s, self.lm.cfg.d_model), self.lm.cfg.activation_dtype()),
                "tgt_tokens": tokens,
            }
        elif self.lm.cfg.family == "vlm":
            b, s = tokens.shape
            batch = {
                "tokens": tokens,
                "prefix_embeds": jnp.zeros((b, prefix, self.lm.cfg.d_model), self.lm.cfg.activation_dtype()),
            }
        else:
            batch = {"tokens": tokens}

        with self._mesh_ctx():
            logits, caches = self._prefill(self.params, batch)
        generated = np.zeros((len(group), max_new), np.int32)
        done = np.asarray([lim == 0 for lim in new_limits])  # 0-limit rows emit nothing
        steps = np.zeros(len(group), np.int32)
        eos_for = [self._eos_for(r) for r in group]
        # logits carry batch_size rows (padding rows included) — size the
        # per-row sampling params to match.
        temps_np = np.zeros((tokens.shape[0],), np.float32)
        seeds_np = np.zeros((tokens.shape[0],), np.int32)
        for j, r in enumerate(group):
            temps_np[j] = r.temperature
            seeds_np[j] = self._seed_for(r, base_idx + j)
        temps = jnp.asarray(temps_np)
        seeds = jnp.asarray(seeds_np)

        cur = self._sample(logits[:, -1], temps, seeds, 0)
        for t in range(max_new):
            for j in range(len(group)):
                if not done[j]:
                    generated[j, t] = int(cur[j, 0])
                    steps[j] = t + 1
                    if int(cur[j, 0]) == eos_for[j] or t + 1 >= new_limits[j]:
                        done[j] = True
            if done.all():
                break
            with self._mesh_ctx():
                logits, caches = self._decode(self.params, cur, caches)
            cur = self._sample(logits[:, -1], temps, seeds, t + 1)

        return [
            GenerationResult(rid=r.rid, tokens=generated[j, : steps[j]], steps=int(steps[j]))
            for j, r in enumerate(group)
        ]

    def _sample(self, logits: jax.Array, temps, seeds, count: int) -> jnp.ndarray:
        counts = jnp.full(seeds.shape, count, jnp.int32)
        keys = _row_keys(self.key, seeds, counts)
        return _sample_rows(logits, temps, keys)[:, None]

    # ---- continuous path -----------------------------------------------------
    #
    # The decode loop runs one fused jitted step per token: assemble the
    # cache view (pages + block tables + lens), decode, sample per-row —
    # a single dispatch, so the scheduler's fewer-steps win is not eaten
    # by per-step host overhead. Admission is likewise one fused
    # prefill+sample call per request (cached per prompt bucket).

    def _prefill_for(self, bucket: int):
        fn = self._prefill_buckets.get(bucket)
        if fn is None:
            lm, base = self.lm, self.key

            def prefill_sample(params, batch, temp, seed, _n=bucket):
                logits, caches = lm.prefill(params, batch, _n)
                key = _row_keys(base, seed, jnp.zeros((1,), jnp.int32))
                tok = _sample_rows(logits[:, -1], temp, key)
                return tok, caches

            fn = jax.jit(prefill_sample)
            self._prefill_buckets[bucket] = fn
        return fn

    def _cont_step_fn(self):
        if getattr(self, "_cont_step", None) is None:
            lm, base = self.lm, self.key
            n_layers = lm.cfg.n_layers

            def step(params, cur, pages, bt, lens, temps, seeds, counts):
                caches = assemble_cache_view(pages, bt, lens, n_layers)
                logits, caches = lm.decode_step(params, cur, caches)
                keys = _row_keys(base, seeds, counts)
                toks = _sample_rows(logits[:, -1], temps, keys)
                return toks, {name: caches[name] for name in pages}

            self._cont_step = jax.jit(step)
        return self._cont_step

    def _generate_continuous(
        self, requests: Sequence[Request]
    ) -> list[GenerationResult]:
        cfg = self.lm.cfg
        n_slots = self.batch_size
        cap = self._cap
        sched = ContinuousScheduler(n_slots)
        sched.submit(list(requests))
        idx_of = {id(r): i for i, r in enumerate(requests)}  # default seeds
        pool = PagedKVPool(cfg, cfg.n_layers, n_slots, cap)

        results: dict[int, GenerationResult] = {}
        cur = np.full((n_slots, 1), self.eos, np.int32)
        temps = np.zeros((n_slots,), np.float32)
        seeds = np.zeros((n_slots,), np.int32)
        counts = np.zeros((n_slots,), np.int32)

        def finish(slot: int) -> None:
            st = sched.retire(slot)
            pool.release(slot)
            cur[slot, 0] = self.eos
            temps[slot] = 0.0
            r = st.request
            results[id(r)] = GenerationResult(
                rid=r.rid,
                tokens=np.asarray(st.generated, np.int32),
                steps=len(st.generated),
            )

        step = 0
        while sched.has_work():
            # Admission: fill free slots with arrived requests while the
            # pool can reserve their worst case.
            while (slot := sched.free_slot()) is not None:
                req = sched.pop_admissible(step)
                if req is None:
                    break
                if not self._admit(
                    req, slot, sched, pool, cur, temps, seeds, counts, idx_of[id(req)]
                ):
                    sched.requeue(req)  # no pages yet; retry after retirements
                    break
                if sched.slots[slot].done:  # first token was already terminal
                    finish(slot)

            active = sched.active_slots()
            if not active:
                if sched.waiting:
                    nxt = sched.next_arrival()
                    step = max(step + 1, nxt if nxt is not None else step + 1)
                    continue
                break

            for slot in active:
                pool.ensure_writable(slot)
            with self._mesh_ctx():
                toks_dev, pages = self._cont_step_fn()(
                    self.params,
                    jnp.asarray(cur),
                    pool.pages,
                    pool.block_tables,
                    pool.lens,
                    temps,
                    seeds,
                    counts,
                )
            pool.update_pages(pages)
            toks = np.asarray(toks_dev)
            step += 1
            for slot in active:
                st = sched.slots[slot]
                pool.advance(slot)
                counts[slot] += 1
                tok = int(toks[slot])
                cur[slot, 0] = tok
                if st.record(tok):
                    finish(slot)

        return [results[id(r)] for r in requests]

    def _admit(
        self, req: Request, slot: int, sched, pool, cur, temps, seeds, counts, idx: int
    ) -> bool:
        """Prefill ``req`` into ``slot``; False if the pool lacks pages."""
        cap = self._cap
        prompt = np.asarray(req.tokens, np.int32)[-cap:]
        bucket = _bucket_len(max(1, len(prompt)), cap, self._page)
        new_limit = max(0, min(req.max_new_tokens, cap - bucket + 1))
        if new_limit == 0:
            # Nothing to emit — resolve without consuming pages.
            st = sched.place(slot, req, eos_id=self._eos_for(req), new_limit=0)
            st.done = True
            return True
        if not pool.can_admit(bucket, new_limit):
            return False
        tokens = self._pad_batch([prompt], batch=1, bucket=bucket)
        with self._mesh_ctx():
            tok_dev, caches = self._prefill_for(bucket)(
                self.params,
                {"tokens": tokens},
                jnp.asarray([req.temperature], jnp.float32),
                jnp.asarray([self._seed_for(req, idx)], jnp.int32),
            )
        pool.insert(slot, caches, bucket, new_limit)
        st = sched.place(slot, req, eos_id=self._eos_for(req), new_limit=new_limit)
        temps[slot] = req.temperature
        seeds[slot] = self._seed_for(req, idx)
        tok = int(np.asarray(tok_dev)[0])
        counts[slot] = 1
        cur[slot, 0] = tok
        st.record(tok)
        return True
