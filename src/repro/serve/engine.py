"""Batched serving engine: prefill + decode over the unified LM API.

Two schedulers (``repro.serve.scheduler``):

* ``scheduler="static"`` — the original fixed-group path: requests are
  grouped into ``batch_size`` batches (left-padded into one shared prefill
  bucket), prefilled once, decoded token-by-token until every row hits its
  own EOS / ``max_new_tokens``. Works for every model family (KV caches,
  SWA ring buffers and SSM states all live behind ``lm.prefill /
  decode_step``).

* ``scheduler="continuous"`` — continuous batching over a shared paged KV
  pool (``repro.serve.kv_pool``) driven by ONE compiled **ragged mixed
  step**: each step, every decoding slot contributes a q_len=1 row and the
  remaining token budget is dealt to prompts as prefill chunks (per-row
  ``q_start``/``q_len``, causal masking inside the chunk, sampling only on
  rows that completed their prompt). Long prompts are chunk-preempted
  instead of stalling decode; the whole path compiles exactly two step
  shapes (chunk width and decode width 1) no matter how many distinct
  prompt lengths arrive. Identical prompt prefixes are deduplicated in the
  pool: full prompt pages are content-hashed, admission *adopts* matching
  pages (refcount bump, zero prefill compute) and copy-on-write forks the
  tail page when a shared page must be written. Pages are visited in the
  paper's ``KVSchedule`` order (sawtooth parity keyed per row on the
  visited length). Requires a token-only full-attention family (dense/moe).

Sampling is per-row in both paths: each request is sampled with its own
temperature and a PRNG stream folded from (engine seed, request seed —
defaulting to the submission index so identical requests decorrelate —
per-request sample index). A greedy request batched next to a sampling
request stays greedy, and a request's sampled stream does not depend on
which slot or group it landed in.

On TPU the mixed step uses the ragged Pallas paged-attention kernel with
the schedule from the paper's technique; on CPU it uses the blockwise XLA
path.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.cache_sim import slot_reuse_stats
from repro.core.schedule import future_visit_window
from repro.dist import sharding as shd
from repro.models.model import LM, build_model
from repro.obs import LLCSampler, Registry, Tracer
from repro.obs.llc import DEFAULT_CAPACITY_BYTES
from repro.serve.adapt import OrderAdaptController
from repro.serve.faults import FaultPlan
from repro.serve.kv_pool import (
    AdmissionError,
    PagedKVPool,
    PoolExhausted,
    assemble_cache_view,
)
from repro.serve.scheduler import ContinuousScheduler
from repro.serve.tiering import TieredPagePool, select_spill_victim

__all__ = [
    "Request",
    "GenerationResult",
    "StepStats",
    "ServeEngine",
    "CONTINUOUS_FAMILIES",
    "REQUEST_STATUSES",
    "supports_continuous",
    "select_victim",
]

EOS = 1  # legacy default, kept for callers that import it; engines use cfg.eos_id

CONTINUOUS_FAMILIES = ("dense", "moe")


def supports_continuous(cfg: ModelConfig) -> bool:
    """Whether ``cfg`` can serve under the continuous scheduler: a
    token-only full-attention family (the paged pool has no ring-buffer or
    recurrent-state layout). The single eligibility predicate — launchers
    and examples picking a scheduler automatically must use this."""
    return cfg.family in CONTINUOUS_FAMILIES and cfg.window is None


REQUEST_STATUSES = ("ok", "deadline", "cancelled", "shed", "failed")


@dataclasses.dataclass
class Request:
    tokens: np.ndarray            # prompt (1D int32)
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    rid: int = 0
    seed: Optional[int] = None    # sampling stream id; defaults to the
                                  # request's submission index so identical
                                  # requests sample independently
    eos_id: Optional[int] = None  # overrides ModelConfig.eos_id
    arrival: int = 0              # step arrival time (continuous only)
    deadline_s: Optional[float] = None
                                  # wall-clock budget from engine start;
                                  # checked at step boundaries — an expired
                                  # request resolves with status="deadline"
                                  # and whatever tokens it has
    priority: int = 0             # preemption shield: LOWER is preempted
                                  # first (admission order stays FIFO)
    max_preemptions: Optional[int] = None
                                  # per-request override of the engine's
                                  # preemption bound before status="failed"


@dataclasses.dataclass
class GenerationResult:
    rid: int
    tokens: np.ndarray            # generated tokens (without prompt)
    steps: int
    ttft_s: float = 0.0           # wall time, engine start -> first token
    tpot_s: float = 0.0           # mean wall time per token after the first;
                                  # NaN when <= 1 token was generated (there
                                  # is no "per token after the first" then)
    status: str = "ok"            # one of REQUEST_STATUSES; every non-"ok"
                                  # status still carries the partial tokens
                                  # generated before the request was retired
    n_preemptions: int = 0        # times this request was preempted+restored


def select_victim(candidates) -> int:
    """Preemption victim policy (DESIGN.md §12): pick from ``candidates``
    — tuples ``(slot, priority, n_generated, shared_donor)`` — the slot
    with the lowest priority, preferring non-donors (releasing a shared
    donor frees fewer pages than it holds), then the fewest generated
    tokens (cheapest chunked re-prefill on restore), slot index as the
    deterministic tiebreak."""
    return min(candidates, key=lambda c: (c[1], bool(c[3]), c[2], c[0]))[0]


def _tpot(elapsed_after_first: float, n_tok: int) -> float:
    """Mean time per output token after the first; NaN for n_tok <= 1 — a
    single-token generation has no inter-token interval, and reporting
    ``elapsed/1`` instead put a meaningless wall-clock sample into the TPOT
    percentiles. Histograms drop NaN observations by construction."""
    return (elapsed_after_first / (n_tok - 1)) if n_tok > 1 else math.nan


@dataclasses.dataclass
class StepStats:
    """Deterministic per-stream work counters for the continuous path.

    Typed replacement for the old ``ServeEngine.last_stats`` ad-hoc dict;
    every field is also published as a registry counter (``serve.steps``,
    ``pool.pages_adopted``, ...). The mapping shim below keeps
    ``stats["wide_steps"]``-style callers working (with a
    DeprecationWarning) — prefer attribute access or the registry.
    """

    mixed_steps: int = 0          # ragged mixed steps dispatched
    wide_steps: int = 0           # steps at chunk width (any prefill row)
    pages_adopted: int = 0        # prefix pages adopted instead of computed
    prompt_tokens_adopted: int = 0
    cow_forks: int = 0
    preemptions: int = 0          # victim slots evicted under pool pressure
    restore_tokens: int = 0       # tokens re-prefilled by preempt restores
    shed: int = 0                 # requests load-shed past --max-queue
    deadline_miss: int = 0        # requests retired on an expired deadline
    cancelled: int = 0            # requests retired by host-side cancel()
    failed: int = 0               # requests failed (preemption bound / step)
    spills: int = 0               # slots spilled to the host tier
    tier_fetches: int = 0         # host pages staged back toward the device
    prefetch_hits: int = 0        # fetched pages attended by the resumed row
    prefetch_wasted: int = 0      # fetched pages released before being used
    draft_tokens: int = 0         # speculative draft tokens verified
    accepted_tokens: int = 0      # drafts accepted (committed to streams)
    rollback_tokens: int = 0      # drafts rejected (len decrement + page
                                  # release); accepted + rollback == draft
                                  # by construction

    @property
    def acceptance_rate(self) -> float:
        """Fraction of verified draft tokens accepted (NaN with no drafts)."""
        return (
            self.accepted_tokens / self.draft_tokens
            if self.draft_tokens
            else math.nan
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    # -- deprecation shim: dict-style access used by pre-obs benches/tests --
    def keys(self):
        return self.as_dict().keys()

    def __iter__(self):
        return iter(self.as_dict())

    def __getitem__(self, key: str):
        warnings.warn(
            "ServeEngine.last_stats is a StepStats dataclass now; use "
            f"attribute access (.{key}) or the engine's obs registry",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.as_dict()[key]

    def get(self, key: str, default=None):
        return self.as_dict().get(key, default)


@jax.jit
def _row_keys(base: jax.Array, seeds: jax.Array, counts: jax.Array) -> jax.Array:
    """One PRNG key per row: fold (request seed, sample index) into base."""
    return jax.vmap(
        lambda s, c: jax.random.fold_in(jax.random.fold_in(base, s), c)
    )(seeds, counts)


@jax.jit
def _sample_rows(logits: jax.Array, temps: jax.Array, keys: jax.Array) -> jax.Array:
    """Per-row sampling: greedy where temp<=0, else categorical at that
    row's own temperature with that row's own key."""
    greedy = jnp.argmax(logits, axis=-1)
    sampled = jax.vmap(
        lambda l, t, k: jax.random.categorical(k, l / jnp.maximum(t, 1e-6))
    )(logits, temps, keys)
    return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)


class ServeEngine:
    def __init__(
        self,
        lm: LM,
        params,
        *,
        batch_size: int = 8,
        max_len: int = 1024,
        seed: int = 0,
        mesh=None,
        pcfg: Optional[ParallelConfig] = None,
        scheduler: str = "static",
        page_size: Optional[int] = None,
        token_budget: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        prefix_sharing: bool = True,
        registry: Optional[Registry] = None,
        tracer: Optional[Tracer] = None,
        llc_every: int = 0,
        llc_capacity_bytes: Optional[float] = None,
        log_every_steps: int = 0,
        adapt_order: bool = False,
        adapt_epoch: int = 8,
        adapt_hysteresis: float = 0.05,
        adapt_confirm: int = 2,
        adapt_shared_threshold: float = 0.25,
        autotune_cache: Optional[str] = None,
        admission: str = "reserve",
        max_queue: Optional[int] = None,
        admit_watermark: Optional[float] = None,
        max_preemptions: int = 2,
        pool_pages: Optional[int] = None,
        faults: Optional[FaultPlan] = None,
        host_pages: Optional[int] = None,
        spill_watermark: Optional[float] = None,
        prefetch_depth: int = 2,
        drafter=None,
        draft_len: int = 4,
    ):
        """Pass ``mesh`` (+ optional ParallelConfig) for sharded serving:
        params are placed on their TP/FSDP shardings and every step runs
        under the mesh context (GSPMD propagates cache/batch shardings).

        ``scheduler="continuous"`` rebuilds the model under the paged KV
        layout (``page_size`` pages, default ``kv_block``) and serves with
        the token-budget ragged mixed step: ``token_budget`` tokens per
        step (default: one per slot plus one prefill chunk) split across
        decode rows and ``prefill_chunk``-token prompt chunks (default: 4
        pages). ``prefix_sharing=False`` disables the pool's content-hash
        page dedup (for A/B measurement). ``"static"`` keeps the
        fixed-group path.

        Telemetry (``repro.obs``, DESIGN.md §10): the engine records step
        spans into ``tracer`` and metrics (TTFT/TPOT histograms, per-kind
        token counters, pool/scheduler gauges) into ``registry`` — both
        default to fresh per-engine instances, exposed as ``.obs`` /
        ``.tracer``. Recording is in-process and sink-free; pass the
        instances to ``repro.obs.export`` to dump them. ``llc_every > 0``
        additionally samples the modeled-LLC gauges
        (``llc.modeled_miss_bytes{order=...}``) every that many mixed steps
        against the live pool footprint (continuous path only);
        ``log_every_steps > 0`` prints a one-line stats summary at that
        step cadence.

        Online order adaptation (continuous path, DESIGN.md §11):
        ``adapt_order=True`` lets an :class:`OrderAdaptController` re-pick
        the KV traversal order every ``adapt_epoch`` mixed steps from the
        live modeled-LLC gauges — a switch needs ≥ ``adapt_hysteresis``
        fractional modeled-byte improvement on ``adapt_confirm``
        consecutive samples — and ``autotune_cache`` (a hillclimb
        ``autotune_cache.jsonl`` path) seeds the initial order by
        nearest-bucket lookup before the first step. The traversal order is
        a traced operand of the mixed step (the ``order_group`` scalar), so
        switches never recompile; with adaptation off the same operand just
        stays constant at the configured order.
        ``adapt_shared_threshold`` is the live shared-page fraction above
        which the controller blends the shared-prefix LLC model into the
        decision (DESIGN.md §11 follow-up).

        Resilience (DESIGN.md §12): ``admission="optimistic"`` reserves only
        prompts and lets decode growth oversubscribe the pool — mid-flight
        ``PoolExhausted`` is answered by preempting a victim slot
        (``select_victim``) and restoring it later via chunked re-prefill,
        at most ``max_preemptions`` times per request before it resolves
        ``status="failed"``. ``max_queue`` bounds the arrived waiting queue
        (newest beyond it are load-shed with ``status="shed"``);
        ``admit_watermark`` pauses admission while pool occupancy is at or
        above it (default 0.9 under optimistic admission, 1.0 — never —
        under reserve) instead of thrashing admission against preemption.
        ``pool_pages`` overrides the pool's allocatable page count below the
        all-slots worst case — the oversubscription knob that makes real
        (non-injected) pool pressure reachable. ``faults`` attaches a
        deterministic ``serve.faults.FaultPlan`` driving the no-op injection
        hooks; one transient device-step failure per step is retried once
        before the step's rows fail.

        Tiered KV memory (DESIGN.md §13): ``host_pages > 0`` backs the
        device pool with a ``serve.tiering.TieredPagePool`` host tier of
        that many pages. When device occupancy reaches ``spill_watermark``
        (default ``min(0.85, admit_watermark)``) the engine *spills* the
        coldest slot — ranked by ``cache_sim.slot_reuse_stats``, not plain
        LRU — to the host instead of (later) preempting it, and the
        pressure resolution order becomes shed → spill → preempt. Resuming
        slots stream their pages back ``prefetch_depth`` pages per step
        boundary in the next step's traversal visit order
        (``core.schedule.future_visit_window``), with the host→device
        copies issued while the current mixed step is in flight; the slot
        re-enters planning only once fully resident, so spill/resume is
        bitwise-invisible to its token stream.

        Speculative decoding (DESIGN.md §14, continuous path only):
        ``drafter`` (a ``serve.spec.Drafter``) proposes up to ``draft_len``
        draft tokens per decode row each boundary; the row rides the mixed
        step as a ``q_len = K+1`` verification chunk (the same ragged
        primitive prefill chunks use, so the compiled widths stay exactly
        two), every chunk position is sampled in the one device step, and
        the longest draft prefix matching the sampled targets is committed
        — plus the sampled token after it. Rejected drafts are undone
        host-side: ``PagedKVPool.rollback`` decrements the row's len and
        releases now-dead tail pages. Per-row PRNG keys fold the sample
        *count*, advanced only per accepted token, so greedy AND sampled
        streams are bitwise identical to non-speculative serving."""
        if scheduler not in ("static", "continuous"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if drafter is not None and scheduler != "continuous":
            raise ValueError("speculative decoding requires scheduler='continuous'")
        if draft_len < 1:
            raise ValueError(f"draft_len must be >= 1, got {draft_len}")
        self.drafter = drafter
        self.draft_len = int(draft_len)
        if admission not in ("reserve", "optimistic"):
            raise AdmissionError(f"unknown admission discipline {admission!r}")
        if scheduler == "continuous":
            cfg = lm.cfg
            if not supports_continuous(cfg):
                raise ValueError(
                    "continuous scheduling needs a token-only full-attention "
                    f"family {CONTINUOUS_FAMILIES} (got family={cfg.family!r}, "
                    f"window={cfg.window}); use scheduler='static'"
                )
            page = min(page_size or cfg.page_size or cfg.kv_block, max_len)
            lm = build_model(cfg.with_(kv_layout="paged", page_size=page))
            self._page = page
            self._chunk = max(1, min(prefill_chunk or 4 * page, max_len))
            self._budget = token_budget
        self.scheduler = scheduler
        self.lm = lm
        self.mesh = mesh
        self.eos = lm.cfg.eos_id
        self.prefix_sharing = prefix_sharing
        self.admission = admission
        self.max_queue = max_queue
        self.max_preemptions = max_preemptions
        self.pool_pages = pool_pages
        self.faults = faults
        self._watermark = (
            admit_watermark
            if admit_watermark is not None
            else (0.9 if admission == "optimistic" else 1.0)
        )
        self.host_pages = host_pages
        self.prefetch_depth = max(1, int(prefetch_depth))
        if spill_watermark is not None and not 0.0 < spill_watermark <= 1.0:
            raise ValueError(
                f"spill_watermark must be in (0, 1], got {spill_watermark}"
            )
        self._spill_wm = (
            spill_watermark
            if spill_watermark is not None
            else min(0.85, self._watermark)
        )
        self._cancelled: set[int] = set()
        # Cache capacity model, shared by validation here and the budgeting
        # in _generate_batch: prefill writes bucket + prefix tokens (VLM
        # prepends prefix embeddings) and decode writes max_new - 1 more
        # (the last sampled token is never written back). Only
        # full-attention caches are max_len-bounded — SSM decode state is
        # O(1) and sliding-window archs use a ring buffer.
        self._prefix = (
            min(lm.cfg.n_prefix_embeds, 8) if lm.cfg.family == "vlm" else 0
        )
        bounded = lm.cfg.window is None and lm.cfg.family != "ssm"
        if bounded and max_len <= self._prefix:
            detail = (
                f"the {self._prefix} VLM prefix embeddings leave no room"
                if self._prefix
                else "it must be positive"
            )
            raise ValueError(
                f"max_len={max_len} gives a zero-capacity KV cache ({detail}); "
                f"use max_len > {self._prefix}"
            )
        self._cap = max_len - self._prefix if bounded else None
        if mesh is not None:
            pcfg = pcfg or ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
            params = jax.device_put(params, shd.param_shardings(params, pcfg, mesh))
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len))
        self._decode = jax.jit(lm.decode_step)
        self._mixed_step = None       # single jitted ragged step (continuous)
        self._step_widths: set[int] = set()

        # ---- telemetry (repro.obs) ----
        self.obs = registry if registry is not None else Registry()
        self.tracer = tracer if tracer is not None else Tracer()
        self._log_every = log_every_steps
        self.last_stats: Optional[StepStats] = None
        r = self.obs  # hot-loop handles resolved once (recording = attr add)
        self._m_tok_decode = r.counter("serve.step.tokens", kind="decode")
        self._m_tok_prefill = r.counter("serve.step.tokens", kind="prefill")
        self._m_generated = r.counter("serve.tokens.generated")
        self._m_steps_wide = r.counter("serve.steps", width="wide")
        self._m_steps_narrow = r.counter("serve.steps", width="narrow")
        self._m_req_admitted = r.counter("serve.requests", event="admitted")
        self._m_req_finished = r.counter("serve.requests", event="finished")
        self._m_req_requeued = r.counter("serve.requests", event="requeued")
        self._m_compiles = r.counter("serve.compiles")
        self._m_ttft = r.histogram("serve.ttft_s")
        self._m_tpot = r.histogram("serve.tpot_s")
        self._m_step_time = r.histogram("serve.step_time_s")
        self._m_queue = r.gauge("serve.queue_depth")
        self._m_active = r.gauge("serve.active_slots")
        self._m_budget = r.gauge("serve.budget_utilization")
        # Resilience series (DESIGN.md §12) — created here, not lazily, so
        # every engine exposes the full schema from step 0 (check_metrics.py
        # requires them even on fault-free runs).
        self._m_preempt = r.counter("serve.preemptions")
        self._m_restore_tok = r.counter("serve.restore_tokens")
        self._m_shed = r.counter("serve.shed")
        self._m_deadline = r.counter("serve.deadline_miss")
        self._m_cancel = r.counter("serve.cancelled")
        self._m_failed = r.counter("serve.failed")
        self._m_retries = r.counter("serve.step_retries")
        self._m_admit_paused = r.gauge("serve.admission_paused")
        # Speculative-decoding series (DESIGN.md §14) — pre-created at zero
        # on every engine so check_metrics.py can require the schema (and
        # its accepted + rolled_back == drafted conservation) even on
        # non-speculative runs.
        self._m_draft_tok = r.counter("serve.spec.draft_tokens")
        self._m_accept_tok = r.counter("serve.spec.accepted_tokens")
        self._m_rollback_tok = r.counter("serve.spec.rollback_tokens")
        # Tiering series (DESIGN.md §13) — likewise pre-created at zero on
        # every engine (tiered or not), so check_metrics.py can require the
        # full tier.* schema unconditionally. The TieredPagePool increments
        # them; on an untiered engine they stay flat at zero.
        for name in (
            "tier.spills",
            "tier.fetches",
            "tier.prefetch_hits",
            "tier.prefetch_wasted",
            "tier.fetch_failures",
            "tier.spill_bytes",
            "tier.fetch_bytes",
        ):
            r.counter(name)
        for name in (
            "tier.host_pages",
            "tier.device_pages",
            "tier.suspended_slots",
            "tier.overlap_frac",
        ):
            r.gauge(name)
        self.llc: Optional[LLCSampler] = None
        self.order_ctl: Optional[OrderAdaptController] = None
        if scheduler == "continuous":
            cfg = self.lm.cfg
            elem_bytes = (
                1
                if cfg.kv_cache_dtype == "int8"
                else np.dtype(cfg.activation_dtype()).itemsize
            )
            capacity = llc_capacity_bytes or DEFAULT_CAPACITY_BYTES
            # The controller owns the live (order, snake_group) pair — also
            # when adaptation is off, so serve.current_order /
            # serve.order_switches exist on every continuous engine and the
            # step operand has a single source.
            self.order_ctl = OrderAdaptController(
                self.obs,
                order=cfg.attn_order,
                snake_group=cfg.snake_group,
                epoch=adapt_epoch,
                hysteresis=adapt_hysteresis,
                confirm=adapt_confirm,
                shared_threshold=adapt_shared_threshold,
                enabled=adapt_order,
            )
            if adapt_order and autotune_cache:
                self.order_ctl.seed_from_cache(
                    autotune_cache,
                    arch=cfg.name,
                    seq_bucket=max_len,
                    capacity_mib=capacity / 2**20,
                    backend=jax.default_backend(),
                )
            self.llc = LLCSampler(
                self.obs,
                page=self._page,
                n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd,
                elem_bytes=elem_bytes,
                current_order=self.order_ctl.order.value,
                snake_group=self.order_ctl.snake_group,
                every=llc_every,
                capacity_bytes=capacity,
                **(
                    {"orders": self.order_ctl.candidate_orders}
                    if adapt_order
                    else {}
                ),
            )

    def _mesh_ctx(self):
        return (
            jax.set_mesh(self.mesh) if self.mesh is not None else contextlib.nullcontext()
        )

    def cancel(self, rid: int) -> None:
        """Host-side cancellation of request ``rid``: at the next step
        boundary (continuous) / decode iteration (static) the request is
        retired, its pages released, and it resolves with
        ``status="cancelled"`` carrying whatever tokens it had produced.
        Unknown rids are remembered — a request submitted later under a
        pre-cancelled rid resolves immediately."""
        self._cancelled.add(int(rid))

    def _eos_for(self, r: Request) -> int:
        return self.eos if r.eos_id is None else r.eos_id

    def _seed_for(self, r: Request, idx: int) -> int:
        """Effective sampling-stream id: explicit seed, else the request's
        submission index (distinct by construction, so N identical
        temperature>0 requests in one call return N independent samples)."""
        return idx if r.seed is None else r.seed

    def _pad_batch(
        self,
        prompts: Sequence[np.ndarray],
        max_bucket: Optional[int] = None,
        batch: Optional[int] = None,
        bucket: Optional[int] = None,
    ) -> jnp.ndarray:
        # Shared prefill bucket. Bounded (full-attention) caches cap it at
        # the cache capacity: an overlong prompt keeps only its most recent
        # tokens (causal LM — the tail conditions generation) instead of
        # silently overflowing the prefill bucket and then clamp-overwriting
        # the cache's last slot every decode step. max_bucket=None (SSM
        # state, SWA ring buffers) leaves prompts untouched.
        length = bucket or max(1, max(len(p) for p in prompts))  # all-empty -> 1 pad
        if max_bucket is not None:
            length = min(length, max_bucket)
        out = np.full((batch or self.batch_size, length), self.eos, np.int32)
        for i, p in enumerate(prompts):
            p = p[-length:]
            out[i, length - len(p) :] = p  # left-pad into a shared bucket
        return jnp.asarray(out)

    def generate(self, requests: Sequence[Request]) -> list[GenerationResult]:
        if self.scheduler == "continuous":
            return self._generate_continuous(requests)
        results: list[GenerationResult] = []
        t0 = time.perf_counter()  # TTFT includes queueing behind earlier groups
        for i in range(0, len(requests), self.batch_size):
            group = list(requests[i : i + self.batch_size])
            results.extend(self._generate_batch(group, base_idx=i, t0=t0))
        return results

    # ---- static path ---------------------------------------------------------

    def _generate_batch(
        self, group: Sequence[Request], base_idx: int = 0, t0: Optional[float] = None
    ) -> list[GenerationResult]:
        # Prompts get priority for the bounded capacity (see __init__ for
        # the capacity model); a request whose max_new_tokens exceeds what
        # remains after the shared bucket is clamped (visible via .steps),
        # not failed — one greedy request must not abort or context-starve
        # the rest of the batch.
        prefix, cap = self._prefix, self._cap
        tokens = self._pad_batch([r.tokens for r in group], max_bucket=cap)
        bucket = tokens.shape[1]
        new_limits = [
            r.max_new_tokens
            if cap is None
            else max(0, min(r.max_new_tokens, cap - bucket + 1))
            for r in group
        ]
        max_new = max(new_limits)
        if self.lm.cfg.family == "encdec":
            b, s = tokens.shape
            batch = {
                "src_embeds": jnp.zeros((b, s, self.lm.cfg.d_model), self.lm.cfg.activation_dtype()),
                "tgt_tokens": tokens,
            }
        elif self.lm.cfg.family == "vlm":
            b, s = tokens.shape
            batch = {
                "tokens": tokens,
                "prefix_embeds": jnp.zeros((b, prefix, self.lm.cfg.d_model), self.lm.cfg.activation_dtype()),
            }
        else:
            batch = {"tokens": tokens}

        t0 = time.perf_counter() if t0 is None else t0
        with self.tracer.span("serve.prefill", rows=len(group), bucket=bucket):
            with self._mesh_ctx():
                logits, caches = self._prefill(self.params, batch)
        self._m_tok_prefill.inc(len(group) * bucket)
        generated = np.zeros((len(group), max_new), np.int32)
        done = np.asarray([lim == 0 for lim in new_limits])  # 0-limit rows emit nothing
        steps = np.zeros(len(group), np.int32)
        status = ["ok"] * len(group)
        eos_for = [self._eos_for(r) for r in group]
        # logits carry batch_size rows (padding rows included) — size the
        # per-row sampling params to match.
        temps_np = np.zeros((tokens.shape[0],), np.float32)
        seeds_np = np.zeros((tokens.shape[0],), np.int32)
        for j, r in enumerate(group):
            temps_np[j] = r.temperature
            seeds_np[j] = self._seed_for(r, base_idx + j)
        temps = jnp.asarray(temps_np)
        seeds = jnp.asarray(seeds_np)

        cur = jax.block_until_ready(self._sample(logits[:, -1], temps, seeds, 0))
        # Group-shared TTFT (one fused prefill+sample), measured from engine
        # start so queueing behind earlier groups counts; blocked first —
        # dispatch is async, the unforced timestamp would exclude device time.
        ttft = time.perf_counter() - t0
        for t in range(max_new):
            # Boundary checks BEFORE recording: a request cancelled (or past
            # its deadline) before this iteration keeps only what it already
            # has — a deadline_s=0 request resolves with zero tokens.
            now_s = time.perf_counter() - t0
            for j, r in enumerate(group):
                if done[j]:
                    continue
                if r.rid in self._cancelled:
                    done[j] = True
                    status[j] = "cancelled"
                    self._cancelled.discard(r.rid)
                elif r.deadline_s is not None and now_s > r.deadline_s:
                    done[j] = True
                    status[j] = "deadline"
            for j in range(len(group)):
                if not done[j]:
                    generated[j, t] = int(cur[j, 0])
                    steps[j] = t + 1
                    if int(cur[j, 0]) == eos_for[j] or t + 1 >= new_limits[j]:
                        done[j] = True
            if done.all():
                break
            with self.tracer.span("serve.decode_step", t=t):
                with self._mesh_ctx():
                    logits, caches = self._decode(self.params, cur, caches)
                cur = self._sample(logits[:, -1], temps, seeds, t + 1)
            self._m_tok_decode.inc(int((~done).sum()))
        total = time.perf_counter() - t0

        results = [
            GenerationResult(
                rid=r.rid,
                tokens=generated[j, : steps[j]],
                steps=int(steps[j]),
                ttft_s=ttft,
                tpot_s=_tpot(total - ttft, int(steps[j])),
                status=status[j],
            )
            for j, r in enumerate(group)
        ]
        for res in results:
            self._record_result(res)
        return results

    def _record_result(self, res: GenerationResult) -> None:
        """Publish one finished request into the registry (NaN TPOT — a
        single-token generation — is dropped by the histogram). Latency
        histograms only see ``status="ok"`` requests — a shed or expired
        request's wall time is a policy artifact, not a latency sample —
        while each non-ok terminal status counts into its own series."""
        self._m_req_finished.inc()
        self._m_generated.inc(res.steps)
        if res.status == "ok":
            self._m_ttft.observe(res.ttft_s)
            self._m_tpot.observe(res.tpot_s)
        elif res.status == "deadline":
            self._m_deadline.inc()
        elif res.status == "cancelled":
            self._m_cancel.inc()
        elif res.status == "shed":
            self._m_shed.inc()
        elif res.status == "failed":
            self._m_failed.inc()

    def _sample(self, logits: jax.Array, temps, seeds, count: int) -> jnp.ndarray:
        counts = jnp.full(seeds.shape, count, jnp.int32)
        keys = _row_keys(self.key, seeds, counts)
        return _sample_rows(logits, temps, keys)[:, None]

    # ---- continuous path -----------------------------------------------------
    #
    # One fused jitted RAGGED MIXED STEP per iteration: assemble the cache
    # view (pages + block tables + per-row q_start/q_len), run the ragged
    # chunk through the model, sample the last valid position of every row
    # — a single dispatch, so the scheduler's fewer-steps win is not eaten
    # by per-step host overhead. The step compiles at exactly two widths
    # (1 for decode-only steps, prefill_chunk otherwise) regardless of how
    # many distinct prompt lengths the stream carries — the per-bucket
    # prefill jit cache of the previous design (unbounded compilation
    # growth) is gone, as is the separate decode-only step.

    def _mixed_step_fn(self):
        if self._mixed_step is None:
            lm, base = self.lm, self.key
            n_layers = lm.cfg.n_layers

            def step(
                params, tokens, pages, bt, lens, qlens, order_group,
                temps, seeds, bases,
            ):
                # ``order_group`` is the traced effective reversal-group
                # scalar (adapt.OrderAdaptController.effective_group): the
                # traversal order is step *data*, so the adaptation can
                # switch it between steps inside this one compiled step.
                caches = assemble_cache_view(
                    pages, bt, lens, n_layers, qlens, order_group
                )
                logits, caches = lm.decode_step(params, tokens, caches)
                # EVERY chunk position is sampled — position p of row b uses
                # the PRNG key for sample index ``bases[b] + p``, the exact
                # key a sequence of q_len=1 steps would have used one by
                # one. The host picks what it needs: the last valid position
                # for prefill/decode rows, the whole K+1 target ladder for a
                # speculative verification row (position i conditions on
                # chunk[0..i], i.e. on the first i draft tokens). Per-row
                # sampling math is unchanged (greedy at temp<=0, categorical
                # at the row's own temperature), so each position is bitwise
                # what the old single-position step sampled.
                greedy = jnp.argmax(logits, axis=-1)

                def _sampled(_):
                    pos = jnp.arange(logits.shape[1], dtype=jnp.int32)
                    keys = jax.vmap(
                        lambda s, b: jax.vmap(
                            lambda c: jax.random.fold_in(
                                jax.random.fold_in(base, s), c
                            )
                        )(b + pos)
                    )(seeds, bases)
                    return jax.vmap(
                        jax.vmap(
                            lambda l, t, k: jax.random.categorical(
                                k, l / jnp.maximum(t, 1e-6)
                            ),
                            in_axes=(0, None, 0),
                        )
                    )(logits, temps, keys)

                # An all-greedy batch (the decode-heavy common case) skips
                # the key ladder + categorical entirely; with any sampling
                # row present the full per-position math runs, bitwise
                # identical to the ungated form.
                sampled = jax.lax.cond(
                    jnp.any(temps > 0.0), _sampled, lambda _: greedy, None
                )
                toks = jnp.where(
                    temps[:, None] > 0.0, sampled, greedy
                ).astype(jnp.int32)
                return toks, {name: caches[name] for name in pages}

            self._mixed_step = jax.jit(step)
        return self._mixed_step

    def compiled_step_count(self) -> int:
        """Number of compiled variants of the continuous mixed step (the
        compile-counter regression surface: O(1) — at most two widths — for
        any stream of prompt lengths). Reads the jit cache itself when the
        runtime exposes it; the engine-tracked width set is the fallback."""
        if self._mixed_step is None:
            return 0
        counter = getattr(self._mixed_step, "_cache_size", None)
        if counter is not None:
            return int(counter())
        return len(self._step_widths)  # pragma: no cover

    def _generate_continuous(
        self, requests: Sequence[Request]
    ) -> list[GenerationResult]:
        cfg = self.lm.cfg
        n_slots = self.batch_size
        cap = self._cap
        sched = ContinuousScheduler(
            n_slots, token_budget=self._budget, prefill_chunk=self._chunk
        )
        sched.submit(list(requests))
        idx_of = {id(r): i for i, r in enumerate(requests)}  # default seeds
        tiered = self.host_pages is not None and self.host_pages > 0
        pool_kw = dict(
            prefix_sharing=self.prefix_sharing,
            registry=self.obs,
            admission=self.admission,
            n_pages=self.pool_pages,
            faults=self.faults,
        )
        if tiered:
            pool = TieredPagePool(
                cfg, cfg.n_layers, n_slots, cap,
                host_pages=self.host_pages, **pool_kw,
            )
        else:
            pool = PagedKVPool(cfg, cfg.n_layers, n_slots, cap, **pool_kw)
        self.last_pool = pool  # exposed for benches/tests (sharing counters)

        drafter = self.drafter
        if drafter is not None:
            drafter.reset()
        results: dict[int, GenerationResult] = {}
        resume: dict[int, list[int]] = {}   # preempted: id(req) -> generated
        n_preempts: dict[int, int] = {}     # id(req) -> times preempted
        tally = {
            "preempt": 0, "restore": 0, "spill": 0,
            "draft": 0, "accept": 0, "roll": 0,
        }
        cur = np.full((n_slots,), self.eos, np.int32)  # last sampled token
        temps = np.zeros((n_slots,), np.float32)
        seeds = np.zeros((n_slots,), np.int32)
        counts = np.zeros((n_slots,), np.int32)
        t0 = time.perf_counter()
        first_t: dict[int, float] = {}

        def resolve(r, tokens: list, status: str) -> None:
            # Terminal for ANY lifecycle outcome — every submitted request
            # funnels through here exactly once, with a typed status and
            # whatever (possibly partial) tokens it produced.
            now = time.perf_counter()
            n_tok = len(tokens)
            ttft = first_t.pop(id(r), now) - t0
            res = GenerationResult(
                rid=r.rid,
                tokens=np.asarray(tokens, np.int32),
                steps=n_tok,
                ttft_s=ttft,
                tpot_s=_tpot((now - t0) - ttft, n_tok),
                status=status,
                n_preemptions=n_preempts.get(id(r), 0),
            )
            results[id(r)] = res
            self._cancelled.discard(r.rid)
            self._record_result(res)

        def finish(slot: int, status: str = "ok") -> None:
            st = sched.retire(slot)
            pool.release(slot)
            if drafter is not None:
                drafter.release(slot)
            cur[slot] = self.eos
            temps[slot] = 0.0
            resolve(st.request, list(st.generated), status)

        def preempt(slot: int) -> None:
            # Evict a live slot under pool pressure: release its pages and
            # requeue it at the queue head (restore = chunked re-prefill of
            # prompt + generated-so-far through the same mixed step), or
            # fail it cleanly once past its preemption bound.
            st = sched.retire(slot)
            pool.release(slot)
            if drafter is not None:
                drafter.release(slot)
            cur[slot] = self.eos
            temps[slot] = 0.0
            r = st.request
            n_pre = n_preempts.get(id(r), 0) + 1
            n_preempts[id(r)] = n_pre
            limit = (
                self.max_preemptions
                if getattr(r, "max_preemptions", None) is None
                else r.max_preemptions
            )
            if n_pre > limit:
                resolve(r, list(st.generated), "failed")
                return
            resume[id(r)] = list(st.generated)
            sched.requeue(r)
            tally["preempt"] += 1
            self._m_preempt.inc()
            self._m_req_requeued.inc()
            tr.instant(
                "serve.preempt", rid=r.rid, slot=slot,
                generated=len(st.generated),
            )

        def preempt_victim() -> bool:
            # Suspended slots are not candidates: they hold no device pages,
            # so preempting one frees nothing (and throws away the spilled
            # KV the tier just paid to preserve).
            cands = [
                (
                    i,
                    getattr(sched.slots[i].request, "priority", 0),
                    len(sched.slots[i].generated),
                    pool.shared_donor(i),
                )
                for i in sched.runnable_slots()
                if not sched.slots[i].done
            ]
            if not cands:
                return False
            preempt(select_victim(cands))
            return True

        def spill_one(keep: int) -> bool:
            # Spill the coldest runnable slot to the host tier, keeping at
            # least ``keep`` runnable (the watermark pass keeps one so the
            # stream always advances; the pressure path may go to zero —
            # the freed pages are exactly what lets a resume complete).
            # Shielded slots (resumed, not yet stepped) are excluded: they
            # would waste their just-fetched pages and invite ping-pong.
            run = [i for i in sched.runnable_slots() if not sched.slots[i].done]
            cands = [
                i for i in run if pool.can_spill(i) and not pool.shielded(i)
            ]
            if not cands or len(run) <= keep:
                return False
            stats = slot_reuse_stats(
                self.order_ctl.order.value,
                [int(l) for l in pool.lens],
                pool.page,
                snake_group=self.order_ctl.snake_group,
            )
            victim = select_spill_victim(
                [
                    (
                        i,
                        getattr(sched.slots[i].request, "priority", 0),
                        pool.shared_donor(i),
                        stats[i]["mean"],
                    )
                    for i in cands
                ]
            )
            if victim is None or not pool.spill_slot(victim):
                return False  # host full / injected tier.spill stall
            sched.suspend(victim)
            tally["spill"] += 1
            tr.instant(
                "serve.spill", slot=victim,
                pages=pool._offslot_pages(victim),
            )
            return True

        def tier_boundary() -> None:
            # Per-boundary tier work, in resolution order (DESIGN.md §13):
            # splice finished resumes back in, spill down to the watermark,
            # then open the fetch queue of (at most) one suspended slot —
            # pages stream back in the next step's traversal visit order.
            for i in pool.suspended_slots():
                if pool.resume_ready(i) and pool.complete_resume(i):
                    sched.resume(i)
                    tr.instant("serve.tier_resume", slot=i)
            while pool.occupancy() >= self._spill_wm and spill_one(keep=1):
                pass
            suspended = pool.suspended_slots()
            if not suspended or any(
                pool._suspended[i].started for i in suspended
            ):
                return
            runnable = [
                i for i in sched.runnable_slots() if not sched.slots[i].done
            ]
            n_alloc = pool.alloc.n_pages - 1
            held = n_alloc - pool.alloc.free_count
            for i in suspended:  # oldest slot index: deterministic FIFO-ish
                n_pgs = pool._offslot_pages(i)
                # Resume only into calm (a resume that immediately pushes
                # occupancy back over the spill watermark just rotates the
                # pressure onto a different victim — park instead, and let
                # running work finish at full width) — unless nothing is
                # runnable, where a resume is the only way to make progress.
                calm = (held + n_pgs) / max(n_alloc, 1) < self._spill_wm
                if pool.alloc.available >= pool.resume_need(i) and (
                    calm or not runnable
                ):
                    group = self.order_ctl.effective_group(max(n_pgs, 1))
                    pool.start_resume(
                        i,
                        order=future_visit_window(
                            int(pool.lens[i]) // pool.page, n_pgs,
                            n_pgs, group,
                        ),
                    )
                    break

        tr = self.tracer
        step_fn = self._mixed_step_fn()
        step = 0
        n_steps = n_wide = 0  # deterministic per-stream work counters
        last_cc = self.compiled_step_count()
        while sched.has_work():
            t_iter = time.perf_counter()
            with tr.span("serve.step", step=step):
                # ---- step-boundary lifecycle checks (DESIGN.md §12) ----
                if self.faults is not None:
                    self.faults.begin_step(step)
                    for rid in self.faults.take_cancels():
                        self._cancelled.add(int(rid))
                if self._cancelled:
                    hit = sched.drain_waiting(
                        lambda r: r.rid in self._cancelled
                    )
                    for r in hit:
                        resolve(r, resume.pop(id(r), []), "cancelled")
                    for i in list(sched.active_slots()):
                        if sched.slots[i].request.rid in self._cancelled:
                            finish(i, "cancelled")
                now_s = time.perf_counter() - t0
                for r in sched.drain_waiting(
                    lambda r: r.deadline_s is not None and now_s > r.deadline_s
                ):
                    resolve(r, resume.pop(id(r), []), "deadline")
                for i in list(sched.active_slots()):
                    r = sched.slots[i].request
                    if r.deadline_s is not None and now_s > r.deadline_s:
                        finish(i, "deadline")

                # Tiered KV boundary work BEFORE admission: spilling down to
                # the spill watermark is what un-pauses admission under the
                # (higher) admit watermark — park cold work, keep admitting.
                if tiered:
                    tier_boundary()

                # Admission: fill free slots with arrived requests while the
                # pool can reserve their (sharing-reduced) worst case. The
                # high watermark pauses admission under pool pressure so new
                # work does not immediately thrash running work back out via
                # preemption; with no active slots it never pauses (only
                # retirements can lower occupancy — registered prefix pages
                # legitimately outlive their donors).
                paused = (
                    pool.occupancy() >= self._watermark
                    and bool(sched.active_slots())
                )
                self._m_admit_paused.set(float(paused))
                while not paused and (slot := sched.free_slot()) is not None:
                    req = sched.pop_admissible(step)
                    if req is None:
                        break
                    restored = id(req) in resume
                    ctx = (
                        tr.span("serve.preempt_restore", rid=req.rid)
                        if restored
                        else contextlib.nullcontext()
                    )
                    with ctx:
                        st = self._admit(
                            req, slot, sched, pool, temps, seeds, counts,
                            idx_of.get(id(req), 0), prior=resume.get(id(req)),
                        )
                    if st is None:
                        sched.requeue(req)  # no pages yet; retry after retirements
                        self._m_req_requeued.inc()
                        break
                    resume.pop(id(req), None)
                    self._m_req_admitted.inc()
                    if restored and st.prompt is not None:
                        n_re = int(len(st.prompt) - st.prompt_pos)
                        tally["restore"] += n_re
                        self._m_restore_tok.inc(n_re)
                    if st.done:  # zero-limit request: emits nothing
                        finish(slot)

                # Load shed AFTER admission drained what it could: the
                # queue bound applies to arrived requests this boundary
                # could not place, newest rejected first.
                if self.max_queue is not None:
                    for r in sched.shed_over(step, self.max_queue):
                        resolve(r, resume.pop(id(r), []), "shed")

                # Speculative drafting (DESIGN.md §14) — ONCE per boundary,
                # before the plan/pressure retry loop: a model drafter runs
                # device steps of its own, so it must not be re-invoked when
                # a PoolExhausted retry below re-plans. K is clamped per row
                # so the verification chunk can neither outgrow the row's
                # new_limit / cache capacity (speculative writes stay inside
                # the admission reservation) nor exceed the wide compiled
                # width (q_len = K+1 <= prefill_chunk).
                drafts: dict[int, list[int]] = {}
                if drafter is not None:
                    want = []
                    for i in sched.runnable_slots():
                        st = sched.slots[i]
                        if st.done or st.prefilling:
                            continue
                        kmax = min(
                            self.draft_len,
                            st.new_limit - len(st.generated) - 1,
                            cap - int(pool.lens[i]) - 1,
                            self._chunk - 1,
                        )
                        if kmax < 1:
                            continue
                        ctx = np.concatenate(
                            [
                                st.prompt,
                                np.asarray(
                                    st.generated[st.n_prior :], np.int32
                                ),
                            ]
                        )
                        want.append((i, ctx, kmax))
                    if want:
                        with tr.span("serve.draft", rows=len(want)):
                            out = drafter.draft_batch(want)
                        for (i, _, kmax) in want:
                            d = [int(t) for t in out.get(i, [])][:kmax]
                            if d:
                                drafts[i] = d

                # Plan under pressure: make every planned row writable; a
                # mid-step PoolExhausted (optimistic oversubscription or an
                # injected fault) resolves shed → spill → preempt: spilling
                # a victim to the host tier preserves its KV (resume is a
                # memcpy), preemption is the fallback that throws work away.
                # Each retry removes one runnable slot — the victim may be
                # the very slot that failed — so this terminates.
                # ensure_writable is idempotent; re-ensured rows are no-ops
                # on retry. (Draft q_lens are part of the plan; a retried
                # plan re-derives them from the surviving slots.)
                draft_lens = {i: len(d) for i, d in drafts.items()} or None
                while True:
                    with tr.span("serve.plan_step"):
                        plan = sched.plan_step(draft_lens)
                    if not plan:
                        break
                    try:
                        for it in plan:
                            pool.ensure_writable(it.slot, it.q_len)
                    except PoolExhausted:
                        if tiered and spill_one(keep=0):
                            continue
                        if not preempt_victim():
                            raise
                        continue
                    break
                self._m_queue.set(len(sched.waiting))
                self._m_active.set(len(sched.active_slots()))
                if not plan:
                    if tiered and pool.suspended_slots():
                        # Nothing runnable, but suspended work exists: spend
                        # the boundary streaming pages back (nothing to
                        # overlap with — the fetches count as un-overlapped)
                        # and come back; complete_resume at the next
                        # boundary returns the slot to planning.
                        with tr.span("serve.prefetch", overlapped=False):
                            for i in pool.suspended_slots():
                                pool.issue_fetches(
                                    i, self.prefetch_depth, overlapped=False
                                )
                        step += 1
                        continue
                    if sched.waiting:
                        nxt = sched.next_arrival()
                        step = max(step + 1, nxt if nxt is not None else step + 1)
                        continue
                    break
                planned = sum(it.q_len for it in plan)
                self._m_budget.set(planned / sched.token_budget)

                width = 1 if all(it.q_len == 1 for it in plan) else self._chunk
                self._step_widths.add(width)
                tokens = np.full((n_slots, width), self.eos, np.int32)
                qlens = np.zeros((n_slots,), np.int32)
                # Per-row first sample index for the step's key ladder
                # (position p of row b folds ``bases[b] + p``): decode and
                # verification rows start at the row's live count; a prefill
                # row's only consumed position is its last (q_len-1), which
                # must land exactly on the row's count — the same key the
                # old single-position step folded.
                bases = counts.copy()
                n_decode = n_prefill = 0
                for it in plan:
                    st = sched.slots[it.slot]
                    if it.is_prefill:
                        seg = st.prompt[st.prompt_pos : st.prompt_pos + it.q_len]
                        tokens[it.slot, : len(seg)] = seg
                        bases[it.slot] = counts[it.slot] - (it.q_len - 1)
                        n_prefill += it.q_len
                    else:
                        row = [int(cur[it.slot])] + drafts.get(it.slot, [])[
                            : it.n_draft
                        ]
                        tokens[it.slot, : len(row)] = row
                        n_decode += it.q_len
                    qlens[it.slot] = it.q_len

                # The device span closes only after the sampled tokens are
                # host-materialized, so it brackets real device time (the
                # dispatch itself is async). The step is functional (pages
                # come back as fresh arrays; the pool adopts them only on
                # success), so a failed dispatch leaves no partial state and
                # a retry re-runs the identical computation: one transient
                # failure is retried once, a second failure fails the
                # step's rows cleanly and the engine moves on.
                # Suspended rows keep their logical length host-side for the
                # resume, but the step operand sees 0: their block-table row
                # is dummied out, and a len>0 row over dummy pages is a
                # shape the kernels never needed to define.
                lens_op = pool.lens
                if tiered and pool.suspended_slots():
                    lens_op = pool.lens.copy()
                    lens_op[pool.suspended_slots()] = 0

                def dispatch():
                    if self.faults is not None:
                        self.faults.raise_if("device.step")
                    with self._mesh_ctx():
                        toks_dev, pages = step_fn(
                            self.params,
                            jnp.asarray(tokens),
                            pool.pages,
                            pool.block_tables,
                            lens_op,
                            qlens,
                            np.int32(
                                self.order_ctl.effective_group(
                                    pool.blocks_per_seq
                                )
                            ),
                            temps,
                            seeds,
                            bases,
                        )
                    if tiered and pool.fetch_backlog():
                        # Overlap the prefetch with the in-flight step: the
                        # async device_put H2D copies queue up behind the
                        # dispatched step, and the np.asarray force below
                        # only blocks on the step's own outputs. Staged rows
                        # are spliced at a later boundary — never into the
                        # pages this step is reading.
                        with tr.span("serve.prefetch", overlapped=True):
                            for i in pool.suspended_slots():
                                pool.issue_fetches(
                                    i, self.prefetch_depth, overlapped=True
                                )
                    return np.asarray(toks_dev), pages

                with tr.span(
                    "serve.device_step", width=width, rows=len(plan),
                    tokens=planned,
                ):
                    try:
                        toks, pages = dispatch()
                    except Exception:
                        self._m_retries.inc()
                        tr.instant("serve.step_retry", step=step)
                        try:
                            toks, pages = dispatch()
                        except Exception:
                            for it in plan:
                                if sched.slots[it.slot] is not None:
                                    finish(it.slot, "failed")
                            step += 1
                            continue
                pool.update_pages(pages)
                cc = self.compiled_step_count()
                if cc > last_cc:
                    tr.instant("serve.compile", width=width, variants=cc)
                    self._m_compiles.inc(cc - last_cc)
                    last_cc = cc
                step += 1
                n_steps += 1
                n_wide += width > 1
                self._m_tok_decode.inc(n_decode)
                self._m_tok_prefill.inc(n_prefill)
                (self._m_steps_wide if width > 1 else self._m_steps_narrow).inc()
                for it in plan:
                    st = sched.slots[it.slot]
                    pool.advance(it.slot, it.q_len)
                    if it.is_prefill:
                        st.prompt_pos += it.q_len
                        if not it.finishes_prompt:
                            continue
                        # Prompt complete: publish its frozen pages for future
                        # admissions to adopt, then take the first sample.
                        pool.register_prompt(it.slot, st.prompt)
                    if it.n_draft == 0:
                        tok = int(toks[it.slot, it.q_len - 1])
                        if id(st.request) not in first_t:
                            first_t[id(st.request)] = time.perf_counter()
                        counts[it.slot] += 1
                        cur[it.slot] = tok
                        if st.record(tok):
                            finish(it.slot)
                        continue
                    # Speculative verification row: the chunk was [cur,
                    # d_1..d_K]; target t_i = toks[slot, i] is the token the
                    # sequential stream would sample after absorbing the
                    # first i drafts. Accept the longest prefix d_1..d_a
                    # with d_{i+1} == t_i, emit t_0..t_a (the bonus token t_a
                    # rides for free), stopping early at EOS / new_limit as
                    # a sequential stream would; then roll the uncommitted
                    # chunk tail back out of the cache. The row's sample
                    # count advances by exactly the tokens emitted — the
                    # PRNG-stream guarantee that keeps sampled runs bitwise
                    # identical to non-speculative serving.
                    d = drafts.get(it.slot, [])[: it.n_draft]
                    k = len(d)
                    a = 0
                    while a < k and d[a] == int(toks[it.slot, a]):
                        a += 1
                    emitted = 0
                    finished = False
                    for p in range(a + 1):
                        tok = int(toks[it.slot, p])
                        if id(st.request) not in first_t:
                            first_t[id(st.request)] = time.perf_counter()
                        emitted += 1
                        cur[it.slot] = tok
                        if st.record(tok):
                            finished = True
                            break
                    counts[it.slot] += emitted
                    n_roll = it.q_len - emitted
                    if n_roll and not finished:
                        pool.rollback(it.slot, n_roll)
                    accepted = emitted - 1
                    tally["draft"] += k
                    tally["accept"] += accepted
                    tally["roll"] += k - accepted
                    self._m_draft_tok.inc(k)
                    self._m_accept_tok.inc(accepted)
                    self._m_rollback_tok.inc(k - accepted)
                    if finished:
                        finish(it.slot)
                if self.faults is not None and self.faults.fired_this_step:
                    # Every injected fault is followed by a full pool
                    # consistency audit at the very step that absorbed it.
                    pool.check_invariants()
                pool.emit_gauges()
                # Widest decode/verify chunk of this step (K+1 under
                # speculative decoding, 1 otherwise): the LLC models must
                # see the query width each KV sweep is amortized over.
                step_q = max(
                    (it.q_len for it in plan if not it.is_prefill), default=1
                )
                if self.order_ctl is not None and self.order_ctl.enabled:
                    # Adaptation drives its own sampling cadence (the
                    # decision needs a fresh reading, not a stale gauge).
                    if self.order_ctl.maybe_adapt(
                        n_steps, pool, self.llc, step_q=step_q
                    ):
                        tr.instant(
                            "serve.order_switch",
                            order=self.order_ctl.order.value,
                            step=n_steps,
                        )
                elif self.llc is not None:
                    self.llc.maybe_sample(n_steps, pool, step_q=step_q)
            self._m_step_time.observe(time.perf_counter() - t_iter)
            if self._log_every and n_steps and n_steps % self._log_every == 0:
                self._log_stats_line(n_steps, pool, sched)

        # A drained stream is definitionally un-paused: the loop can exit
        # right after the final retirement, before any boundary recomputes
        # the watermark, and the gauge must not stay latched at 1.
        self._m_admit_paused.set(0.0)
        # Deterministic work counters for benches / CI trend lines (wall
        # clock on a shared CI box is noisy; step counts are not). Typed
        # snapshot of this stream; cumulative totals live in the registry.
        by_status: dict[str, int] = {}
        for res in results.values():
            by_status[res.status] = by_status.get(res.status, 0) + 1
        self.last_stats = StepStats(
            mixed_steps=n_steps,
            wide_steps=n_wide,
            pages_adopted=pool.shared_hits,
            prompt_tokens_adopted=pool.shared_tokens,
            cow_forks=pool.cow_forks,
            preemptions=tally["preempt"],
            restore_tokens=tally["restore"],
            shed=by_status.get("shed", 0),
            deadline_miss=by_status.get("deadline", 0),
            cancelled=by_status.get("cancelled", 0),
            failed=by_status.get("failed", 0),
            spills=getattr(pool, "spills", 0),
            tier_fetches=getattr(pool, "fetches", 0),
            prefetch_hits=getattr(pool, "prefetch_hits", 0),
            prefetch_wasted=getattr(pool, "prefetch_wasted", 0),
            draft_tokens=tally["draft"],
            accepted_tokens=tally["accept"],
            rollback_tokens=tally["roll"],
        )
        return [results[id(r)] for r in requests]

    def _log_stats_line(self, n_steps: int, pool, sched) -> None:
        """Periodic one-line operational summary (launchers enable it)."""
        v = self.obs.value
        spec = ""
        if self.drafter is not None:
            drafted = v("serve.spec.draft_tokens")
            acc = v("serve.spec.accepted_tokens")
            spec = (
                f" draft={drafted:.0f} accept={acc:.0f}"
                f" ({acc / drafted:.0%})" if drafted else " draft=0"
            )
        print(
            f"[serve] step {n_steps}: "
            f"queue={len(sched.waiting)} active={len(sched.active_slots())} "
            f"tokens dec/pre={v('serve.step.tokens', kind='decode'):.0f}"
            f"/{v('serve.step.tokens', kind='prefill'):.0f} "
            f"gen={v('serve.tokens.generated'):.0f} "
            f"pool free={pool.alloc.free_count} "
            f"occ={v('pool.occupancy_frac'):.0%} "
            f"adopted={pool.shared_hits} cow={pool.cow_forks}"
            f"{spec}"
        )

    def _admit(
        self,
        req: Request,
        slot: int,
        sched,
        pool,
        temps,
        seeds,
        counts,
        idx: int,
        prior: Optional[list] = None,
    ):
        """Admit ``req`` into ``slot``; returns the placed ``Slot`` or None
        if the pool lacks pages.

        No prefill happens here — the prompt's non-shared tokens run
        through the mixed step as chunks. The pool adopts any registered
        shared prefix (its KV is already resident), so ``prompt_pos``
        starts past the adopted tokens.

        ``prior`` (a preempted request's generated-so-far) turns admission
        into a *restore*: the effective prompt becomes prompt + prior —
        re-prefilled chunk-wise through the same compiled mixed step, no
        restore kernel — the slot's generated list is pre-seeded with the
        prior tokens (so ``new_limit`` and EOS accounting continue, not
        restart), and the sampling count resumes at ``len(prior)``. Row
        PRNG keys depend only on (engine seed, request seed, count), never
        on the slot or the step, so the restored stream is bitwise the
        uninterrupted one — for greedy and sampled rows alike.
        """
        cap = self._cap
        prompt = np.asarray(req.tokens, np.int32)[-cap:]
        if len(prompt) == 0:
            prompt = np.full((1,), self.eos, np.int32)  # empty prompt -> 1 pad
        new_limit = max(0, min(req.max_new_tokens, cap - len(prompt) + 1))
        if new_limit == 0:
            # Nothing to emit — resolve without consuming pages.
            st = sched.place(slot, req, eos_id=self._eos_for(req), new_limit=0)
            st.done = True
            return st
        prior = list(prior) if prior else []
        if prior:
            # len(prompt+prior) <= len(prompt) + new_limit - 1 <= cap by the
            # new_limit clamp above, so the restore prompt always fits.
            full = np.concatenate([prompt, np.asarray(prior, np.int32)])
        else:
            full = prompt
        shared = pool.admit(slot, full, new_limit - len(prior))
        if shared is None:
            return None
        st = sched.place(
            slot,
            req,
            eos_id=self._eos_for(req),
            new_limit=new_limit,
            prompt=full,
            prompt_pos=shared,
        )
        st.generated = prior
        st.n_prior = len(prior)  # prompt already carries the prior tokens —
                                 # the committed stream for drafters is
                                 # prompt + generated[n_prior:]
        temps[slot] = req.temperature
        seeds[slot] = self._seed_for(req, idx)
        counts[slot] = len(prior)
        return st
