"""Batched serving engine: prefill + decode over the unified LM API.

Static-batch continuous-ish serving: requests are grouped into fixed-size
batches (padding short prompts on the left so all rows share one prefill
length bucket), prefilled once, then decoded token-by-token with greedy or
temperature sampling until EOS/max_new_tokens. KV caches, SWA ring buffers
and SSM states all live behind ``lm.prefill/decode_step``.

On TPU the decode step uses the Pallas flash-decode kernel with the
schedule from the paper's technique; on CPU it uses the jnp path.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.dist import sharding as shd
from repro.models.model import LM

__all__ = ["Request", "GenerationResult", "ServeEngine"]

EOS = 1


@dataclasses.dataclass
class Request:
    tokens: np.ndarray            # prompt (1D int32)
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    rid: int = 0


@dataclasses.dataclass
class GenerationResult:
    rid: int
    tokens: np.ndarray            # generated tokens (without prompt)
    steps: int


class ServeEngine:
    def __init__(
        self,
        lm: LM,
        params,
        *,
        batch_size: int = 8,
        max_len: int = 1024,
        seed: int = 0,
        mesh=None,
        pcfg: Optional[ParallelConfig] = None,
    ):
        """Pass ``mesh`` (+ optional ParallelConfig) for sharded serving:
        params are placed on their TP/FSDP shardings and every step runs
        under the mesh context (GSPMD propagates cache/batch shardings)."""
        self.lm = lm
        self.mesh = mesh
        # Cache capacity model, shared by validation here and the budgeting
        # in _generate_batch: prefill writes bucket + prefix tokens (VLM
        # prepends prefix embeddings) and decode writes max_new - 1 more
        # (the last sampled token is never written back). Only
        # full-attention caches are max_len-bounded — SSM decode state is
        # O(1) and sliding-window archs use a ring buffer.
        self._prefix = (
            min(lm.cfg.n_prefix_embeds, 8) if lm.cfg.family == "vlm" else 0
        )
        bounded = lm.cfg.window is None and lm.cfg.family != "ssm"
        if bounded and max_len <= self._prefix:
            detail = (
                f"the {self._prefix} VLM prefix embeddings leave no room"
                if self._prefix
                else "it must be positive"
            )
            raise ValueError(
                f"max_len={max_len} gives a zero-capacity KV cache ({detail}); "
                f"use max_len > {self._prefix}"
            )
        self._cap = max_len - self._prefix if bounded else None
        if mesh is not None:
            pcfg = pcfg or ParallelConfig(fsdp_axes=("data",), data_axes=("data",))
            params = jax.device_put(params, shd.param_shardings(params, pcfg, mesh))
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, b, max_len))
        self._decode = jax.jit(lm.decode_step)

    def _mesh_ctx(self):
        return (
            jax.set_mesh(self.mesh) if self.mesh is not None else contextlib.nullcontext()
        )

    def _pad_batch(
        self, prompts: Sequence[np.ndarray], max_bucket: Optional[int] = None
    ) -> jnp.ndarray:
        # Shared prefill bucket. Bounded (full-attention) caches cap it at
        # the cache capacity: an overlong prompt keeps only its most recent
        # tokens (causal LM — the tail conditions generation) instead of
        # silently overflowing the prefill bucket and then clamp-overwriting
        # the cache's last slot every decode step. max_bucket=None (SSM
        # state, SWA ring buffers) leaves prompts untouched.
        length = max(1, max(len(p) for p in prompts))  # all-empty -> 1 EOS pad
        if max_bucket is not None:
            length = min(length, max_bucket)
        out = np.full((self.batch_size, length), EOS, np.int32)
        for i, p in enumerate(prompts):
            p = p[-length:]
            out[i, length - len(p) :] = p  # left-pad into a shared bucket
        return jnp.asarray(out)

    def generate(self, requests: Sequence[Request]) -> list[GenerationResult]:
        results: list[GenerationResult] = []
        for i in range(0, len(requests), self.batch_size):
            group = list(requests[i : i + self.batch_size])
            results.extend(self._generate_batch(group))
        return results

    def _generate_batch(self, group: Sequence[Request]) -> list[GenerationResult]:
        # Prompts get priority for the bounded capacity (see __init__ for
        # the capacity model); a request whose max_new_tokens exceeds what
        # remains after the shared bucket is clamped (visible via .steps),
        # not failed — one greedy request must not abort or context-starve
        # the rest of the batch.
        prefix, cap = self._prefix, self._cap
        tokens = self._pad_batch([r.tokens for r in group], max_bucket=cap)
        bucket = tokens.shape[1]
        new_limits = [
            r.max_new_tokens
            if cap is None
            else max(0, min(r.max_new_tokens, cap - bucket + 1))
            for r in group
        ]
        max_new = max(new_limits)
        if self.lm.cfg.family == "encdec":
            b, s = tokens.shape
            batch = {
                "src_embeds": jnp.zeros((b, s, self.lm.cfg.d_model), self.lm.cfg.activation_dtype()),
                "tgt_tokens": tokens,
            }
        elif self.lm.cfg.family == "vlm":
            b, s = tokens.shape
            batch = {
                "tokens": tokens,
                "prefix_embeds": jnp.zeros((b, prefix, self.lm.cfg.d_model), self.lm.cfg.activation_dtype()),
            }
        else:
            batch = {"tokens": tokens}

        with self._mesh_ctx():
            logits, caches = self._prefill(self.params, batch)
        generated = np.zeros((len(group), max_new), np.int32)
        done = np.asarray([lim == 0 for lim in new_limits])  # 0-limit rows emit nothing
        steps = np.zeros(len(group), np.int32)

        cur = self._sample(logits[:, -1], group)
        for t in range(max_new):
            for j in range(len(group)):
                if not done[j]:
                    generated[j, t] = int(cur[j, 0])
                    steps[j] = t + 1
                    if int(cur[j, 0]) == EOS or t + 1 >= new_limits[j]:
                        done[j] = True
            if done.all():
                break
            with self._mesh_ctx():
                logits, caches = self._decode(self.params, cur, caches)
            cur = self._sample(logits[:, -1], group)

        return [
            GenerationResult(rid=r.rid, tokens=generated[j, : steps[j]], steps=int(steps[j]))
            for j, r in enumerate(group)
        ]

    def _sample(self, logits: jax.Array, group) -> jnp.ndarray:
        temp = max((r.temperature for r in group), default=0.0)
        if temp <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temp, axis=-1)[:, None].astype(
            jnp.int32
        )
