from repro.serve.adapt import ORDER_INDEX, OrderAdaptController
from repro.serve.engine import (
    CONTINUOUS_FAMILIES,
    REQUEST_STATUSES,
    GenerationResult,
    Request,
    ServeEngine,
    StepStats,
    select_victim,
    supports_continuous,
)
from repro.serve.faults import FAULT_SITES, Fault, FaultPlan, StepFault
from repro.serve.kv_pool import (
    AdmissionError,
    PagedKVPool,
    PagePool,
    PoolError,
    PoolExhausted,
    assemble_cache_view,
)
from repro.serve.scheduler import ContinuousScheduler, Slot, StepItem
from repro.serve.spec import Drafter, ModelDrafter, NgramDrafter, make_drafter
from repro.serve.tiering import (
    HostPageStore,
    TieredPagePool,
    select_spill_victim,
)

__all__ = [
    "ORDER_INDEX",
    "OrderAdaptController",
    "CONTINUOUS_FAMILIES",
    "REQUEST_STATUSES",
    "GenerationResult",
    "Request",
    "ServeEngine",
    "StepStats",
    "select_victim",
    "supports_continuous",
    "FAULT_SITES",
    "Fault",
    "FaultPlan",
    "StepFault",
    "AdmissionError",
    "PagedKVPool",
    "PagePool",
    "PoolError",
    "PoolExhausted",
    "assemble_cache_view",
    "ContinuousScheduler",
    "Slot",
    "StepItem",
    "Drafter",
    "ModelDrafter",
    "NgramDrafter",
    "make_drafter",
    "HostPageStore",
    "TieredPagePool",
    "select_spill_victim",
]
