from repro.serve.engine import GenerationResult, Request, ServeEngine

__all__ = ["GenerationResult", "Request", "ServeEngine"]
