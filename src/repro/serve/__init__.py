from repro.serve.adapt import ORDER_INDEX, OrderAdaptController
from repro.serve.engine import (
    CONTINUOUS_FAMILIES,
    GenerationResult,
    Request,
    ServeEngine,
    StepStats,
    supports_continuous,
)
from repro.serve.kv_pool import PagedKVPool, PagePool, assemble_cache_view
from repro.serve.scheduler import ContinuousScheduler, Slot, StepItem

__all__ = [
    "ORDER_INDEX",
    "OrderAdaptController",
    "CONTINUOUS_FAMILIES",
    "GenerationResult",
    "Request",
    "ServeEngine",
    "StepStats",
    "supports_continuous",
    "PagedKVPool",
    "PagePool",
    "assemble_cache_view",
    "ContinuousScheduler",
    "Slot",
    "StepItem",
]
