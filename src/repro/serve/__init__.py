from repro.serve.engine import (
    CONTINUOUS_FAMILIES,
    GenerationResult,
    Request,
    ServeEngine,
    supports_continuous,
)
from repro.serve.kv_pool import PagedKVPool, PagePool
from repro.serve.scheduler import ContinuousScheduler, Slot

__all__ = [
    "CONTINUOUS_FAMILIES",
    "GenerationResult",
    "Request",
    "ServeEngine",
    "supports_continuous",
    "PagedKVPool",
    "PagePool",
    "ContinuousScheduler",
    "Slot",
]
