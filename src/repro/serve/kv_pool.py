"""Shared paged KV pool for continuous-batching serving, with refcounted
copy-on-write prefix sharing.

One physical page pool per layer (stacked on a leading L axis, matching the
scanned cache pytrees the models produce) is shared by every running
sequence; each decode slot owns a *block table* row mapping its logical
pages to physical pool pages. Page size equals the schedule's ``kv_block``
(see ``transformer.page_geometry``), so a block-table entry is exactly one
KV tile of the paper's traversal schedule and the decode kernels walk the
table in ``KVSchedule`` order (DESIGN.md §8).

Page 0 is a reserved dummy: free slots — and the invalid rows of a ragged
mixed step — point their writes at it, so the fixed-shape whole-batch step
can write masked-out tokens somewhere harmless.

**Prefix sharing.** Every physical page carries a refcount. Full prompt
pages are registered in a content-hash registry (a rolling hash over the
chain of page token contents, with exact token comparison on lookup, so
hash collisions are harmless): when a new prompt's leading pages match a
registered chain, ``admit`` *adopts* those pages — refcount bump, zero
prefill compute, zero copies — instead of recomputing and re-storing them.
A partially-matching tail page is adopted too (its extra positions are
masked by the row's ``len``); the first write into it triggers
copy-on-write in :meth:`PagedKVPool.ensure_writable` — fork to a fresh
page, decrement the shared page's refcount. ``release`` decrements
refcounts and frees+unregisters pages that hit zero, so sharing survives
the donor's retirement for as long as any adopter still holds the pages.

Allocation is lazy (a sequence materializes owned pages as its writes cross
page boundaries). Two admission disciplines (DESIGN.md §12):

* ``admission="reserve"`` (default) — worst-case reservation: a request is
  admitted only if the pool can cover its *non-shared* worst case — prompt
  + full ``max_new_tokens``, minus the adopted pages that can never be
  written — on top of every running sequence's outstanding reservation, so
  lazy growth and CoW forks never fail mid-flight.
* ``admission="optimistic"`` — only the *prompt's* pages are reserved;
  decode growth competes for the remaining pool, so the pool can be
  oversubscribed and mid-flight allocation can fail with a typed
  :class:`PoolExhausted` — the serve engine's pool-pressure preemption
  (victim selection + chunked re-prefill restore) is the recovery path.

Failures are typed: :class:`PoolExhausted` (allocation), ``AdmissionError``
(admission misuse); both keep their legacy base (``RuntimeError`` /
``ValueError``) for one release so existing ``except`` clauses still catch
them. ``release`` is idempotent — double-retiring a slot during preemption
cleanup is a no-op, never a refcount corruption. int8 pools
(``kv_cache_dtype='int8'``) carry the per-vector scales from
``repro.dist.compression`` as parallel page arrays and halve the pool's HBM
footprint.
"""

from __future__ import annotations

import functools
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

__all__ = [
    "PagePool",
    "PagedKVPool",
    "assemble_cache_view",
    "PoolError",
    "PoolExhausted",
    "AdmissionError",
]


class PoolError(RuntimeError):
    """Base of the serve pool's typed failures (``RuntimeError`` kept as a
    base for one release so legacy ``except RuntimeError`` still catches)."""


class PoolExhausted(PoolError):
    """Page allocation could not be satisfied from the free list.

    Under ``admission="reserve"`` this can only happen through fault
    injection; under ``admission="optimistic"`` it is the steady-state
    pressure signal the engine answers with preemption.
    """


class AdmissionError(PoolError, ValueError):
    """Admission-path misuse (occupied slot, unusable pool geometry).

    Inherits both legacy bases — these paths used to raise bare
    ``RuntimeError`` or ``ValueError`` depending on the call site.
    """


def assemble_cache_view(
    pages: dict, block_table, lens, n_layers: int, q_lens=None, order_group=None
) -> dict:
    """Splice block tables + lengths into a page pytree for ``decode_step``.

    Block tables and lengths are tiled across the layer axis because the
    scanned decode carries one copy per layer (a few KB — uniformity with
    the contiguous cache pytree is worth more than the bytes). ``q_lens``
    (B,) adds the ragged mixed step's per-row valid chunk counts
    (``transformer.attn_decode`` reads it as ``cache["q_len"]``);
    ``order_group`` a traced effective reversal-group scalar
    (``core.schedule.resolve_order_group``) that overrides the config's
    static traversal order for this step (``cache["order_group"]`` — the
    online order adaptation's rebind channel). Traceable: the engine calls
    this inside its fused jitted mixed step.
    """
    view = dict(pages)
    bt = jnp.asarray(block_table)
    ln = jnp.asarray(lens)
    view["block_table"] = jnp.broadcast_to(bt, (n_layers,) + bt.shape)
    view["len"] = jnp.broadcast_to(ln, (n_layers,) + ln.shape)
    if q_lens is not None:
        ql = jnp.asarray(q_lens)
        view["q_len"] = jnp.broadcast_to(ql, (n_layers,) + ql.shape)
    if order_group is not None:
        og = jnp.asarray(order_group, jnp.int32)
        view["order_group"] = jnp.broadcast_to(og, (n_layers,) + og.shape)
    return view


class PagePool:
    """Host-side free-list allocator over physical page ids.

    Page 0 is never handed out (reserved dummy). ``reserved`` tracks pages
    promised to admitted-but-not-yet-written sequences; ``available`` is
    what a new admission may claim. ``faults`` is the no-op fault-injection
    hook (``serve.faults.FaultPlan``): when attached, an ``alloc`` that the
    plan schedules to fail raises :class:`PoolExhausted` exactly as a real
    exhaustion would, so the engine's preemption path is testable on a pool
    that is not actually full.
    """

    def __init__(self, n_pages: int, *, faults=None):
        if n_pages < 2:
            raise AdmissionError(f"pool needs >= 2 pages (1 dummy), got {n_pages}")
        self.n_pages = n_pages
        self._free: list[int] = list(range(n_pages - 1, 0, -1))  # pop() -> low ids
        self.reserved = 0
        self.faults = faults

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        return self.free_count - self.reserved

    def alloc(self, n: int) -> list[int]:
        if self.faults is not None and self.faults.take("pool.alloc"):
            raise PoolExhausted(
                f"injected pool exhaustion: want {n}, free {self.free_count}"
            )
        if n > self.free_count:
            raise PoolExhausted(
                f"page pool exhausted: want {n}, free {self.free_count}"
            )
        return [self._free.pop() for _ in range(n)]

    def free(self, ids) -> None:
        self._free.extend(int(i) for i in ids)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(dst: jax.Array, src_id: jax.Array, dst_id: jax.Array) -> jax.Array:
    """dst (L, P, ...): physical page ``src_id`` copied onto ``dst_id``.

    The pool buffer is donated — callers always rebind ``pages[name]`` to
    the result — so a CoW fork updates in place (O(page) traffic) instead
    of cloning the whole pool per leaf (backends without donation fall back
    to the copy with a one-time warning)."""
    return dst.at[:, dst_id].set(dst[:, src_id])


def _hash_step(h: int, page_tokens: np.ndarray) -> int:
    """One link of the rolling prompt-page content hash. Collisions are
    harmless — every registry hit is verified by exact token comparison."""
    return zlib.crc32(np.ascontiguousarray(page_tokens, np.int32).tobytes(), h)


class PagedKVPool:
    """Device page pool + host block tables / lengths / refcounts / registry.

    The device side is a dict of stacked leaves shaped like the per-layer
    paged caches from ``transformer.init_cache`` with a leading layer axis,
    which is exactly what ``stack_decode`` scans — ``caches_view()`` splices
    the host block tables and lengths in, and ``update_pages()`` takes the
    written pages back after a mixed step. K/V values are *produced* by the
    engine's ragged mixed step writing at per-row offsets
    (``transformer._paged_write``); the pool itself never copies prefill
    caches — admission only adopts (shared) or reserves (owned) pages.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        n_layers: int,
        n_slots: int,
        max_len: int,
        *,
        dtype=None,
        prefix_sharing: bool = True,
        registry=None,
        admission: str = "reserve",
        n_pages: Optional[int] = None,
        faults=None,
    ):
        if cfg.window is not None:
            raise ValueError("paged KV pools require full attention (window=None)")
        if admission not in ("reserve", "optimistic"):
            raise AdmissionError(f"unknown admission discipline {admission!r}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.prefix_sharing = prefix_sharing
        self.admission = admission
        self.page, self.blocks_per_seq = T.page_geometry(cfg, max_len)
        self.capacity = self.blocks_per_seq * self.page
        # ``n_pages`` (allocatable pages, dummy excluded) defaults to the
        # full worst case — every slot at capacity. A smaller override is
        # the oversubscription knob: less HBM than the slots could demand,
        # with the engine's preemption absorbing the pressure. It must still
        # fit one capacity row, or some admissions could never succeed.
        if n_pages is None:
            n_pages = n_slots * self.blocks_per_seq
        if n_pages < self.blocks_per_seq:
            raise AdmissionError(
                f"pool of {n_pages} pages cannot fit one {self.blocks_per_seq}"
                f"-page capacity row"
            )
        self.alloc = PagePool(n_pages + 1, faults=faults)  # +1 dummy page 0
        self.faults = faults

        shape = (n_layers, self.alloc.n_pages, self.page, cfg.n_kv_heads, cfg.hd)
        self.pages: dict[str, jax.Array] = {}
        if cfg.kv_cache_dtype == "int8":
            for name in ("k_pages", "v_pages"):
                self.pages[name] = jnp.zeros(shape, jnp.int8)
                self.pages[name + "_scale"] = jnp.ones(shape[:4], jnp.float32)
        else:
            dt = dtype or cfg.activation_dtype()
            for name in ("k_pages", "v_pages"):
                self.pages[name] = jnp.zeros(shape, dt)

        self.block_tables = np.zeros((n_slots, self.blocks_per_seq), np.int32)
        self.lens = np.zeros((n_slots,), np.int32)
        # Per-slot written high-water mark: the furthest position this slot
        # itself has made writable (``ensure_writable``). ``rollback`` moves
        # ``lens`` down but not ``_written`` — the gap is exactly the region
        # holding disowned (rejected-draft) KV, which the registry-coverage
        # invariant in ``check_invariants`` polices.
        self._written = np.zeros((n_slots,), np.int32)
        self._ref = np.zeros((self.alloc.n_pages,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_reserved: list[int] = [0] * n_slots
        # Prefix registry: parent-chain-hash -> (physical page, its tokens).
        # Weak entries — a page is unregistered the moment it is freed or its
        # sole owner is about to overwrite it, so a registry hit (verified by
        # token equality) always points at live, correct KV.
        self._chain_next: dict[int, tuple[int, np.ndarray]] = {}
        self._page_parent: dict[int, int] = {}
        # Counters for benches/tests: pages / prompt tokens adopted instead
        # of recomputed, and CoW forks performed. With a ``repro.obs``
        # registry attached the same counts are published as ``pool.*``
        # counter series (and ``emit_gauges`` adds occupancy/refcount
        # gauges); the plain ints stay authoritative for registry-less use.
        self.shared_hits = 0
        self.shared_tokens = 0
        self.cow_forks = 0
        self._registry = registry
        if registry is not None:
            self._m_adopted = registry.counter("pool.pages_adopted")
            self._m_adopted_tokens = registry.counter("pool.tokens_adopted")
            self._m_cow = registry.counter("pool.cow_forks")
            # Pre-create the gauges so every pool series exists from step 0.
            self.emit_gauges()

    # ---- admission / lifecycle ----------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page)

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        """Worst-case admissibility ignoring prefix sharing (sharing only
        ever *reduces* the requirement; ``admit`` checks the exact one)."""
        worst = self.pages_for(min(prompt_len + max_new, self.capacity))
        return self.alloc.available >= worst

    def match_prefix(self, prompt: np.ndarray) -> tuple[int, list[int]]:
        """Longest registered prefix of ``prompt``: (tokens covered, pages).

        Walks the rolling-hash chain over full prompt pages, verifying token
        contents at every link; a final *partial* page match (the registered
        page's leading tokens equal the prompt's remaining tokens) is adopted
        too — its first write CoW-forks. Coverage is capped at
        ``len(prompt) - 1``: the last prompt token must always run through
        the model to produce the first sampled logit.
        """
        prompt = np.asarray(prompt, np.int32)
        if not self.prefix_sharing or len(prompt) <= 1:
            return 0, []
        page = self.page
        limit = min(len(prompt) - 1, self.capacity)
        h, covered, pids = 0, 0, []
        while covered < limit:
            ent = self._chain_next.get(h)
            if ent is None:
                break
            pid, ptoks = ent
            seg = prompt[covered : covered + page]
            if (
                len(seg) == page
                and covered + page <= limit
                and np.array_equal(ptoks, seg)
            ):
                pids.append(pid)
                covered += page
                h = _hash_step(h, ptoks)
                continue
            rem = prompt[covered:limit]
            if rem.size and np.array_equal(ptoks[: rem.size], rem):
                pids.append(pid)
                covered = limit
            break
        return covered, pids

    def admit(self, slot: int, prompt: np.ndarray, max_new: int) -> Optional[int]:
        """Admit a request into ``slot``: adopt the shared prefix, reserve
        the owned pages this discipline guarantees. Returns the number of
        prompt tokens whose KV was adopted (0 if none), or None when the
        pool lacks pages.

        ``admission="reserve"`` reserves the worst case (prompt + full
        ``max_new``); ``"optimistic"`` reserves only the prompt's pages —
        decode growth then competes for the leftover pool and can raise
        :class:`PoolExhausted` mid-flight, which the engine answers with
        preemption. No K/V is copied and nothing is prefilled here — the
        engine's ragged mixed step computes the non-shared tokens chunk by
        chunk, writing through the block table into lazily materialized
        owned pages.
        """
        if self._slot_pages[slot] or self._slot_reserved[slot] or self.lens[slot]:
            # A freshly admitted slot with no adopted prefix holds no pages
            # and has len 0 — its reservation is what marks it occupied.
            raise AdmissionError(f"slot {slot} is occupied")
        if self.faults is not None and self.faults.take("pool.admit"):
            return None  # injected admission pressure
        prompt = np.asarray(prompt, np.int32)
        prompt_len = min(len(prompt), self.capacity)
        covered, pids = self.match_prefix(prompt)
        # Adopted pages strictly below the write boundary are never touched
        # again; a partially covered tail page will be CoW-forked (one page
        # from the reservation) on its first write.
        n_safe = covered // self.page
        guaranteed = (
            prompt_len + max_new if self.admission == "reserve" else prompt_len
        )
        worst = self.pages_for(min(guaranteed, self.capacity))
        need = max(worst - n_safe, 0)
        if self.alloc.available < need:
            return None
        for pid in pids:
            self._ref[pid] += 1
        self.shared_hits += len(pids)
        self.shared_tokens += covered
        if self._registry is not None and pids:
            self._m_adopted.inc(len(pids))
            self._m_adopted_tokens.inc(covered)
        self._slot_pages[slot] = list(pids)
        self._slot_reserved[slot] = need
        self.alloc.reserved += need
        self.block_tables[slot] = 0
        self.block_tables[slot, : len(pids)] = pids
        self.lens[slot] = covered
        self._written[slot] = 0  # adopted prefix KV was written by the donor
        return covered

    def _take_page(self, slot: int) -> int:
        if self._slot_reserved[slot] > 0:
            (pid,) = self.alloc.alloc(1)
            self.alloc.reserved -= 1
            self._slot_reserved[slot] -= 1
        else:
            # Beyond the reservation: legal only under optimistic admission,
            # and only from the unreserved remainder — a take here must not
            # eat a page promised to another (reserve-guaranteed) slot.
            if self.admission == "reserve":
                raise AssertionError("allocation beyond reservation")
            if self.alloc.available < 1:
                raise PoolExhausted(
                    f"optimistic growth for slot {slot}: free "
                    f"{self.alloc.free_count}, reserved {self.alloc.reserved}"
                )
            (pid,) = self.alloc.alloc(1)
        self._ref[pid] = 1
        return pid

    def _unregister(self, pid: int) -> None:
        parent = self._page_parent.pop(pid, None)
        if parent is not None and self._chain_next.get(parent, (None,))[0] == pid:
            del self._chain_next[parent]

    def ensure_writable(self, slot: int, n: int = 1) -> None:
        """Make positions ``[len, len+n)`` of ``slot`` writable: materialize
        missing pages, copy-on-write-fork shared ones, unregister a sole-
        owned registered page about to diverge. Covered by the admission
        reservation, so allocation cannot fail within the worst-case budget.
        """
        start = int(self.lens[slot])
        end = min(start + n, self.capacity)
        if end <= start:
            return
        held = self._slot_pages[slot]
        for pg in range(start // self.page, (end - 1) // self.page + 1):
            if pg < len(held):
                pid = held[pg]
                if self._ref[pid] > 1:
                    nid = self._take_page(slot)
                    self.cow_forks += 1
                    if self._registry is not None:
                        self._m_cow.inc()
                    for name in self.pages:
                        self.pages[name] = _copy_page(
                            self.pages[name],
                            jnp.int32(pid),
                            jnp.int32(nid),
                        )
                    self._ref[pid] -= 1
                    held[pg] = nid
                    self.block_tables[slot, pg] = nid
                elif pid in self._page_parent:
                    # Sole owner writing a registered page: its content is
                    # about to diverge from the registered prompt chain.
                    self._unregister(pid)
            else:
                pid = self._take_page(slot)
                held.append(pid)
                self.block_tables[slot, pg] = pid
        self._written[slot] = max(int(self._written[slot]), end)

    def advance(self, slot: int, n: int = 1) -> None:
        """Record ``n`` written tokens (host mirror of the device len+q_len)."""
        self.lens[slot] = min(self.lens[slot] + n, self.capacity)

    def rollback(self, slot: int, n: int) -> int:
        """Disown the last ``n`` tokens of ``slot`` — the speculative-decoding
        reject path: a host-side ``lens`` decrement plus release of tail
        pages that no longer back any live token. Returns pages freed.

        Contract: only tokens the slot itself wrote (rejected draft tokens)
        may be rolled back. Those positions went through
        :meth:`ensure_writable`, whose CoW fork guarantees the backing pages
        are exclusively owned — dropping a page another slot still holds
        (refcount > 1) means the caller rolled back adopted prefix content
        and raises :class:`PoolError` before any state is mutated.

        Under ``admission="reserve"`` each freed page is returned to the
        slot's reservation, preserving the cannot-fail growth guarantee for
        a later re-draft over the same positions. The registry refresh then
        unregisters any still-held registered page whose coverage extends
        past the new live len into positions this slot wrote
        (``_written``) — without it, a later ``admit`` could adopt a page
        whose tail holds rejected draft KV.
        """
        n = min(int(n), int(self.lens[slot]))
        if n <= 0:
            return 0
        new_len = int(self.lens[slot]) - n
        keep = self.pages_for(new_len)
        held = self._slot_pages[slot]
        dropped = held[keep:]
        for pid in dropped:
            if self._ref[pid] > 1:
                raise PoolError(
                    f"rollback({slot}, {n}) would drop shared page {pid} "
                    f"(ref {int(self._ref[pid])}): only self-written tokens "
                    "may be rolled back"
                )
        for pid in dropped:
            self._ref[pid] -= 1
            self._unregister(pid)
            self.alloc.free([pid])
        del held[keep:]
        self.block_tables[slot, keep:] = 0
        self.lens[slot] = new_len
        if dropped and self.admission == "reserve":
            self._slot_reserved[slot] += len(dropped)
            self.alloc.reserved += len(dropped)
        for pg, pid in enumerate(held):
            end = (pg + 1) * self.page
            if (
                pid in self._page_parent
                and new_len < end <= int(self._written[slot])
            ):
                self._unregister(pid)
        return len(dropped)

    def register_prompt(self, slot: int, prompt: np.ndarray) -> None:
        """Publish ``slot``'s full prompt pages in the prefix registry.

        Call once, when the slot's prompt is fully in cache and before its
        first decode write. Only *frozen* pages are registered — the full
        pages strictly inside the prompt, which no decode write can ever
        touch. A chain link already registered with the same content is
        *refreshed* to point at this slot's copy when it owns a distinct
        one (so the chain survives the original donor's retirement as long
        as ANY same-prefix sequence is still running); a divergent chain
        occupying the hash link ends registration (first-wins).
        """
        if not self.prefix_sharing:
            return
        prompt = np.asarray(prompt, np.int32)
        page = self.page
        held = self._slot_pages[slot]
        h = 0
        for j in range(min(len(prompt) // page, len(held))):
            ptoks = prompt[j * page : (j + 1) * page]
            pid = held[j]
            ent = self._chain_next.get(h)
            if ent is not None and not np.array_equal(ent[1], ptoks):
                break
            if ent is None or ent[0] != pid:
                if ent is not None:
                    self._page_parent.pop(ent[0], None)
                self._chain_next[h] = (pid, ptoks.copy())
                self._page_parent[pid] = h
            h = _hash_step(h, ptoks)

    def shared_donor(self, slot: int) -> bool:
        """Whether ``slot`` holds any page other slots also hold (refcount >
        1). Releasing such a slot frees fewer pages than it holds — the
        preemption victim policy prefers non-donors for exactly that reason.
        """
        return any(self._ref[pid] > 1 for pid in self._slot_pages[slot])

    def occupancy(self) -> float:
        """Held fraction of the allocatable pool (admission watermarks)."""
        n_alloc = self.alloc.n_pages - 1
        return (n_alloc - self.alloc.free_count) / max(n_alloc, 1)

    def release(self, slot: int) -> None:
        """Release every page ``slot`` holds. Idempotent: releasing an
        already-free slot is a no-op, so a double-retire during preemption
        cleanup (engine retires, then a failure path retires again) cannot
        drive refcounts negative or free pages twice."""
        if (
            not self._slot_pages[slot]
            and not self._slot_reserved[slot]
            and not self.lens[slot]
        ):
            self.block_tables[slot] = 0
            return
        for pid in self._slot_pages[slot]:
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._unregister(pid)
                self.alloc.free([pid])
        self.alloc.reserved -= self._slot_reserved[slot]
        self._slot_pages[slot] = []
        self._slot_reserved[slot] = 0
        self.block_tables[slot] = 0
        self.lens[slot] = 0
        self._written[slot] = 0

    # ---- invariants (property tests / debugging) -----------------------------

    def _offslot_pages(self, slot: int) -> int:
        """Logical pages of ``slot`` living outside its device block table.

        Always 0 here; ``serve.tiering.TieredPagePool`` overrides it with
        the slot's host-resident page count so ``check_invariants`` can
        keep asserting full logical coverage across tiers."""
        return 0

    def check_invariants(self) -> None:
        """Assert the pool's conservation + consistency invariants:
        free + distinct-held == allocatable pages, per-page refcounts equal
        the number of slots holding them, reservations are consistent, and
        every block-table entry points at a held page (or the dummy)."""
        held: dict[int, int] = {}
        for pages in self._slot_pages:
            assert len(set(pages)) == len(pages), "slot holds a page twice"
            for pid in pages:
                held[pid] = held.get(pid, 0) + 1
        assert self.alloc.free_count + len(held) == self.alloc.n_pages - 1, (
            f"page leak: free={self.alloc.free_count} held={len(held)} "
            f"of {self.alloc.n_pages - 1}"
        )
        for pid, cnt in held.items():
            assert pid != 0, "dummy page held by a slot"
            assert self._ref[pid] == cnt, (pid, self._ref[pid], cnt)
        assert (self._ref >= 0).all(), "negative refcount"
        for pid in range(1, self.alloc.n_pages):
            if pid not in held:
                assert self._ref[pid] == 0, f"freed page {pid} has refs"
                assert pid not in self._page_parent, f"freed page {pid} registered"
        assert self.alloc.reserved == sum(self._slot_reserved) >= 0
        for slot in range(self.n_slots):
            n_logical = -(-int(self.lens[slot]) // self.page)
            assert (
                len(self._slot_pages[slot]) + self._offslot_pages(slot) >= n_logical
            ), (slot, len(self._slot_pages[slot]), self._offslot_pages(slot), n_logical)
            for pg, pid in enumerate(self._slot_pages[slot]):
                assert self.block_tables[slot, pg] == pid
                # Rollback hygiene: no registry entry may extend past the
                # slot's live len into positions the slot itself wrote —
                # such a page would advertise rejected-draft KV for adoption.
                end = (pg + 1) * self.page
                assert not (
                    pid in self._page_parent
                    and int(self.lens[slot]) < end <= int(self._written[slot])
                ), (
                    f"registered page {pid} of slot {slot} extends past live "
                    f"len {int(self.lens[slot])} into written tail "
                    f"(page end {end}, written {int(self._written[slot])})"
                )
            for pg in range(len(self._slot_pages[slot]), self.blocks_per_seq):
                assert self.block_tables[slot, pg] == 0
        for parent, (pid, _) in self._chain_next.items():
            assert self._page_parent.get(pid) == parent

    # ---- telemetry -----------------------------------------------------------

    def emit_gauges(self, registry=None) -> None:
        """Publish the pool's occupancy/sharing state as ``pool.*`` gauges:
        free/reserved page counts, occupancy fraction of the allocatable
        pool, pages currently shared (refcount > 1) and registered in the
        prefix registry. Cheap (a handful of numpy reductions); the engine
        calls it once per mixed step."""
        registry = registry if registry is not None else self._registry
        if registry is None:
            return
        n_alloc = self.alloc.n_pages - 1  # dummy page 0 excluded
        held = n_alloc - self.alloc.free_count
        registry.gauge("pool.pages_free").set(self.alloc.free_count)
        registry.gauge("pool.pages_reserved").set(self.alloc.reserved)
        registry.gauge("pool.occupancy_frac").set(held / max(n_alloc, 1))
        registry.gauge("pool.shared_pages").set(int((self._ref > 1).sum()))
        registry.gauge("pool.registered_pages").set(len(self._page_parent))

    # ---- step plumbing -------------------------------------------------------

    def caches_view(self, q_lens=None) -> dict:
        """Cache pytree for ``decode_step``: pages + current tables/lens
        (host-authoritative), via :func:`assemble_cache_view`."""
        n_layers = next(iter(self.pages.values())).shape[0]
        return assemble_cache_view(
            self.pages, self.block_tables, self.lens, n_layers, q_lens
        )

    def update_pages(self, caches: dict) -> None:
        """Take back the page leaves written by a mixed step."""
        for name in self.pages:
            self.pages[name] = caches[name]
