"""Shared paged KV pool for continuous-batching serving.

One physical page pool per layer (stacked on a leading L axis, matching the
scanned cache pytrees the models produce) is shared by every running
sequence; each decode slot owns a *block table* row mapping its logical
pages to physical pool pages. Page size equals the schedule's ``kv_block``
(see ``transformer.page_geometry``), so a block-table entry is exactly one
KV tile of the paper's traversal schedule and the decode kernels walk the
table in ``KVSchedule`` order (DESIGN.md §8).

Page 0 is a reserved dummy: free slots point their block tables at it, so
the (fixed-shape, whole-batch) decode step can write the masked-out token
of an empty slot somewhere harmless.

Allocation is lazy (a sequence holds pages for the tokens it has, growing
one page at a time as decode crosses page boundaries) with worst-case
admission reservation: a request is admitted only if the pool can cover its
prompt bucket plus its full ``max_new_tokens`` on top of every running
sequence's outstanding reservation — so ``grow`` never fails mid-flight and
no preemption machinery is needed. int8 pools (``kv_cache_dtype='int8'``)
carry the per-vector scales from ``repro.dist.compression`` as parallel
page arrays and halve the pool's HBM footprint.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T

__all__ = ["PagePool", "PagedKVPool", "assemble_cache_view"]


def assemble_cache_view(pages: dict, block_table, lens, n_layers: int) -> dict:
    """Splice block tables + lengths into a page pytree for ``decode_step``.

    Block tables and lengths are tiled across the layer axis because the
    scanned decode carries one copy per layer (a few KB — uniformity with
    the contiguous cache pytree is worth more than the bytes). Traceable:
    the engine calls this inside its fused jitted decode step.
    """
    view = dict(pages)
    bt = jnp.asarray(block_table)
    ln = jnp.asarray(lens)
    view["block_table"] = jnp.broadcast_to(bt, (n_layers,) + bt.shape)
    view["len"] = jnp.broadcast_to(ln, (n_layers,) + ln.shape)
    return view


class PagePool:
    """Host-side free-list allocator over physical page ids.

    Page 0 is never handed out (reserved dummy). ``reserved`` tracks pages
    promised to admitted-but-not-yet-written sequences; ``available`` is
    what a new admission may claim.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (1 dummy), got {n_pages}")
        self.n_pages = n_pages
        self._free: list[int] = list(range(n_pages - 1, 0, -1))  # pop() -> low ids
        self.reserved = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        return self.free_count - self.reserved

    def alloc(self, n: int) -> list[int]:
        if n > self.free_count:
            raise RuntimeError(f"page pool exhausted: want {n}, free {self.free_count}")
        return [self._free.pop() for _ in range(n)]

    def free(self, ids) -> None:
        self._free.extend(int(i) for i in ids)


@jax.jit
def _scatter_pages(dst: jax.Array, src: jax.Array, ids: jax.Array) -> jax.Array:
    """dst (L, P, ...) <- src (L, n, ...) at physical pages ``ids`` (n,)."""
    return dst.at[:, ids].set(src.astype(dst.dtype))


class PagedKVPool:
    """Device page pool + host block tables / lengths / reservations.

    The device side is a dict of stacked leaves shaped like the per-layer
    paged caches from ``transformer.init_cache`` with a leading layer axis,
    which is exactly what ``stack_decode`` scans — ``caches_view()`` splices
    the host block tables and lengths in, and ``update_pages()`` takes the
    written pages back after a decode step.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        n_layers: int,
        n_slots: int,
        max_len: int,
        *,
        dtype=None,
    ):
        if cfg.window is not None:
            raise ValueError("paged KV pools require full attention (window=None)")
        self.cfg = cfg
        self.n_slots = n_slots
        self.page, self.blocks_per_seq = T.page_geometry(cfg, max_len)
        self.capacity = self.blocks_per_seq * self.page
        n_pages = n_slots * self.blocks_per_seq + 1  # +1 reserved dummy page 0
        self.alloc = PagePool(n_pages)

        shape = (n_layers, n_pages, self.page, cfg.n_kv_heads, cfg.hd)
        self.pages: dict[str, jax.Array] = {}
        if cfg.kv_cache_dtype == "int8":
            for name in ("k_pages", "v_pages"):
                self.pages[name] = jnp.zeros(shape, jnp.int8)
                self.pages[name + "_scale"] = jnp.ones(shape[:4], jnp.float32)
        else:
            dt = dtype or cfg.activation_dtype()
            for name in ("k_pages", "v_pages"):
                self.pages[name] = jnp.zeros(shape, dt)

        self.block_tables = np.zeros((n_slots, self.blocks_per_seq), np.int32)
        self.lens = np.zeros((n_slots,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(n_slots)]
        self._slot_worst: list[int] = [0] * n_slots

    # ---- admission / lifecycle ----------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page)

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        worst = self.pages_for(min(prompt_len + max_new, self.capacity))
        return self.alloc.available >= worst

    def insert(self, slot: int, caches, prompt_len: int, max_new: int) -> None:
        """Adopt a freshly prefilled B=1 paged cache pytree into ``slot``.

        ``caches`` comes from ``lm.prefill`` under the paged config with
        ``max_len == prompt bucket``: page leaves are (L, n_src, page, H, D)
        in identity order, so copying rows [0, pages_for(prompt_len)) into
        newly allocated physical pages is the whole insertion.
        """
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot} is occupied")
        n = self.pages_for(prompt_len)
        worst = self.pages_for(min(prompt_len + max_new, self.capacity))
        ids = self.alloc.alloc(n)
        self.alloc.reserved += worst - n
        self._slot_worst[slot] = worst
        self._slot_pages[slot] = ids
        idx = jnp.asarray(ids, jnp.int32)
        for name in self.pages:
            self.pages[name] = _scatter_pages(
                self.pages[name], caches[name][:, :n], idx
            )
        self.block_tables[slot] = 0
        self.block_tables[slot, :n] = ids
        self.lens[slot] = min(prompt_len, self.capacity)

    def ensure_writable(self, slot: int) -> None:
        """Grow ``slot`` by one page if the next decode write needs it.

        Covered by the admission reservation, so allocation cannot fail for
        a slot within its worst-case budget.
        """
        owned = self._slot_pages[slot]
        if self.lens[slot] >= len(owned) * self.page and len(owned) < self.blocks_per_seq:
            (pid,) = self.alloc.alloc(1)
            self.alloc.reserved -= 1
            owned.append(pid)
            self.block_tables[slot, len(owned) - 1] = pid

    def advance(self, slot: int) -> None:
        """Record one decoded token (host mirror of the device len+1)."""
        self.lens[slot] = min(self.lens[slot] + 1, self.capacity)

    def release(self, slot: int) -> None:
        ids = self._slot_pages[slot]
        self.alloc.free(ids)
        self.alloc.reserved -= self._slot_worst[slot] - len(ids)
        self._slot_pages[slot] = []
        self._slot_worst[slot] = 0
        self.block_tables[slot] = 0
        self.lens[slot] = 0

    # ---- decode-step plumbing ------------------------------------------------

    def caches_view(self) -> dict:
        """Cache pytree for ``decode_step``: pages + current tables/lens
        (host-authoritative), via :func:`assemble_cache_view`."""
        n_layers = next(iter(self.pages.values())).shape[0]
        return assemble_cache_view(
            self.pages, self.block_tables, self.lens, n_layers
        )

    def update_pages(self, caches: dict) -> None:
        """Take back the page leaves written by a decode step."""
        for name in self.pages:
            self.pages[name] = caches[name]
