"""Online traversal-order adaptation: modeled-LLC signal → visit-order knob.

PR 4's sweeps showed the winning traversal order *flips with KV footprint*
(cyclic while the working set fits the LLC, block_snake/sawtooth once it is
capacity-bound); PR 6 made that signal live (``obs.llc.LLCSampler`` gauges
against the real ``PagedKVPool``). This module closes the loop:
:class:`OrderAdaptController` seeds its initial order from the persistent
autotune cache at engine start, then every adaptation epoch re-evaluates the
per-candidate modeled miss bytes and — with hysteresis — switches the order
the serve engine binds into its next mixed steps.

The switch itself is free. ``core.schedule.resolve_order_group`` collapses
an (order, snake_group) pair to the single *effective reversal-group*
scalar the grouped-reversal formula needs (cyclic=1, sawtooth=n_blocks,
block_snake=g), and the decode stack accepts that scalar as a **traced
operand** (``order_group`` through ``assemble_cache_view`` →
``transformer._attn_decode_paged`` → ``ops.attention_decode``): the visit
order is data folded into the step's scalar-prefetch operands before the
kernel launches, not a trace constant, so flipping it between steps causes
zero recompiles (``ServeEngine.compiled_step_count()`` is invariant across
switches — pinned by tests).

Hysteresis: modeled miss bytes move with every admission/retirement, and a
marginal candidate that flaps the order each epoch would churn dashboards
for no locality gain. A switch therefore requires the best candidate to
beat the current order by at least ``hysteresis`` (fractional modeled-byte
improvement) on ``confirm`` *consecutive* samples; any epoch where the
candidate changes or falls under the threshold resets the count.

Metrics: ``serve.order_switches`` (counter) and ``serve.current_order``
(gauge, encoded via :data:`ORDER_INDEX` — 0=cyclic, 1=sawtooth,
2=block_snake — so a step dashboard can overlay order flips on the
footprint curve). Both series exist even when adaptation is disabled (the
gauge then just pins the static order), so the CI metrics schema can
require them unconditionally.
"""

from __future__ import annotations

from typing import Optional

from repro.core.schedule import DEFAULT_SNAKE_GROUP, Order, resolve_order_group
from repro.obs.autotune import load_autotune_cache, lookup_order_winner
from repro.obs.metrics import Registry

__all__ = ["OrderAdaptController", "ORDER_INDEX"]

# Stable gauge encoding of the order families (enum declaration order).
ORDER_INDEX = {Order.CYCLIC: 0, Order.SAWTOOTH: 1, Order.BLOCK_SNAKE: 2}


class OrderAdaptController:
    """Decide, per adaptation epoch, which traversal order the engine binds.

    The controller owns the engine's *current* (order, snake_group) pair on
    the continuous path; the engine asks :meth:`effective_group` for the
    traced operand each step and calls :meth:`maybe_adapt` once per mixed
    step. ``enabled=False`` keeps the metrics surface (current-order gauge,
    zero switch counter) but never samples or switches — the pinned-order
    engine configuration.
    """

    def __init__(
        self,
        registry: Registry,
        *,
        order: "Order | str",
        snake_group: Optional[int] = None,
        epoch: int = 8,
        hysteresis: float = 0.05,
        confirm: int = 2,
        shared_threshold: float = 0.25,
        enabled: bool = True,
    ):
        self.registry = registry
        self.order = Order.parse(order)
        self.snake_group = snake_group
        self.epoch = int(epoch)
        self.hysteresis = float(hysteresis)
        self.confirm = max(1, int(confirm))
        self.shared_threshold = float(shared_threshold)
        self.enabled = enabled
        self.switches = 0
        self.seeded_from: Optional[dict] = None
        self._pending: Optional[str] = None
        self._pending_count = 0
        self._m_switches = registry.counter("serve.order_switches")
        self._m_current = registry.gauge("serve.current_order")
        self._m_current.set(ORDER_INDEX[self.order])

    # ---- the per-step operand ------------------------------------------------

    def effective_group(self, n_blocks: int) -> int:
        """Effective reversal-group for the current order over ``n_blocks``
        pages — the int the engine feeds the mixed step's ``order_group``
        operand (host int; the jit boundary makes it a traced scalar)."""
        return resolve_order_group(self.order, self.snake_group, n_blocks)

    @property
    def candidate_orders(self) -> tuple[str, ...]:
        """Orders the LLC sampler must model for the controller to choose
        among — all three families (the current one listed first by the
        sampler's own convention)."""
        return (Order.CYCLIC.value, Order.SAWTOOTH.value, Order.BLOCK_SNAKE.value)

    # ---- engine-start cache seeding ------------------------------------------

    def seed_from_cache(
        self,
        path: str,
        *,
        arch: str,
        seq_bucket: int,
        capacity_mib: float,
        backend: Optional[str] = None,
    ) -> bool:
        """Seed (order, snake_group) from the persistent autotune cache.

        Nearest-bucket ``order_sweep`` lookup (``repro.obs.autotune``); on a
        hit the winner's order replaces the configured initial order before
        the first step ever runs. Missing file / no arch match → keep the
        configured order, return False.
        """
        rec = lookup_order_winner(
            load_autotune_cache(path),
            arch=arch,
            seq_bucket=seq_bucket,
            capacity_mib=capacity_mib,
            backend=backend,
        )
        if rec is None:
            return False
        winner = rec.get("winner", {})
        try:
            self.order = Order.parse(winner["order"])
        except (KeyError, ValueError):
            return False
        if winner.get("snake_group") is not None:
            self.snake_group = int(winner["snake_group"])
        self.seeded_from = rec
        self._m_current.set(ORDER_INDEX[self.order])
        return True

    # ---- the runtime decision loop -------------------------------------------

    def maybe_adapt(self, step_epoch: int, pool, sampler, step_q=None) -> bool:
        """Run one adaptation decision if ``step_epoch`` lands on the epoch.

        Samples the LLC models against the live pool (through ``sampler``,
        an ``obs.llc.LLCSampler``) and applies the hysteresis rule to the
        fresh per-candidate modeled miss bytes. On a switch, the sampler's
        notion of the current order — and the history entry that triggered
        the switch — are updated, so the recorded order is the one driving
        the *next* steps. ``step_q`` (the step's widest decode/verify
        chunk — K+1 under speculative decoding) is forwarded to the sampler
        so the recorded footprint reflects multi-token verification sweeps.
        Returns True iff the order changed.
        """
        if not self.enabled or self.epoch <= 0 or step_epoch % self.epoch != 0:
            return False
        if not sampler.sample(pool, step_q=step_q):
            return False
        entry = sampler.history[-1]
        switched = self.consider(
            sampler.last_fwd_miss,
            shared_miss=entry.get("shared_miss"),
            shared_frac=entry.get("shared_frac", 0.0),
        )
        if switched:
            sampler.current_order = self.order.value
            sampler.history[-1]["current_order"] = self.order.value
        return switched

    def consider(
        self,
        fwd_miss: Optional[dict],
        shared_miss: Optional[dict] = None,
        shared_frac: float = 0.0,
    ) -> bool:
        """Apply the hysteresis rule to one per-order modeled-miss reading.

        The base reading is the fwd-wavefront model; when the live
        shared-page fraction reaches ``shared_threshold``, the shared-prefix
        decode model is blended in, weighted by that fraction — a pool
        dominated by adopted prefix pages has cross-row reuse the fwd model
        cannot see, and the two models can disagree on the argmin (the flip
        the blend exists to catch). Split from :meth:`maybe_adapt` so unit
        tests (and offline replays) can drive the decision logic with
        synthetic readings — no pool or sampler required.
        """
        if not fwd_miss:
            return False
        blended = self.blend(fwd_miss, shared_miss, shared_frac)
        cur = blended.get(self.order.value)
        if cur is None:
            return False
        best_order = min(blended, key=blended.get)
        best = blended[best_order]
        improvement = (cur - best) / cur if cur > 0 else 0.0
        if best_order == self.order.value or improvement < self.hysteresis:
            self._pending, self._pending_count = None, 0
            return False
        if self._pending != best_order:
            self._pending, self._pending_count = best_order, 1
        else:
            self._pending_count += 1
        if self._pending_count < self.confirm:
            return False
        self.switch_to(best_order)
        return True

    def blend(
        self,
        fwd_miss: dict,
        shared_miss: Optional[dict],
        shared_frac: float,
    ) -> dict:
        """Per-order decision signal: fwd model blended with the
        shared-prefix model by the live shared-page fraction ``w`` —
        ``(1-w)*fwd + w*shared`` — once that fraction reaches
        ``shared_threshold``; below it (or with no shared reading) the fwd
        reading passes through untouched. Orders the shared model did not
        score fall back to their fwd value."""
        if not shared_miss or shared_frac < self.shared_threshold:
            return dict(fwd_miss)
        w = min(max(shared_frac, 0.0), 1.0)
        return {
            o: (1.0 - w) * v + w * shared_miss.get(o, v)
            for o, v in fwd_miss.items()
        }

    def switch_to(self, order: "Order | str") -> None:
        """Unconditional switch (the hysteresis-approved tail of
        :meth:`consider`; also the forced-switch hook tests use). Publishes
        the counter bump and the new gauge value; ``snake_group`` is kept —
        it parameterizes block_snake whenever that family is (re)entered."""
        self.order = Order.parse(order)
        self.switches += 1
        self._pending, self._pending_count = None, 0
        self._m_switches.inc()
        self._m_current.set(ORDER_INDEX[self.order])

    @property
    def effective_snake_group(self) -> int:
        """The group block_snake runs at if selected (config or default)."""
        return DEFAULT_SNAKE_GROUP if self.snake_group is None else self.snake_group
