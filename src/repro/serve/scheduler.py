"""Request schedulers: static groups vs continuous batching.

The static scheduler reproduces the original engine behavior — requests are
chopped into fixed ``batch_size`` groups and each group runs prefill + decode
to completion before the next starts (a short request parked next to a long
one holds its slot doing nothing).

The continuous scheduler gives each request a *slot* in a persistent decode
batch: requests are admitted the moment a slot and enough KV pages are free
(including mid-decode), and retire individually on their own EOS /
``max_new_tokens``, freeing the slot for the next waiting request. Admission
is FIFO in arrival order, gated on the paged pool's worst-case reservation
(`kv_pool.PagedKVPool.can_admit`), so a running sequence can never be
starved of pages by a later admission. ``Request.arrival`` (a decode-step
timestamp, used by the serve benchmark to model staggered traffic) holds a
request out of the queue until the engine's step counter reaches it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["Slot", "ContinuousScheduler"]


@dataclasses.dataclass
class Slot:
    """One running sequence in the continuous batch."""

    request: object                   # serve.engine.Request
    eos_id: int
    new_limit: int                    # clamped max_new_tokens
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    def record(self, token: int) -> bool:
        """Append a token; returns True when the sequence is finished."""
        self.generated.append(token)
        if token == self.eos_id or len(self.generated) >= self.new_limit:
            self.done = True
        return self.done


class ContinuousScheduler:
    """Admission queue + slot lifecycle for continuous batching."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.waiting: list = []
        self.slots: list[Optional[Slot]] = [None] * n_slots

    def submit(self, requests: Sequence) -> None:
        self.waiting.extend(requests)
        # FIFO in arrival order; python's stable sort keeps submission order
        # within one arrival step.
        self.waiting.sort(key=lambda r: getattr(r, "arrival", 0))

    # ---- queries -------------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def next_arrival(self) -> Optional[int]:
        return getattr(self.waiting[0], "arrival", 0) if self.waiting else None

    def pop_admissible(self, step: int) -> Optional[object]:
        """Next waiting request whose arrival time has passed, if any."""
        if self.waiting and getattr(self.waiting[0], "arrival", 0) <= step:
            return self.waiting.pop(0)
        return None

    def requeue(self, request) -> None:
        """Put an admissible-but-unplaceable request back at the queue head
        (no pages free yet — admission stays FIFO, no overtaking)."""
        self.waiting.insert(0, request)

    # ---- lifecycle -----------------------------------------------------------

    def place(self, slot: int, request, *, eos_id: int, new_limit: int) -> Slot:
        assert self.slots[slot] is None, f"slot {slot} occupied"
        st = Slot(request=request, eos_id=eos_id, new_limit=new_limit)
        self.slots[slot] = st
        return st

    def retire(self, slot: int) -> Slot:
        st = self.slots[slot]
        assert st is not None
        self.slots[slot] = None
        return st
