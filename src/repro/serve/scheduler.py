"""Request schedulers: static groups vs token-budget continuous batching.

The static scheduler reproduces the original engine behavior — requests are
chopped into fixed ``batch_size`` groups and each group runs prefill + decode
to completion before the next starts (a short request parked next to a long
one holds its slot doing nothing).

The continuous scheduler gives each request a *slot* in a persistent ragged
batch and plans one **token-budget mixed step** at a time: every decoding
slot contributes one q_len=1 row, and the remaining budget is split into
prefill chunks (q_len up to ``prefill_chunk``, round-robin across slots
still working through their prompts). A long prompt is therefore *preempted*
by construction — it advances chunk by chunk while decode rows keep emitting
every step and new arrivals keep being admitted — instead of stalling the
whole batch for a monolithic prefill. Requests are admitted the moment a
slot and enough KV pages are free (including mid-decode), and retire
individually on their own EOS / ``max_new_tokens``, freeing the slot for the
next waiting request.

Admission is FIFO in arrival order, gated on the paged pool's worst-case
reservation (`kv_pool.PagedKVPool.admit`), so a running sequence can never
be starved of pages by a later admission. ``Request.arrival`` (a step
timestamp, used by the serve benchmark to model staggered traffic) holds a
request out of the queue until the engine's step counter reaches it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = ["Slot", "StepItem", "ContinuousScheduler"]


@dataclasses.dataclass
class Slot:
    """One running sequence in the continuous batch."""

    request: object                   # serve.engine.Request
    eos_id: int
    new_limit: int                    # clamped max_new_tokens
    prompt: np.ndarray = None         # clamped prompt tokens (1D int32)
    prompt_pos: int = 0               # prompt tokens already in cache
                                      # (shared-prefix adoption + chunks)
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False
    n_prior: int = 0                  # leading entries of ``generated`` that
                                      # were re-prefilled as part of the
                                      # prompt on a preemption restore (the
                                      # committed stream is prompt +
                                      # generated[n_prior:] — prompt already
                                      # carries the prior tokens)

    @property
    def prefilling(self) -> bool:
        """Still working through the prompt (no token sampled yet)."""
        return self.prompt is not None and self.prompt_pos < len(self.prompt)

    def record(self, token: int) -> bool:
        """Append a token; returns True when the sequence is finished."""
        self.generated.append(token)
        if token == self.eos_id or len(self.generated) >= self.new_limit:
            self.done = True
        return self.done


@dataclasses.dataclass(frozen=True)
class StepItem:
    """One row of a planned mixed step."""

    slot: int
    q_len: int
    is_prefill: bool
    finishes_prompt: bool = False     # this chunk covers the prompt's last
                                      # token -> the row samples this step
    n_draft: int = 0                  # speculative draft tokens verified in
                                      # this row (decode rows only):
                                      # q_len == 1 + n_draft


class ContinuousScheduler:
    """Admission queue + slot lifecycle + per-step token budgeting."""

    def __init__(
        self,
        n_slots: int,
        *,
        token_budget: Optional[int] = None,
        prefill_chunk: int = 64,
    ):
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.n_slots = n_slots
        self.prefill_chunk = prefill_chunk
        # Default: every decode row plus one full prefill chunk per step.
        self.token_budget = token_budget or (n_slots + prefill_chunk)
        if self.token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.waiting: list = []
        self.slots: list[Optional[Slot]] = [None] * n_slots
        # Slots whose KV is parked on the host tier (serve.tiering): placed
        # and alive — they count against admission and keep their Slot — but
        # excluded from step plans until the engine resumes them.
        self.suspended: set[int] = set()
        self._rr = 0                  # round-robin cursor over prefill slots

    def submit(self, requests: Sequence) -> None:
        self.waiting.extend(requests)
        # FIFO in arrival order; python's stable sort keeps submission order
        # within one arrival step.
        self.waiting.sort(key=lambda r: getattr(r, "arrival", 0))

    # ---- queries -------------------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def runnable_slots(self) -> list[int]:
        """Active slots eligible for step plans (suspension filtered)."""
        return [
            i
            for i, s in enumerate(self.slots)
            if s is not None and i not in self.suspended
        ]

    def next_arrival(self) -> Optional[int]:
        return getattr(self.waiting[0], "arrival", 0) if self.waiting else None

    def pop_admissible(self, step: int) -> Optional[object]:
        """Next waiting request whose arrival time has passed, if any."""
        if self.waiting and getattr(self.waiting[0], "arrival", 0) <= step:
            return self.waiting.pop(0)
        return None

    def requeue(self, request) -> None:
        """Put an admissible-but-unplaceable request back at the queue head
        (no pages free yet — admission stays FIFO, no overtaking). Preempted
        requests also land here: they restart before later arrivals."""
        self.waiting.insert(0, request)

    def drain_waiting(self, pred) -> list:
        """Remove and return every waiting request matching ``pred`` (used
        for boundary-time cancellation / deadline expiry of queued work)."""
        hit = [r for r in self.waiting if pred(r)]
        if hit:
            self.waiting = [r for r in self.waiting if not pred(r)]
        return hit

    def shed_over(self, step: int, max_queue: int) -> list:
        """Load-shed: drop the newest *arrived* requests beyond ``max_queue``.

        Only requests whose ``arrival`` has passed count against the bound —
        future traffic modeled by the benchmark's staggered arrivals has not
        actually joined the queue yet. Reject-newest keeps the policy fair to
        earlier arrivals (FIFO order is preserved for survivors).
        """
        arrived = [r for r in self.waiting if getattr(r, "arrival", 0) <= step]
        if len(arrived) <= max_queue:
            return []
        shed = arrived[max_queue:]
        drop = set(map(id, shed))
        self.waiting = [r for r in self.waiting if id(r) not in drop]
        return shed

    # ---- step planning -------------------------------------------------------

    def plan_step(self, draft_lens: Optional[dict] = None) -> list[StepItem]:
        """Plan one ragged mixed step under the token budget.

        Decode rows come first (one token each — they are latency-critical
        and cheap); the leftover budget is dealt to prefilling slots
        round-robin in chunks of up to ``prefill_chunk`` tokens. When decode
        rows alone exhaust the budget, prefill simply waits — decode slots
        retire in bounded time (``new_limit``) and hand their budget back,
        so prefill progress is delayed, never deadlocked. If *only* prefill
        slots are active the full budget is theirs.

        ``draft_lens`` (slot -> K speculative draft tokens) upgrades decode
        rows to ``q_len = 1 + K`` verification chunks. Drafts are best
        effort: each row's K is clamped to ``prefill_chunk - 1`` (the row
        must fit the step's wide width) and to the budget left after every
        decode row's guaranteed 1 token, so speculation can never starve a
        decode row out of a plan it would otherwise be in.
        """
        decode_rows: list[int] = []
        prefill_rows: list[int] = []
        for i, st in enumerate(self.slots):
            if st is None or st.done or i in self.suspended:
                continue
            (prefill_rows if st.prefilling else decode_rows).append(i)
        items = []
        spare = self.token_budget - len(decode_rows)
        for i in decode_rows:
            k = 0
            if draft_lens:
                k = min(
                    max(int(draft_lens.get(i, 0)), 0),
                    self.prefill_chunk - 1,
                    max(spare, 0),
                )
                spare -= k
            items.append(StepItem(i, 1 + k, False, n_draft=k))
        left = self.token_budget - sum(it.q_len for it in items)
        if not prefill_rows or left <= 0:
            return items
        # Rotate so successive steps serve prefilling slots fairly.
        order = sorted(prefill_rows, key=lambda i: (i - self._rr) % self.n_slots)
        for slot in order:
            if left <= 0:
                break
            st = self.slots[slot]
            n = min(self.prefill_chunk, len(st.prompt) - st.prompt_pos, left)
            items.append(
                StepItem(
                    slot,
                    n,
                    True,
                    finishes_prompt=st.prompt_pos + n >= len(st.prompt),
                )
            )
            left -= n
            self._rr = (slot + 1) % self.n_slots
        return items

    # ---- lifecycle -----------------------------------------------------------

    def place(
        self,
        slot: int,
        request,
        *,
        eos_id: int,
        new_limit: int,
        prompt: Optional[np.ndarray] = None,
        prompt_pos: int = 0,
    ) -> Slot:
        assert self.slots[slot] is None, f"slot {slot} occupied"
        st = Slot(
            request=request,
            eos_id=eos_id,
            new_limit=new_limit,
            prompt=None if prompt is None else np.asarray(prompt, np.int32),
            prompt_pos=prompt_pos,
        )
        self.slots[slot] = st
        return st

    def suspend(self, slot: int) -> None:
        """Exclude a placed slot from step plans (its KV spilled to host)."""
        assert self.slots[slot] is not None, f"slot {slot} is empty"
        self.suspended.add(slot)

    def resume(self, slot: int) -> None:
        """Return a suspended slot to step planning (its KV re-resident)."""
        self.suspended.discard(slot)

    def retire(self, slot: int) -> Slot:
        st = self.slots[slot]
        assert st is not None
        self.slots[slot] = None
        self.suspended.discard(slot)
        return st
