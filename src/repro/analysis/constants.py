"""Target-hardware constants (TPU v5e) for the roofline analysis.

These are the numbers the assignment fixes; the container runs on CPU, the
roofline is *derived* (compiled-HLO terms / these peaks), not measured.
"""

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link (assignment: ~50 GB/s/link)
CHIP_HBM_BYTES = 16 * 2**30   # v5e: 16 GiB per chip
VMEM_BYTES = 128 * 2**20
