from repro.analysis import constants, hlo, roofline

__all__ = ["constants", "hlo", "roofline"]
