"""HLO-text parsing: per-device collective traffic from a compiled module.

``compiled.as_text()`` of a GSPMD-partitioned module has per-device shapes;
summing the result-buffer sizes of every collective op gives the per-chip
collective byte count used by the §Roofline collective term.

cost_analysis() does NOT include collective bytes — this parser is the
authoritative source (assignment instruction).
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "parse_shape_bytes", "count_ops"]

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches: "%name = TYPE op-name(" where TYPE may be a tuple
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


def parse_shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes (per device). '-done' ops skipped to
    avoid double counting async pairs."""
    out: dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, phase = m.groups()
        if phase == "-done":
            continue
        out[kind] += parse_shape_bytes(type_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, names=("fusion", "while", "custom-call")) -> dict[str, int]:
    return {n: len(re.findall(rf"\b{re.escape(n)}\(", hlo_text)) for n in names}
