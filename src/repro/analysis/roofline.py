"""Roofline terms per (arch × shape × mesh) from dry-run artifacts.

  compute term    = HLO_FLOPs(per device) / peak_FLOP/s
  memory term     = HLO_bytes(per device) / HBM_bw
  collective term = collective_bytes(per device) / link_bw

cost_analysis() of a GSPMD-partitioned module reports *per-partition*
numbers (verified in tests), so no extra division by chip count. The
"useful compute" ratio compares 6·N_active·D model FLOPs against the global
compiled FLOPs (chips × per-device) — it exposes remat recompute, capacity
overcounting (MoE), and padding waste.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.analysis import constants as C
from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["RooflineTerms", "analyze", "param_count", "active_param_count", "model_flops"]


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float
    useful_ratio: float
    step_s: float              # max of the three terms (no-overlap bound)
    hw_flops_util: float       # model_flops / (chips * peak * step_s)

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    coll: dict,
    cfg: ModelConfig,
    shape_cfg: ShapeConfig,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0.0))
    t_c = flops / C.PEAK_FLOPS_BF16
    t_m = byts / C.HBM_BW
    t_x = cb / C.ICI_BW_PER_LINK
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_cfg)
    global_flops = flops * chips
    useful = mf / global_flops if global_flops else 0.0
    step_s = max(t_c, t_m, t_x)
    util = mf / (chips * C.PEAK_FLOPS_BF16 * step_s) if step_s else 0.0
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=cb,
        compute_s=t_c,
        memory_s=t_m,
        collective_s=t_x,
        bottleneck=bottleneck,
        model_flops_global=mf,
        useful_ratio=useful,
        step_s=step_s,
        hw_flops_util=util,
    )


# --------------------------------------------------------------------------
# analytic parameter / FLOP counts
# --------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.hd
    p = cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * cfg.d_model
    if cfg.qkv_bias:
        p += hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
    return p


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff


def _mamba_params(cfg: ModelConfig) -> int:
    m = cfg.ssm
    di = m.expand * cfg.d_model
    n = m.state_dim
    h = di // m.head_dim
    return (
        cfg.d_model * (2 * di + 2 * n + h)
        + m.conv_width * (di + 2 * n)
        + di * cfg.d_model
        + di
        + 3 * h
    )


def param_count(cfg: ModelConfig, *, active_only: bool = False) -> int:
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm"):
        per = _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff)
        total = cfg.n_layers * per + emb
        if cfg.family == "vlm":
            total += cfg.d_model * cfg.d_model
        return total
    if cfg.family == "moe":
        e = cfg.moe.num_experts if not active_only else cfg.moe.top_k
        per = (
            _attn_params(cfg)
            + e * 3 * cfg.d_model * cfg.moe.d_ff_expert
            + cfg.d_model * cfg.moe.num_experts
        )
        return cfg.n_layers * per + emb
    if cfg.family == "ssm":
        return cfg.n_layers * _mamba_params(cfg) + emb
    if cfg.family == "hybrid":
        shared = (
            2 * cfg.d_model * cfg.d_model
            + _attn_params(cfg)
            + _ffn_params(cfg, cfg.d_ff)
        )
        return cfg.n_layers * _mamba_params(cfg) + shared + emb
    if cfg.family == "encdec":
        enc = cfg.n_encoder_layers * (_attn_params(cfg) + _ffn_params(cfg, cfg.d_ff))
        dec = cfg.n_layers * (2 * _attn_params(cfg) + _ffn_params(cfg, cfg.d_ff))
        return enc + dec + emb
    raise ValueError(cfg.family)


def active_param_count(cfg: ModelConfig) -> int:
    return param_count(cfg, active_only=True)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D for train, 2·N_active·D for prefill/decode forward."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch
