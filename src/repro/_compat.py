"""Forward-compatibility shims for older JAX runtimes.

The codebase is written against the modern JAX surface (``jax.set_mesh``,
``jax.shard_map``, ``jax.sharding.AxisType``, the positional
``AbstractMesh(axis_sizes, axis_names)`` constructor). On runtimes where
those names are missing (jax 0.4.x) this module installs equivalent shims so
every call site — including the test-suite snippets that run in spawned
interpreters — works unchanged. On a new-enough JAX every block below is a
no-op, so the shims age out automatically.

Imported for its side effects from ``repro/__init__.py``; safe to import
multiple times.
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.sharding as jshard

__all__ = ["install"]


def _abstract_mesh_needs_shim() -> bool:
    try:
        jshard.AbstractMesh((1,), ("x",))
        return False
    except TypeError:
        return True


@functools.cache
def install() -> None:
    # -- AbstractMesh(axis_sizes, axis_names) ------------------------------
    # jax 0.4.x spells it AbstractMesh(tuple[(name, size), ...]). Subclass
    # (not wrap) so isinstance checks inside jax keep passing.
    if _abstract_mesh_needs_shim():
        _Real = jshard.AbstractMesh

        class AbstractMesh(_Real):  # noqa: D401 - thin signature adapter
            def __init__(self, *args, **kwargs):
                kwargs.pop("axis_types", None)  # 0.4.x meshes are all "auto"
                if len(args) == 2:
                    axis_sizes, axis_names = args
                    args = (tuple(zip(axis_names, axis_sizes)),)
                super().__init__(*args, **kwargs)

        AbstractMesh.__name__ = "AbstractMesh"
        jshard.AbstractMesh = AbstractMesh

    # -- AxisType / make_mesh(axis_types=...) ------------------------------
    if not hasattr(jshard, "AxisType"):

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jshard.AxisType = AxisType

        _real_make_mesh = jax.make_mesh

        @functools.wraps(_real_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            # 0.4.x has no axis_types concept; every axis behaves as Auto,
            # which is exactly what this codebase requests.
            return _real_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    # -- jax.set_mesh ------------------------------------------------------
    if not hasattr(jax, "set_mesh"):

        def set_mesh(mesh):
            """Use the mesh as a context manager (0.4.x resource-env entry).

            ``jax.sharding.Mesh`` is itself a context manager on 0.4.x, and
            entering it is what lets bare ``PartitionSpec``s (e.g. in
            ``with_sharding_constraint``) resolve against the mesh.
            """
            return mesh

        jax.set_mesh = set_mesh

    # -- jax.shard_map -----------------------------------------------------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        jax.shard_map = _shard_map

    # -- Compiled.cost_analysis() ------------------------------------------
    # 0.4.x returns list[dict] (one per program); modern jax returns the
    # dict directly. Normalize so callers can do dict(compiled.cost_analysis()).
    try:
        from jax._src import stages as _stages

        _real_cost = _stages.Compiled.cost_analysis

        @functools.wraps(_real_cost)
        def _cost_analysis(self):
            out = _real_cost(self)
            if isinstance(out, (list, tuple)):
                return out[0] if out else {}
            return out

        _stages.Compiled.cost_analysis = _cost_analysis
    except Exception:  # pragma: no cover - layout changed; modern jax is fine
        pass
