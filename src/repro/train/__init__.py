from repro.train import checkpoint, fault_tolerance, loop, optimizer, step

__all__ = ["checkpoint", "fault_tolerance", "loop", "optimizer", "step"]
