"""Train/serve step factories: pjit-compiled, sharded, microbatched.

``make_train_step`` builds the jitted update used by the training loop, the
launcher and the dry-run. The same factory serves the 40-cell dry-run (it is
lowered with ShapeDtypeStructs) and real training (smoke scale on CPU).

Gradient accumulation: the global batch is reshaped to
(microbatches, B/microbatches, ...) and scanned; grads are averaged in f32.
With FSDP-sharded params this is ZeRO-style: grads inherit the parameter
sharding (reduce-scattered by GSPMD), optimizer state is sharded likewise.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.dist import sharding as shd
from repro.models.model import LM
from repro.train.optimizer import OptState, make_optimizer

__all__ = ["TrainState", "make_train_state", "make_train_step", "make_serve_steps"]


TrainState = dict  # {"params": pytree, "opt": OptState}


def make_train_state(lm: LM, tcfg: TrainConfig, key) -> TrainState:
    params = lm.init(key)
    opt_init, _ = make_optimizer(tcfg)
    return {"params": params, "opt": opt_init(params)}


def shard_state(state: TrainState, pcfg: ParallelConfig, mesh: Mesh) -> TrainState:
    """Place a (host/replicated) state onto its target shardings. jit with
    in_shardings does not reshard committed arrays — call this once after
    init/restore."""
    return jax.device_put(state, state_shardings(state, pcfg, mesh))


def state_shardings(state, pcfg: ParallelConfig, mesh: Mesh):
    """Opt state mirrors param sharding (ZeRO); factored stats tighten."""
    pspecs = shd.param_specs(state["params"], pcfg, mesh)

    def opt_leaf(path, x):
        # OptState.m / .v mirror params structure below the NamedTuple field
        return None

    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    def mirror(tree):
        """Shard each moment leaf like its param (tighten for factored)."""

        def leaf(path, x):
            spec = shd.spec_for(shd._path_str(path), x.shape, pcfg, mesh)
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(leaf, tree)

    opt = state["opt"]
    return {
        "params": pshard,
        "opt": OptState(
            step=NamedSharding(mesh, P()),
            m=mirror(opt.m),
            v=mirror(opt.v),
        ),
    }


def make_train_step(
    lm: LM,
    tcfg: TrainConfig,
    pcfg: ParallelConfig,
    mesh: Mesh,
):
    """Returns (jitted_step, in_shardings info) — step(state, batch) ->
    (state, metrics)."""
    _, opt_update = make_optimizer(tcfg)
    n_micro = max(1, pcfg.microbatches)

    def loss_fn(params, batch):
        loss, metrics = lm.loss(params, batch)
        return loss, metrics

    def step(state, batch):
        params = state["params"]

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:

            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params
            )
            mbatch = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )
            (grads, loss_sum), metrics = jax.lax.scan(micro, (g0, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = jax.tree.map(lambda x: x.mean(0), metrics)

        new_params, new_opt, stats = opt_update(grads, state["opt"], params)
        metrics = dict(metrics, **stats, loss_mean=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    def shardings_for(state, batch):
        st_sh = state_shardings(state, pcfg, mesh)
        b_sh = shd.batch_shardings(batch, pcfg, mesh)
        return st_sh, b_sh

    def compile_step(state_spec, batch_spec):
        st_sh, b_sh = shardings_for(state_spec, batch_spec)
        return jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )

    return step, compile_step


def make_serve_steps(lm: LM, pcfg: ParallelConfig, mesh: Mesh, *, max_len: int):
    """prefill(params, batch) -> (logits, caches); decode(params, tok, caches)."""

    def prefill(params, batch):
        return lm.prefill(params, batch, max_len)

    def decode(params, tokens, caches):
        return lm.decode_step(params, tokens, caches)

    def compile_prefill(params_spec, batch_spec):
        p_sh = shd.param_shardings(params_spec, pcfg, mesh)
        b_sh = shd.batch_shardings(batch_spec, pcfg, mesh)
        return jax.jit(prefill, in_shardings=(p_sh, b_sh))

    def compile_decode(params_spec, tok_spec, caches_spec):
        p_sh = shd.param_shardings(params_spec, pcfg, mesh)
        t_sh = shd.batch_shardings(tok_spec, pcfg, mesh)
        c_sh = shd.cache_shardings(caches_spec, pcfg, mesh)
        return jax.jit(
            decode, in_shardings=(p_sh, t_sh, c_sh), donate_argnums=(2,)
        )

    return prefill, decode, compile_prefill, compile_decode
