"""Fault tolerance: step watchdogs, failure injection, elastic re-mesh.

Designed for the 1000+-node regime the system prompt targets:

  * ``Watchdog`` — wall-clock bound per step; a hung collective (dead host,
    network partition) raises instead of blocking the job forever. At real
    scale this is the signal to re-form the mesh from survivors.
  * ``FailureInjector`` — deterministic fault schedule for integration tests
    (kill at step k, slow step = straggler, corrupt grads = bit-flip drill).
  * ``elastic_remesh`` — given the surviving device list, build the largest
    usable (data, model) mesh, recompute shardings, and restore the latest
    checkpoint into it. Batch is re-split over the new data extent.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["Watchdog", "StepTimeout", "FailureInjector", "elastic_remesh", "usable_mesh_shape"]


class StepTimeout(RuntimeError):
    pass


class Watchdog:
    """Context manager raising StepTimeout if the body exceeds ``timeout_s``.

    jax dispatch is async; callers must block (e.g. metrics fetch) inside.
    """

    def __init__(self, timeout_s: float, on_timeout: Optional[Callable] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.fired = False

    def _fire(self):
        self.fired = True
        if self.on_timeout:
            self.on_timeout()

    def __enter__(self):
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._timer:
            self._timer.cancel()
        if self.fired and exc_type is None:
            raise StepTimeout(f"step exceeded {self.timeout_s}s watchdog")
        return False


@dataclasses.dataclass
class FailureInjector:
    """Deterministic fault schedule keyed by step number."""

    crash_at: Sequence[int] = ()
    straggle_at: Sequence[int] = ()
    straggle_seconds: float = 0.5

    def maybe_fail(self, step: int):
        if step in self.crash_at:
            raise RuntimeError(f"[injected] node failure at step {step}")
        if step in self.straggle_at:
            time.sleep(self.straggle_seconds)


def usable_mesh_shape(n_devices: int, *, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) grid from survivors, keeping TP degree if
    possible (params were sharded model-wise; keeping it avoids resharding
    the TP axis), else the biggest TP degree that divides the survivors."""
    mp = model_parallel
    while mp > 1 and n_devices % mp:
        mp //= 2
    return (n_devices // mp, mp)


def elastic_remesh(
    devices: Sequence,
    *,
    model_parallel: int,
    axis_names: tuple[str, str] = ("data", "model"),
) -> Mesh:
    """Build a mesh from an arbitrary surviving device list."""
    n = len(devices)
    dp, mp = usable_mesh_shape(n, model_parallel=model_parallel)
    usable = dp * mp
    grid = np.asarray(devices[:usable]).reshape(dp, mp)
    return Mesh(grid, axis_names)
