"""Optimizers (pure JAX, pytree states): AdamW and memory-factored AdamW.

``adamw_factored`` keeps the first moment in bf16 and replaces the second
moment of rank>=2 leaves with Adafactor-style row/col statistics — this is
what lets llama3-405b-class configs fit the assigned 256x16GB pod (see
EXPERIMENTS.md §Dry-run memory table).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

__all__ = ["OptState", "make_optimizer", "cosine_schedule", "global_norm"]


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def cosine_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return cfg.lr * warm * (0.1 + 0.9 * cos)

    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


_NO_DECAY = {"b", "bias", "scale", "a_log", "dt_bias", "d_skip", "conv_b"}


def _decay_mask(path) -> bool:
    """Weight decay only on weight matrices (skip norms, biases, scalars)."""
    leaf_name = str(getattr(path[-1], "key", path[-1])) if path else ""
    return leaf_name not in _NO_DECAY


def _factored_shape(shape):
    return len(shape) >= 2


def make_optimizer(cfg: TrainConfig):
    """Returns (init_fn, update_fn).

    update(grads, state, params) -> (new_params, new_state, stats)
    """
    factored = cfg.optimizer == "adamw_factored"
    lr_fn = cosine_schedule(cfg)

    def init(params) -> OptState:
        def m_leaf(x):
            return jnp.zeros_like(x, dtype=jnp.bfloat16 if factored else jnp.float32)

        def v_leaf(x):
            if factored and _factored_shape(x.shape):
                return {
                    "row": jnp.zeros(x.shape[:-1], jnp.float32),
                    "col": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32),
                }
            return jnp.zeros_like(x, dtype=jnp.float32)

        return OptState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(m_leaf, params),
            v=jax.tree.map(v_leaf, params),
        )

    def update(grads, state: OptState, params):
        step = state.step + 1
        lr = lr_fn(step)
        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        b1, b2, eps = cfg.b1, cfg.b2, 1e-8
        bc1 = 1.0 - b1**step.astype(jnp.float32)
        bc2 = 1.0 - b2**step.astype(jnp.float32)

        def upd(path, g, m, v, p):
            g = g.astype(jnp.float32) * clip
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            if isinstance(v, dict):  # factored second moment
                g2 = g * g + 1e-30
                row = b2 * v["row"] + (1 - b2) * jnp.mean(g2, axis=-1)
                col = b2 * v["col"] + (1 - b2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction: v_ij ~ row_i * col_j / mean(row)
                denom = jnp.clip(jnp.mean(row, axis=-1, keepdims=True), 1e-30, None)
                v_hat = (row[..., :, None] * col[..., None, :]) / denom[..., None]
                v_new = {"row": row, "col": col}
                nu = v_hat / bc2
            else:
                v_new = b2 * v + (1 - b2) * g * g
                nu = v_new / bc2
            mu = m_new / bc1
            delta = mu / (jnp.sqrt(nu) + eps)
            if _decay_mask(path):
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p_new, m_new.astype(m.dtype), v_new

        flat = jax.tree_util.tree_map_with_path(
            lambda path, g, m, v, p: upd(path, g, m, v, p),
            grads,
            state.m,
            state.v,
            params,
            is_leaf=lambda x: isinstance(x, dict) and set(x) == {"row", "col"},
        )
        is3 = lambda x: isinstance(x, tuple) and len(x) == 3
        new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=is3)
        new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=is3)
        new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=is3)
        stats = {"lr": lr, "grad_norm": gnorm, "clip": clip}
        return new_params, OptState(step=step, m=new_m, v=new_v), stats

    return init, update
