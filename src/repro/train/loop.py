"""Fault-tolerant training loop: checkpoint/resume, watchdog, injection.

The loop is deliberately plain: a production job wraps exactly this shape —
build step -> restore-or-init -> iterate(data) with watchdog ->
checkpoint cadence -> on failure: resume from latest (same or smaller mesh).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.data.pipeline import DataConfig, make_batch_iterator
from repro.models.model import LM
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FailureInjector, StepTimeout, Watchdog
from repro.train.step import make_train_state, make_train_step, shard_state

log = logging.getLogger(__name__)

__all__ = ["TrainResult", "run_training"]


@dataclasses.dataclass
class TrainResult:
    final_step: int
    losses: list
    resumed_from: Optional[int]
    interrupted: bool = False


def run_training(
    lm: LM,
    tcfg: TrainConfig,
    pcfg: ParallelConfig,
    mesh,
    *,
    steps: Optional[int] = None,
    data_cfg: Optional[DataConfig] = None,
    injector: Optional[FailureInjector] = None,
    step_timeout_s: float = 0.0,
    log_every: int = 10,
    make_batch: Optional[Callable[[int], dict]] = None,
) -> TrainResult:
    steps = steps or tcfg.total_steps
    ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)

    with jax.set_mesh(mesh):
        state = make_train_state(lm, tcfg, jax.random.PRNGKey(tcfg.seed))
        resumed_from = None
        if ckpt.latest_step() is not None:
            state, resumed = ckpt.restore_latest(state)
            resumed_from = resumed
            log.info("resumed from step %d", resumed)
        state = shard_state(state, pcfg, mesh)
        start = resumed_from + 1 if resumed_from is not None else 0

        if make_batch is None:
            assert data_cfg is not None
            src = make_batch_iterator(data_cfg, start_step=start)
            batch_fn = lambda step: next(iter(src))
        else:
            batch_fn = make_batch

        step_fn, compile_step = make_train_step(lm, tcfg, pcfg, mesh)
        batch0 = batch_fn(start)
        compiled = compile_step(state, batch0)

        losses = []
        interrupted = False
        t0 = time.time()
        i = start
        while i < steps:
            batch = batch_fn(i) if i != start else batch0
            try:
                if injector is not None:
                    injector.maybe_fail(i)
                if step_timeout_s > 0:
                    with Watchdog(step_timeout_s):
                        state, metrics = compiled(state, batch)
                        loss = float(metrics["loss"])  # blocks inside watchdog
                else:
                    state, metrics = compiled(state, batch)
                    loss = float(metrics["loss"])
            except StepTimeout:
                log.warning("step %d hit watchdog; re-running batch", i)
                continue  # straggler mitigation: redo the step
            except RuntimeError as e:
                log.error("step %d failed: %s — checkpoint + stop", i, e)
                interrupted = True
                break
            losses.append(loss)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {i}: {loss}")
            if log_every and i % log_every == 0:
                dt = time.time() - t0
                log.info("step %d loss %.4f (%.2fs elapsed)", i, loss, dt)
            if tcfg.checkpoint_every and (i + 1) % tcfg.checkpoint_every == 0:
                ckpt.save(state, i)
            i += 1

        ckpt.save(state, max(i - 1, 0), blocking=True)
        return TrainResult(
            final_step=i - 1,
            losses=losses,
            resumed_from=resumed_from,
            interrupted=interrupted,
        )
